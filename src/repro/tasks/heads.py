"""Task-head registry: N downstream consumers of one restored BaF tensor.

The source paper compresses the split activation for exactly one consumer
(the detector's cloud half). The multi-task line of work (Alvar & Bajić
2020, arXiv 2002.07048; "Multi-task learning with compressible features",
arXiv 1902.05179) shares that single encoded stream across several task
heads — here:

  * ``classify``: the repo's own cloud tail (models/cnn.py ``cnn_cloud``)
    — Leaky sigma, darknet res blocks, GAP, dense class head. It reuses the
    gateway's CNN params; the head bank carries no extra weights for it.
  * ``detect``: a dense per-cell prediction head in the style of
    models/encdec.py's encoder block — the restored tensor's spatial grid
    is flattened to tokens, projected to a small d_model, passed through
    one bidirectional LayerNorm-attention + GELU-FFN block
    (models/attention.py + models/ffn.py, the exact primitives encdec's
    ``_enc_block_init`` composes), then projected to a YOLO-shaped
    (box_fields + num_classes) vector per cell.
  * ``embed``: a lightweight retrieval embedding — Leaky sigma, global
    average pool, dense projection, L2 normalization.

Every head consumes the *restored* tensor ``z_tilde`` that
:meth:`repro.pipeline.CompressionPlan.restore` produces — one decode +
restore pass feeds all of them (the gateway asserts this). Forwards are
jitted once per (head, config) via an lru cache, mirroring
``core.split._jitted_cnn_fns`` so per-tenant gateways in tests/benchmarks
share one trace cache.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.models.attention import attention_apply, init_attention
from repro.models.ffn import ffn_apply, init_ffn


class HeadConfig(NamedTuple):
    """Static geometry every head's init/forward closes over.

    split_p     : channels of the restored split tensor (CNNConfig.split_p)
    num_classes : classification/detection class count
    d_model     : token width of the detect head's encoder block
    n_heads     : attention heads of the detect head
    d_ff        : FFN width of the detect head
    box_fields  : per-cell box regression slots of the detect head
    embed_dim   : output width of the embedding head
    """
    split_p: int
    num_classes: int = 8
    d_model: int = 32
    n_heads: int = 2
    d_ff: int = 64
    box_fields: int = 5
    embed_dim: int = 32

    @property
    def head_dim(self) -> int:
        if self.d_model % self.n_heads:
            raise ValueError(f"d_model {self.d_model} not divisible by "
                             f"n_heads {self.n_heads}")
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class TaskHead:
    """One registered downstream task.

    init(key, cfg)                      -> head params (may be empty: the
                                           classify head reuses CNN params)
    forward(cnn_params, head_params, z, cfg) -> task output for the batch
    divergence(ref, out)                -> scalar output divergence of this
                                           head's outputs vs the
                                           uncompressed-tensor reference
                                           (0 = identical; lower is better)
    """
    name: str
    init: Callable
    forward: Callable
    divergence: Callable


_REGISTRY: dict[str, TaskHead] = {}


def register_head(head: TaskHead) -> TaskHead:
    if head.name in _REGISTRY:
        raise ValueError(f"task head {head.name!r} already registered")
    _REGISTRY[head.name] = head
    return head


def get_head(name: str) -> TaskHead:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown task head {name!r} "
                       f"(registered: {available_heads()})") from None


def available_heads() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# classify — the repo's own cloud tail
# ---------------------------------------------------------------------------

def _classify_init(key, cfg: HeadConfig):
    return {}                    # reuses the gateway's CNN cloud-half params


def _classify_forward(cnn_params, head_params, z, cfg: HeadConfig):
    from repro.models.cnn import cnn_cloud
    return cnn_cloud(cnn_params, z)


def _softmax_kl(ref: np.ndarray, out: np.ndarray) -> float:
    """Mean KL(ref || out) of softmaxed logits — the same divergence
    core.split.fidelity_metrics reports for the downstream classifier."""
    ref = np.asarray(ref, np.float64)
    out = np.asarray(out, np.float64)
    ref = ref - ref.max(axis=-1, keepdims=True)
    out = out - out.max(axis=-1, keepdims=True)
    p = np.exp(ref) / np.exp(ref).sum(axis=-1, keepdims=True)
    q = np.exp(out) / np.exp(out).sum(axis=-1, keepdims=True)
    eps = 1e-12
    return float(np.mean(np.sum(p * (np.log(p + eps) - np.log(q + eps)),
                                axis=-1)))


# ---------------------------------------------------------------------------
# detect — encdec-style dense per-cell head
# ---------------------------------------------------------------------------

def _detect_init(key, cfg: HeadConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "proj": nn.init_dense(k1, cfg.split_p, cfg.d_model),
        # one bidirectional encoder block, the encdec _enc_block_init shape
        "ln1": nn.init_layernorm(cfg.d_model, jnp.float32),
        "attn": init_attention(k2, cfg.d_model, cfg.n_heads, cfg.n_heads,
                               cfg.head_dim, qkv_bias=True),
        "ln2": nn.init_layernorm(cfg.d_model, jnp.float32),
        "ffn": init_ffn(k3, cfg.d_model, cfg.d_ff, "gelu", jnp.float32),
        "out": nn.init_dense(k4, cfg.d_model,
                             cfg.box_fields + cfg.num_classes),
    }


def _detect_forward(cnn_params, head_params, z, cfg: HeadConfig):
    b, h, w, _ = z.shape
    x = nn.leaky_relu(z).reshape(b, h * w, z.shape[-1])
    x = nn.dense_apply(head_params["proj"], x)
    attn = attention_apply(
        head_params["attn"], nn.layernorm_apply(head_params["ln1"], x),
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads, head_dim=cfg.head_dim,
        rope_theta=10000.0, causal=False)
    x = x + attn
    x = x + ffn_apply(head_params["ffn"],
                      nn.layernorm_apply(head_params["ln2"], x), "gelu")
    y = nn.dense_apply(head_params["out"], x)
    return y.reshape(b, h, w, cfg.box_fields + cfg.num_classes)


def _normalized_mse(ref: np.ndarray, out: np.ndarray) -> float:
    """MSE of the dense map normalized by reference power (scale-free)."""
    ref = np.asarray(ref, np.float64)
    out = np.asarray(out, np.float64)
    denom = float(np.mean(ref * ref)) + 1e-12
    return float(np.mean((ref - out) ** 2)) / denom


# ---------------------------------------------------------------------------
# embed — lightweight retrieval embedding
# ---------------------------------------------------------------------------

def _embed_init(key, cfg: HeadConfig):
    return {"proj": nn.init_dense(key, cfg.split_p, cfg.embed_dim)}


def _embed_forward(cnn_params, head_params, z, cfg: HeadConfig):
    feat = jnp.mean(nn.leaky_relu(z), axis=(1, 2))          # GAP
    e = nn.dense_apply(head_params["proj"], feat)
    return e / (jnp.linalg.norm(e, axis=-1, keepdims=True) + 1e-8)


def _cosine_distance(ref: np.ndarray, out: np.ndarray) -> float:
    """Mean (1 - cosine) over embedding rows (rows are ~unit-norm)."""
    ref = np.asarray(ref, np.float64)
    out = np.asarray(out, np.float64)
    num = np.sum(ref * out, axis=-1)
    den = (np.linalg.norm(ref, axis=-1) * np.linalg.norm(out, axis=-1)
           + 1e-12)
    return float(np.mean(1.0 - num / den))


register_head(TaskHead(name="classify", init=_classify_init,
                       forward=_classify_forward, divergence=_softmax_kl))
register_head(TaskHead(name="detect", init=_detect_init,
                       forward=_detect_forward, divergence=_normalized_mse))
register_head(TaskHead(name="embed", init=_embed_init,
                       forward=_embed_forward, divergence=_cosine_distance))


# ---------------------------------------------------------------------------
# Banks and jitted forwards
# ---------------------------------------------------------------------------

def init_head_bank(key, cfg: HeadConfig, *, heads=None) -> dict:
    """{name: head_params} for ``heads`` (default: every registered head)."""
    names = tuple(sorted(heads)) if heads is not None else available_heads()
    keys = jax.random.split(key, max(len(names), 2))
    return {name: get_head(name).init(k, cfg)
            for name, k in zip(names, keys)}


@lru_cache(maxsize=None)
def _jitted_head_fn(name: str, cfg: HeadConfig):
    """Process-wide jit cache, one trace per (head, config, input shape) —
    the head analogue of ``core.split._jitted_cnn_fns``."""
    head = get_head(name)
    return jax.jit(lambda p, hp, z: head.forward(p, hp, z, cfg))


def run_heads(cnn_params, head_bank: dict, z, tasks, cfg: HeadConfig) -> dict:
    """Run each requested head once over the (restored) tensor ``z``.

    Returns {task: np.ndarray} with the batch dimension leading; iteration
    is over the sorted task list so output construction is deterministic.
    """
    out = {}
    for task in sorted(set(tasks)):
        y = _jitted_head_fn(task, cfg)(cnn_params, head_bank[task], z)
        out[task] = np.asarray(jax.block_until_ready(y))
    return out
