"""Multi-task serving: one encoded BaF stream, N downstream task heads.

The task layer on top of pipeline + serve (see docs/MULTITASK.md):

  * :mod:`repro.tasks.heads` — the TaskHead registry (classify / detect /
    embed) with jitted forwards over the restored tensor;
  * :mod:`repro.tasks.distortion` — per-task output-divergence RD tables
    (one encode/decode/restore per operating point, head fan-out);
  * :mod:`repro.tasks.allocation` — deterministic bit allocation across a
    tenant's declared task set (degrade-before-shed under pressure);
  * :mod:`repro.tasks.gateway` — MultiTaskGateway: one decode + one restore
    per micro-batch fanned out to every subscribed head.
"""
from repro.tasks.allocation import AllocationDecision, BitAllocationController
from repro.tasks.distortion import (build_task_rd_tables, divergence_to_db,
                                    load_or_build_task_tables, task_set_key,
                                    task_divergences)
from repro.tasks.gateway import MultiTaskGateway, MultiTaskResponse
from repro.tasks.heads import (HeadConfig, TaskHead, available_heads,
                               get_head, init_head_bank, register_head,
                               run_heads)

__all__ = [
    "AllocationDecision", "BitAllocationController",
    "build_task_rd_tables", "divergence_to_db", "load_or_build_task_tables",
    "task_set_key", "task_divergences",
    "MultiTaskGateway", "MultiTaskResponse",
    "HeadConfig", "TaskHead", "available_heads", "get_head",
    "init_head_bank", "register_head", "run_heads",
]
