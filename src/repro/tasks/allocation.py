"""Bit allocation across a tenant's declared task set.

One encoded stream feeds every head, so "allocation" picks the single
operating point whose wire bits cover ALL declared tasks' quality floors —
the weighted-Lagrangian view of Alvar & Bajić 2020 collapsed onto the
shared-stream constraint: the op's cost is paid once, each task prices it
through its own distortion table, and the weight vector decides who is
degraded first when the budget cannot cover everyone.

Selection policy (deterministic, replay-identical):

  1. candidates = operating points present in every declared task's table,
     sorted by (bits, op identity);
  2. among candidates fitting the bit budget, take the CHEAPEST point that
     meets every declared task's quality floor (ties: higher weighted
     quality). Cheapest-first (not budget-filling) makes allocation
     monotone: declaring fewer tasks removes constraints and can never
     cost more bits — the property tenants' billing relies on. (The
     guarantee is for the non-degraded regime — every declared floor
     servable within budget; once relaxation kicks in, a low-weight task
     may be sacrificed entirely, and a larger set that sacrifices it can
     legitimately be cheaper than the small set that must serve it);
  3. under pressure (no fitting point meets all floors), relax floors in
     ascending weight order — the lowest-weight task is degraded first and
     recorded as such, mirroring the session QoS ladder's
     degrade-before-shed shape — and retry;
  4. if every floor has been relaxed, serve best-effort: the fitting point
     with the highest weighted quality (nothing fits at all -> the
     globally cheapest point, never a drop).

The per-task bit attribution splits the chosen point's wire bits across
declared tasks proportionally to weight — an accounting view for telemetry
and billing; the stream itself is shared.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.serve.rate_control import RDPoint


@dataclass(frozen=True)
class AllocationDecision:
    """One deterministic allocation outcome for a declared task set."""
    op: object                               # OperatingPoint
    bits_per_example: float                  # shared-stream wire cost
    per_task_quality_db: tuple               # ((task, quality_db), ...) sorted
    per_task_bits: tuple                     # ((task, attributed bits), ...)
    degraded: tuple                          # tasks whose floor was relaxed,
                                             # in relaxation order

    def quality_db(self, task: str) -> float:
        return dict(self.per_task_quality_db)[task]


def _op_sort_key(op) -> tuple:
    return (op.c, op.bits, op.backend, op.tiling, op.context, op.profile)


class BitAllocationController:
    """Splits a tenant's channel budget across its declared task set.

    tables  : {task: [RDPoint]} from tasks/distortion.py —
              ``psnr_db`` = task quality dB, shared ``bits_per_example``
    weights : {task: weight > 0} (default 1.0) — degrade order and tie-breaks
    floors  : {task: quality floor dB} (default ``default_floor_db``)
    default_floor_db : floor for tasks absent from ``floors``
                       (-inf = no floor: that task never constrains)
    """

    def __init__(self, tables: dict, *, weights: dict | None = None,
                 floors: dict | None = None,
                 default_floor_db: float = -math.inf):
        if not tables:
            raise ValueError("empty task table set")
        self.tables = {t: list(pts) for t, pts in sorted(tables.items())}
        for t, pts in self.tables.items():
            if not pts:
                raise ValueError(f"task {t!r}: empty RD table")
        self.tasks = tuple(sorted(self.tables))
        weights = dict(weights or {})
        for t, w in weights.items():
            if w <= 0:
                raise ValueError(f"task {t!r}: weight must be > 0, got {w}")
        self.weights = {t: float(weights.get(t, 1.0)) for t in self.tasks}
        floors = dict(floors or {})
        self.floors = {t: float(floors.get(t, default_floor_db))
                       for t in self.tasks}
        # op identity -> {task: RDPoint}; only ops every table prices are
        # candidates (an op one task cannot price cannot serve that task)
        by_op: dict[tuple, dict] = {}
        for t in self.tasks:
            for p in self.tables[t]:
                by_op.setdefault(_op_sort_key(p.op), {})[t] = p
        self._by_op = by_op

    def weight(self, task: str) -> float:
        return self.weights[task]

    def floor(self, task: str) -> float:
        return self.floors[task]

    def _declared(self, tasks) -> tuple:
        declared = tuple(sorted(set(tasks)))
        if not declared:
            raise ValueError("empty declared task set")
        unknown = [t for t in declared if t not in self.tables]
        if unknown:
            raise KeyError(f"no RD table for tasks {unknown} "
                           f"(have {list(self.tasks)})")
        return declared

    def _candidates(self, declared) -> list:
        """[(bits, op_key, point_by_task)] sorted by (bits, op identity)."""
        out = []
        for op_key, pts in self._by_op.items():
            if all(t in pts for t in declared):
                bits = max(pts[t].bits_per_example for t in declared)
                out.append((bits, op_key, pts))
        if not out:
            raise ValueError(f"no operating point is priced by every task "
                             f"in {list(declared)}")
        out.sort(key=lambda c: (c[0], c[1]))
        return out

    def _weighted_quality(self, declared, pts) -> float:
        return sum(self.weights[t] * pts[t].psnr_db for t in declared)

    def select(self, tasks, bit_budget: float | None = None
               ) -> AllocationDecision:
        """Deterministic operating-point choice for one declared task set."""
        declared = self._declared(tasks)
        budget = math.inf if bit_budget is None else float(bit_budget)
        cands = self._candidates(declared)
        fitting = [c for c in cands if c[0] <= budget]
        degraded: list = []
        if not fitting:
            # nothing fits: cheapest overall, every unmet floor is degraded
            bits, _, pts = cands[0]
            degraded = [t for t in declared
                        if pts[t].psnr_db < self.floors[t]]
            return self._decision(declared, bits, pts, degraded)
        # degrade-before-shed: relax floors in ascending weight order
        relax_order = sorted(declared, key=lambda t: (self.weights[t], t))
        active = set(declared)
        while True:
            if not active:
                # every floor relaxed: best-effort, not cheapest — the
                # budget is already being paid, spend it on quality
                bits, _, pts = max(
                    fitting,
                    key=lambda c: (self._weighted_quality(declared, c[2]),
                                   -c[0]))
                return self._decision(declared, bits, pts, degraded)
            meeting = [c for c in fitting
                       if all(c[2][t].psnr_db >= self.floors[t]
                              for t in active)]
            if meeting:
                bits, _, pts = min(
                    meeting,
                    key=lambda c: (c[0],
                                   -self._weighted_quality(declared, c[2]),
                                   c[1]))
                return self._decision(declared, bits, pts, degraded)
            drop = next(t for t in relax_order if t in active)
            active.discard(drop)
            degraded.append(drop)

    def _decision(self, declared, bits, pts, degraded) -> AllocationDecision:
        total_w = sum(self.weights[t] for t in declared)
        return AllocationDecision(
            op=pts[declared[0]].op,
            bits_per_example=float(bits),
            per_task_quality_db=tuple((t, float(pts[t].psnr_db))
                                      for t in declared),
            per_task_bits=tuple((t, float(bits) * self.weights[t] / total_w)
                                for t in declared),
            degraded=tuple(degraded))

    def independent_bits(self, tasks, bit_budget: float | None = None
                         ) -> float:
        """Total wire bits if every declared task ran its OWN stream —
        each task independently picks its cheapest floor-meeting point.
        The benchmark's baseline the shared stream must beat."""
        declared = self._declared(tasks)
        return sum(self.select((t,), bit_budget).bits_per_example
                   for t in declared)
