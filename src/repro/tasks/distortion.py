"""Per-task distortion: price each operating point by what it does to each
head's *output*, not by tensor PSNR.

Tensor-level PSNR (serve/rate_control.py's RD tables) treats every restored
value as equally important; a classification head that only consumes the
global average pool is far more robust to quantization than a dense
per-cell detector. Following the multi-task bit-allocation formulation
(Alvar & Bajić 2020), each operating point is swept once — encode /
decode / restore exactly as deployment runs it — and every registered head
runs over the restored tensor; the head's own divergence against its
uncompressed-tensor reference output becomes that task's distortion at that
point.

Task quality is reported in dB (``-10·log10(divergence)``, higher is
better) so the per-task tables reuse :class:`repro.serve.RDPoint` —
``psnr_db`` holds the task quality, ``kl`` the raw divergence — and the
existing controller/serialization machinery applies unchanged.

The disk cache (:func:`load_or_build_task_tables`) is keyed on the ops
grid, :func:`repro.serve.rate_control.codec_revision`, AND the head-set
identity + task-weight vector, so a single-task cache can never be served
to a multi-task caller (and vice versa).
"""
from __future__ import annotations

import json
import math
import os

import jax
import numpy as np

from repro.serve.rate_control import (RDPoint, codec_revision, op_to_json,
                                      rd_table_from_json, rd_table_to_json)
from repro.tasks.heads import HeadConfig, run_heads

TASK_QUALITY_EPS = 1e-12


def divergence_to_db(divergence: float) -> float:
    """Map a head-output divergence (0 = identical) onto a higher-is-better
    dB scale comparable across heads: ``-10·log10(max(d, eps))``."""
    return -10.0 * math.log10(max(float(divergence), TASK_QUALITY_EPS))


def task_divergences(reference: dict, outputs: dict) -> dict:
    """{task: divergence} for every task present in both output dicts."""
    from repro.tasks.heads import get_head
    out = {}
    for task in sorted(set(reference) & set(outputs)):
        out[task] = get_head(task).divergence(reference[task], outputs[task])
    return out


def build_task_rd_tables(params, baf_bank: dict, imgs, *, head_bank: dict,
                         head_cfg: HeadConfig, ops,
                         consolidation: bool = True) -> dict:
    """Sweep ``ops`` once; price every head at every point.

    params    : CNN params (models/cnn.py)
    baf_bank  : {c: (baf_params, sel_idx)} — BaF predictor per C
    imgs      : (B, H, W, 3) calibration batch
    head_bank : {task: head_params} (tasks/heads.init_head_bank)
    ops       : operating-point grid (e.g. serve.rate_control.rd_grid)

    Returns {task: [RDPoint]} where each point's ``bits_per_example`` is the
    measured per-request container bits (identical across tasks — one
    stream feeds all heads) and ``psnr_db``/``kl`` hold the task's quality
    dB / raw output divergence. Each op is encoded, decoded, and restored
    exactly once; the heads fan out from the single restored batch — the
    same one-decode-N-forwards shape the serving gateway runs.
    """
    from repro import pipeline
    from repro.models.cnn import cnn_edge

    edge = jax.jit(lambda p, i: cnn_edge(p, i)[1])
    z = edge(params, np.asarray(imgs))
    tasks = tuple(sorted(head_bank))
    reference = run_heads(params, head_bank, z, tasks, head_cfg)
    specs = {c: pipeline.ModelSpec(sel_idx=np.asarray(sel), params=params,
                                   baf_params=baf)
             for c, (baf, sel) in sorted(baf_bank.items())}
    n = int(np.asarray(imgs).shape[0])
    tables: dict[str, list] = {t: [] for t in tasks}
    for op in ops:
        if op.c not in specs:
            raise ValueError(f"operating point wants C={op.c} but the bank "
                             f"holds {sorted(baf_bank)}")
        plan = pipeline.compile(op, specs[op.c], consolidation=consolidation)
        # deployment granularity: one request = one example = one container
        blobs = [plan.encode(z[i:i + 1]) for i in range(n)]
        per_req_bits = float(np.mean([b.stats.wire_bits for b in blobs]))
        z_tilde = plan.restore(plan.decode_batch(blobs))
        outputs = run_heads(params, head_bank, z_tilde, tasks, head_cfg)
        for task, div in task_divergences(reference, outputs).items():
            tables[task].append(RDPoint(
                op=op, bits_per_example=per_req_bits,
                psnr_db=divergence_to_db(div), kl=float(div)))
    return tables


# ---------------------------------------------------------------------------
# Disk cache (benchmark / CI time budget)
# ---------------------------------------------------------------------------

def task_set_key(head_bank_or_names, weights: dict | None = None) -> dict:
    """JSON-serializable identity of a head set + its task-weight vector —
    the extra cache-key material multi-task sweeps must carry."""
    names = sorted(head_bank_or_names)
    w = dict(weights or {})
    return {"heads": names,
            "weights": [float(w.get(n, 1.0)) for n in names]}


def load_or_build_task_tables(cache_path, key: dict | None = None,
                              build=None, *, ops, tasks: dict) -> dict:
    """Per-task analogue of ``serve.rate_control.load_or_build_rd_table``.

    The effective cache key is ``key`` + the full ``ops`` grid +
    ``codec_revision()`` + ``tasks`` (a :func:`task_set_key` dict: head-set
    identity and weight vector). Any mismatch — including a single-task
    cache on disk where a multi-task sweep is requested — rebuilds in
    place.
    """
    if build is None:
        raise TypeError("load_or_build_task_tables needs a build callable")
    full_key = dict(key or {})
    full_key["ops"] = [op_to_json(p) for p in ops]
    full_key["codec_rev"] = codec_revision()
    full_key["tasks"] = dict(tasks)

    cache_path = os.fspath(cache_path)
    try:
        with open(cache_path) as f:
            data = json.load(f)
        if data.get("key") == full_key:
            return {t: rd_table_from_json(rows)
                    for t, rows in data["tables"].items()}
    except (OSError, ValueError, KeyError, AttributeError, TypeError):
        pass                         # any unusable cache file -> rebuild
    tables = build()
    tmp = cache_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"key": full_key,
                   "tables": {t: rd_table_to_json(rows)
                              for t, rows in sorted(tables.items())}},
                  f, indent=1)
    os.replace(tmp, cache_path)
    return tables
