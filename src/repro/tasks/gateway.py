"""Multi-task serving: one decoded stream fanned out to N task heads.

:class:`MultiTaskGateway` extends the event-driven multi-tenant gateway
(serve/gateway.py) with the task layer:

  * each tenant's ``TenantSpec.tasks`` declaration is negotiated once at
    construction against the gateway's capabilities
    (:func:`repro.pipeline.negotiate_tasks`) — unsupported heads drop (or
    refuse) before any traffic flows;
  * per request, the :class:`repro.tasks.allocation.BitAllocationController`
    picks the operating point covering exactly the tenant's declared task
    set within the scheduler's remaining budget — a classify-only tenant
    never pays detection-grade bits;
  * per micro-batch, ONE ``plan.decode_batch`` + ONE ``plan.restore`` feed
    every head the batch's tenants subscribe to, each head running exactly
    once over the whole restored batch (``decode_calls``/``head_calls``
    counters expose the invariant; the benchmark gates on it);
  * responses are :class:`MultiTaskResponse` — one output row per declared
    task — with per-task telemetry counters
    (``task_requests_total{tenant=,task=}``) and per-head ``head.<task>``
    trace spans on the executor track.

Replay: allocation, negotiation, and head fan-out are all deterministic, so
a repeated workload under a deterministic executor cost model
(``LinearCostModel``) reproduces responses bit-identically.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.pipeline import negotiate_tasks
from repro.serve.batcher import EncodedRequest, MicroBatch
from repro.serve.executor import ExecTicket
from repro.serve.gateway import MultiTenantGateway
from repro.serve.telemetry import Telemetry
from repro.tasks.allocation import BitAllocationController
from repro.tasks.heads import HeadConfig, _jitted_head_fn, get_head


@dataclass
class MultiTaskResponse:
    """One request's fan-out outcome: an output row per declared task."""
    req_id: int
    outputs: dict                 # task -> np.ndarray (this request's row)
    tasks: tuple                  # effective (negotiated) declared task set
    op: object                    # OperatingPoint the stream was coded at
    stats: object                 # SplitStats wire accounting

    @property
    def shed(self) -> bool:       # duck-type discriminator vs RequestShed
        return False

    @property
    def logits(self) -> np.ndarray:
        """Back-compat single-consumer view: the classify row when that head
        was declared, else the first declared task's output."""
        if "classify" in self.outputs:
            return self.outputs["classify"]
        return self.outputs[sorted(self.outputs)[0]]


class MultiTaskGateway(MultiTenantGateway):
    """Event-driven multi-tenant serving where each tenant subscribes to a
    declared subset of the registered task heads.

    Parameters (beyond :class:`MultiTenantGateway`)
    ----------
    head_bank : {task: head_params} (tasks/heads.init_head_bank); its key
        set is the gateway's full head set — a tenant with an empty
        declaration subscribes to all of it
    head_cfg  : HeadConfig the bank was initialized with
    allocator : BitAllocationController splitting each tenant's budget
        across its declared task set (None = the inherited controller /
        default-op path picks the operating point; declarations still
        bound which heads run and which outputs are returned)
    """

    def __init__(self, params, baf_bank: dict, *, tenants, head_bank: dict,
                 head_cfg: HeadConfig,
                 allocator: BitAllocationController | None = None, **kw):
        super().__init__(params, baf_bank, tenants=tenants, **kw)
        if self._run_fn == self._run_batch_mesh:
            raise NotImplementedError(
                "MultiTaskGateway fans the restored batch out to task heads "
                "inline; mesh (run_sharded) executors are not supported")
        if not head_bank:
            raise ValueError("empty head bank")
        for name in head_bank:
            get_head(name)               # unknown head names fail loudly here
        self.head_bank = dict(head_bank)
        self.head_cfg = head_cfg
        self.allocator = allocator
        all_heads = tuple(sorted(head_bank))
        if allocator is not None:
            missing = [t for t in all_heads if t not in allocator.tables]
            if missing:
                raise ValueError(f"allocator has no RD table for heads "
                                 f"{missing}")
        self.task_sets: dict[str, tuple] = {}
        for spec in self.specs.values():
            declared = spec.tasks if spec.tasks else all_heads
            unknown = [t for t in declared if t not in head_bank]
            if unknown:
                raise ValueError(f"tenant {spec.name!r} declares tasks "
                                 f"{unknown} with no head in the bank "
                                 f"{list(all_heads)}")
            self.task_sets[spec.name] = negotiate_tasks(declared,
                                                        self.capabilities)
        # "" is the single-tenant sentinel (ServingGateway.serve): full set
        self.task_sets[""] = negotiate_tasks(all_heads, self.capabilities)
        # one-decode-fan-out invariant counters (benchmarks gate on these)
        self.decode_calls = 0
        self.head_calls: dict[str, int] = {}

    def _tasks_for(self, tenant: str) -> tuple:
        return self.task_sets[tenant]

    # -- edge side ----------------------------------------------------------
    def _pick_tenant_op(self, spec, z, budget):
        if self.allocator is None:
            return super()._pick_tenant_op(spec, z, budget)
        decision = self.allocator.select(self._tasks_for(spec.name), budget)
        return self._fit_op(decision.op)

    # -- cloud side ---------------------------------------------------------
    def _run_batch(self, batch: MicroBatch):
        """ONE decode + ONE restore; every subscribed head runs once over
        the whole restored batch. Returns ({task: outputs}, wall_s)."""
        plan = self.plan_for(batch.key.op)
        # repro: allow[RA01] -- warm-timing helper: measures real compute
        # wall for MeasuredCost models; feeds telemetry, never the clock
        t0 = time.perf_counter()
        decoded = plan.decode_batch([r.blob for r in batch.requests])
        z_tilde = plan.restore(decoded.pad_to(batch.padded_size))
        needed = sorted({t for r in batch.requests
                         for t in self._tasks_for(r.tenant)})
        outputs = {}
        for task in needed:
            y = _jitted_head_fn(task, self.head_cfg)(
                self.params, self.head_bank[task], z_tilde)
            outputs[task] = np.asarray(jax.block_until_ready(y))
        self.decode_calls += 1
        for task in needed:
            self.head_calls[task] = self.head_calls.get(task, 0) + 1
        # repro: allow[RA01] -- warm-timing helper (see t0 above)
        return outputs, time.perf_counter() - t0

    # -- response fan-out ---------------------------------------------------
    def _response_for(self, req: EncodedRequest, ticket: ExecTicket,
                      row: int, op, stats) -> MultiTaskResponse:
        tasks = self._tasks_for(req.tenant)
        return MultiTaskResponse(
            req_id=req.req_id,
            outputs={t: ticket.logits[t][row] for t in tasks},
            tasks=tasks, op=op, stats=stats)

    def _exec_batch_spans(self, tracer, ticket: ExecTicket) -> None:
        super()._exec_batch_spans(tracer, ticket)
        for task in sorted(ticket.logits):
            tracer.span(f"head.{task}", ticket.t_start, ticket.t_done,
                        track=f"exec-q{ticket.queue}", seq=ticket.seq,
                        task=task, n_requests=len(ticket.batch.requests))

    def _post_record(self, req: EncodedRequest, out,
                     telemetry: Telemetry) -> None:
        for task in out.tasks:
            telemetry.metrics.counter("task_requests_total",
                                      tenant=req.tenant, task=task).inc()
