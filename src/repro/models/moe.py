"""Mixture-of-Experts layer: top-k routing, fixed expert capacity, gather-based
dispatch/combine (production formulation — no (T,E,C) one-hot is ever
materialized, so olmoe's 64-expert and arctic's 128-expert layers shard as
(experts -> model axis) with per-group buffers of (E, C, D)).

Dispatch:  per group (one batch row), tokens pick top-k experts; each expert
           keeps its first C tokens (capacity), the rest are dropped (standard
           GShard-style dropping). An (E, C) token-index table is built by
           scatter, expert inputs by gather.
Combine:   each token gathers its k expert outputs back (dropped slots hit a
           zero pad row) and sums them weighted by the renormalized gates.
Aux loss:  switch-style load-balance loss, returned for the trainer.

Arctic's dense-residual variant runs a parallel dense FFN over the same input
and adds it to the MoE output (Snowflake Arctic "dense-MoE hybrid").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import MoEConfig
from repro.distributed import shard_hidden
from repro.models.ffn import ffn_apply, init_ffn


def init_moe(key, d_model: int, d_ff: int, mcfg: MoEConfig, act: str,
             dtype=jnp.float32):
    kr, kg, ku, kd, kdr = jax.random.split(key, 5)
    e, f = mcfg.num_experts, mcfg.d_ff_expert
    p = {
        "router": nn.normal(kr, (d_model, e), 0.02, dtype),
        "wup": nn.normal(ku, (e, d_model, f), 0.02, dtype),
        "wdown": nn.normal(kd, (e, f, d_model), 0.02, dtype),
    }
    if act == "swiglu":
        p["wgate"] = nn.normal(kg, (e, d_model, f), 0.02, dtype)
    if mcfg.dense_residual:
        p["dense"] = init_ffn(kdr, d_model, d_ff, act, dtype)
    return p


def capacity(tokens_per_group: int, mcfg: MoEConfig) -> int:
    c = int(mcfg.top_k * tokens_per_group / mcfg.num_experts * mcfg.capacity_factor)
    return max(8, -(-c // 8) * 8)   # round up to a multiple of 8, floor 8


def _route_one_group(x, p, mcfg: MoEConfig, act: str, dtype):
    """x: (T, D) one group. Returns (y (T, D), aux_loss scalar)."""
    t, d = x.shape
    e, k = mcfg.num_experts, mcfg.top_k
    c = capacity(t, mcfg)

    logits = (x @ p["router"].astype(dtype)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                          # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert, GShard slot ordering
    counts = jnp.zeros((e,), jnp.int32)
    token_for = jnp.full((e, c + 1), t, jnp.int32)   # sentinel t -> zero row
    slot_pos = []
    for j in range(k):
        oh = jax.nn.one_hot(top_e[:, j], e, dtype=jnp.int32)        # (T, E)
        pos_in = jnp.cumsum(oh, axis=0) - 1 + counts[None, :]       # (T, E)
        pos_j = jnp.sum(pos_in * oh, axis=1)                        # (T,)
        counts = counts + jnp.sum(oh, axis=0)
        pos_j = jnp.where(pos_j < c, pos_j, c)                      # overflow -> pad
        token_for = token_for.at[top_e[:, j], pos_j].set(jnp.arange(t),
                                                         mode="drop")
        slot_pos.append(pos_j)
    slot_pos = jnp.stack(slot_pos, axis=1)                          # (T, k)
    # the pad column may have been overwritten by dropped tokens; restore it
    token_for = token_for.at[:, c].set(t)

    # dispatch: gather expert inputs (E, C, D).
    # NOTE (§Perf HC3, refuted): forcing expert-parallel sharding constraints
    # here (xe/up/ye -> experts on the model axis) HALVED the bwd all-reduce
    # but exploded the all-gather (1.4e10 -> 5.3e11 B) and 5x'd compute — XLA
    # then gathers the batch-sharded dispatch indices. Measured worse; the
    # real fix is a shard_map all-to-all token dispatch (future work).
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[token_for[:, :c]]                                     # (E, C, D)

    # expert FFN
    up = jnp.einsum("ecd,edf->ecf", xe, p["wup"].astype(dtype))
    if act == "swiglu":
        gate = jnp.einsum("ecd,edf->ecf", xe, p["wgate"].astype(dtype))
        h = jax.nn.silu(gate) * up
    elif act == "gelu":
        h = jax.nn.gelu(up, approximate=True)
    else:
        h = nn.squared_relu(up)
    ye = jnp.einsum("ecf,efd->ecd", h, p["wdown"].astype(dtype))     # (E, C, D)

    # combine: each token fetches its k outputs
    ye_pad = jnp.concatenate([ye, jnp.zeros((e, 1, d), ye.dtype)], axis=1)
    fetched = ye_pad[top_e, slot_pos]                                # (T, k, D)
    y = jnp.sum(fetched * top_p[..., None].astype(ye.dtype), axis=1)

    # switch load-balance aux loss
    frac_tokens = jnp.mean(jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32), 0)
    mean_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * mean_probs)
    return y, aux


def moe_apply(p, x, mcfg: MoEConfig, act: str, d_ff: int, *, dtype=None):
    """x: (B, S, D) — each batch row is a routing group. Returns (y, aux)."""
    dtype = dtype or x.dtype
    y, aux = jax.vmap(lambda xr: _route_one_group(xr, p, mcfg, act, dtype))(x)
    y = shard_hidden(y, "batch", None, None)
    if mcfg.dense_residual:
        y = y + ffn_apply(p["dense"], x, act, dtype=dtype)
    return y, jnp.mean(aux)
