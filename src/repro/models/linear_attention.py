"""Chunked linear-attention / SSM scan — the shared sub-quadratic engine for
RWKV-6 (per-channel data-dependent decay + bonus) and Mamba-2 (scalar
per-head decay). TPU adaptation of the CUDA recurrences (DESIGN.md §4):
intra-chunk terms are MXU matmuls, the inter-chunk state is carried through a
lax.scan — O(S) time, O(chunk^2) score blocks.

Recurrence (state S_t: (dk, dv) per head):
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
    rwkv mode:  y_t = q_t·S_{t-1} + (q_t ⊙ u ⊙ k_t)·v_t      (bonus u)
    ssm  mode:  y_t = q_t·S_t                                  (self included)

Numerical strategy: within a chunk the decay factorization
exp(la_t - la_i) = exp(la_t)·exp(-la_i) can overflow when cumulative log-decay
is large, so ``chunk`` defaults small enough that |sum log w| stays < 80 with
log-decay clamped to >= LOG_DECAY_MIN; Mamba-2's scalar decay instead uses the
exact pairwise-difference matrix (always <= 0 exponents). The Pallas kernel
(kernels/linear_scan.py) mirrors the same math with two-level blocking.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

LOG_DECAY_MIN = -4.0   # clamp: e^{|min|*chunk} must stay inside fp32


def _chunk(x, n):
    """(B, S, ...) -> (B, S//n, n, ...)."""
    b, s = x.shape[:2]
    return x.reshape(b, s // n, n, *x.shape[2:])


# Scan backend: 'jnp' (this module) or 'pallas' (kernels/linear_scan.py,
# the TPU hot path). Auto-selects pallas on TPU; override via set_backend().
_BACKEND = None


def set_backend(name: Optional[str]):
    global _BACKEND
    _BACKEND = name


def _backend() -> str:
    if _BACKEND is not None:
        return _BACKEND
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def chunked_linear_attention(q, k, v, log_decay, *, bonus: Optional[jax.Array] = None,
                             chunk: int = 16, initial_state=None,
                             per_channel: bool = True, mode: str = "rwkv"):
    """q,k: (B,S,H,dk)  v: (B,S,H,dv)  log_decay: (B,S,H,dk) or (B,S,H,1).

    bonus: (H, dk) rwkv-6 current-token bonus ``u`` (mode='rwkv' only).
    Returns (y: (B,S,H,dv), final_state: (B,H,dk,dv)).
    """
    if _backend() == "pallas":
        from repro.kernels.ops import linear_scan
        return linear_scan(q, k, v, log_decay, bonus=bonus,
                           initial_state=initial_state, chunk=chunk, mode=mode)
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    ld = jnp.clip(log_decay.astype(f32), LOG_DECAY_MIN, -1e-9)

    qc, kc, vc, ldc = (_chunk(t, chunk) for t in (q, k, v, ld))
    # -> (B, NC, L, H, *); reorder to (NC, B, H, L, *) for the scan
    def perm(t):
        return jnp.transpose(t, (1, 0, 3, 2, 4))
    qc, kc, vc, ldc = perm(qc), perm(kc), perm(vc), perm(ldc)
    nc = qc.shape[0]

    la = jnp.cumsum(ldc, axis=-2)                    # inclusive cum-log-decay
    la_prev = la - ldc                               # exclusive
    la_end = la[..., -1:, :]                         # (..., 1, dk|1)

    # q-side decays: exclusive for rwkv (uses S_{t-1}), inclusive for ssm
    la_q = la_prev if mode == "rwkv" else la
    qd = qc * jnp.exp(la_q)                          # (NC,B,H,L,dk)
    kd = kc * jnp.exp(-la)                           # safe: |la| bounded by clamp*chunk
    k_rem = kc * jnp.exp(la_end - la)                # decay from i to chunk end

    # intra-chunk scores; strict lower-triangular for rwkv, inclusive for ssm
    tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1 if mode == "rwkv" else 0)
    scores = jnp.einsum("cbhtd,cbhsd->cbhts", qd, kd) * tri
    y_intra = jnp.einsum("cbhts,cbhsv->cbhtv", scores, vc)

    if mode == "rwkv" and bonus is not None:
        bq = jnp.einsum("cbhtd,hd,cbhtd->cbht", qc, bonus.astype(f32), kc)
        y_intra = y_intra + bq[..., None] * vc

    if initial_state is None:
        s0 = jnp.zeros((b, h, dk, dv), f32)
    else:
        s0 = initial_state.astype(f32)

    def body(state, inp):
        qd_i, k_rem_i, v_i, la_end_i = inp
        y_inter = jnp.einsum("bhtd,bhdv->bhtv", qd_i, state)
        new_state = jnp.exp(la_end_i[..., 0, :])[..., None] * state \
            + jnp.einsum("bhtd,bhtv->bhdv", k_rem_i, v_i)
        return new_state, y_inter

    final_state, y_inter = jax.lax.scan(body, s0, (qd, k_rem, vc, la_end))
    y = y_intra + y_inter                            # (NC,B,H,L,dv)
    y = jnp.transpose(y, (1, 0, 3, 2, 4)).reshape(b, s, h, dv)
    return y, final_state


def linear_attention_step(q, k, v, log_decay, state, *, bonus=None,
                          mode: str = "rwkv"):
    """Single-token recurrent step for decode. q,k: (B,H,dk), v: (B,H,dv),
    log_decay: (B,H,dk) or (B,H,1), state: (B,H,dk,dv)."""
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    w = jnp.exp(jnp.clip(log_decay.astype(f32), LOG_DECAY_MIN, -1e-9))
    kv = k[..., :, None] * v[..., None, :]           # (B,H,dk,dv)
    if mode == "rwkv":
        y = jnp.einsum("bhd,bhdv->bhv", q, state)
        if bonus is not None:
            y = y + jnp.einsum("bhd,hd,bhd->bh", q, bonus.astype(f32), k)[..., None] * v
        new_state = w[..., None] * state + kv
    else:
        new_state = w[..., None] * state + kv
        y = jnp.einsum("bhd,bhdv->bhv", q, new_state)
    return y, new_state


def reference_scan(q, k, v, log_decay, *, bonus=None, initial_state=None,
                   mode: str = "rwkv"):
    """O(S) pure recurrent oracle (used by tests to validate the chunked path
    and the Pallas kernel)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    state = (jnp.zeros((b, h, dk, dv), jnp.float32) if initial_state is None
             else initial_state.astype(jnp.float32))

    def body(state, inp):
        qi, ki, vi, ldi = inp
        y, state = linear_attention_step(qi, ki, vi, ldi, state,
                                         bonus=bonus, mode=mode)
        return state, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, log_decay))
    state, ys = jax.lax.scan(body, state, xs)
    return jnp.moveaxis(ys, 0, 1), state
