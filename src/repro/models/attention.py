"""GQA attention with RoPE: train/prefill (full or windowed causal) and
single-token decode against a KV cache.

Pure-jnp einsum formulation — pjit/SPMD shards it via the logical-axis
annotations; the Pallas flash kernel (kernels/flash_attention.py) is the TPU
hot path and is validated against this code.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import nn
from repro.distributed import shard_hidden


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, positions: jax.Array) -> tuple:
    """positions: (..., S) int32 -> cos/sin of shape (..., S, head_dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) or (S, hd//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias=False, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": nn.normal(kq, (d_model, n_heads * head_dim), 0.02, dtype),
        "wk": nn.normal(kk, (d_model, n_kv_heads * head_dim), 0.02, dtype),
        "wv": nn.normal(kv, (d_model, n_kv_heads * head_dim), 0.02, dtype),
        "wo": nn.normal(ko, (n_heads * head_dim, d_model), 0.02, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def _project_qkv(p, x, n_heads, n_kv_heads, head_dim, dtype):
    b, s, _ = x.shape
    q = x @ p["wq"].astype(dtype)
    k = x @ p["wk"].astype(dtype)
    v = x @ p["wv"].astype(dtype)
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = q.reshape(b, s, n_heads, head_dim)
    k = k.reshape(b, s, n_kv_heads, head_dim)
    v = v.reshape(b, s, n_kv_heads, head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------
#
# KV heads are REPEATED to the full head count before the score matmul: the
# grouped-einsum alternative reshapes H into (kv, group), and neither factor
# is divisible by a 16-way model axis for kv<16 archs — repetition keeps the
# head dim shardable (the repeat is itself sharded, so per-chip cost is
# h_local x S x hd). Scores are computed q-block by q-block (lax.scan) so the
# fp32 score buffer is O(q_block x S) per head shard, never O(S^2) — the
# pure-jnp analogue of flash attention's tiling (the Pallas kernel does the
# same with VMEM blocks).

def repeat_kv(k, h: int):
    """(B, S, K, hd) -> (B, S, H, hd) by repeating each kv head H/K times."""
    b, s, kh, hd = k.shape
    if kh == h:
        return k
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, h // kh, hd))
    return k.reshape(b, s, h, hd)


def blocked_attention(q, k, v, *, causal: bool, q_offset=0,
                      window: Optional[int] = None, q_block: int = 1024):
    """q: (B,Sq,H,hd), k/v: (B,Sk,H,hd) (kv already repeated). fp32 softmax,
    scanned over q blocks. ``window``: band mask (each query sees the previous
    ``window`` keys inclusive)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    bq = min(q_block, sq)
    nb = sq // bq
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kpos = jnp.arange(sk)

    def one_block(start):
        qb = jax.lax.dynamic_slice_in_dim(q, start, bq, axis=1).astype(jnp.float32)
        scores = jnp.einsum("bqhd,bshd->bhqs", qb, kf) * scale
        if causal or window is not None:
            qpos = start + q_offset + jnp.arange(bq)
            mask = jnp.ones((bq, sk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            scores = jnp.where(mask[None, None], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqs,bshd->bqhd", probs, vf).astype(q.dtype)

    if nb == 1:
        return one_block(0)
    outs = jax.lax.map(one_block, jnp.arange(nb) * bq)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


def gqa_scores_softmax_v(q, k, v, *, causal: bool, q_offset=0):
    """Back-compat wrapper: repeats kv heads then runs blocked attention."""
    h = q.shape[2]
    return blocked_attention(q, repeat_kv(k, h), repeat_kv(v, h),
                             causal=causal, q_offset=q_offset)


def windowed_attention(q, k, v, window: int):
    """Banded causal attention: each position attends to the previous
    ``window`` positions (inclusive of itself). Chunked so the score matrix is
    O(S * 2W) instead of O(S^2) — the long-context path for hybrid archs.

    Requires S % window == 0.
    """
    b, s, h, hd = q.shape
    _, _, kh, _ = k.shape
    g = h // kh
    w = window
    nc = s // w
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qc = q.reshape(b, nc, w, h, hd)
    kc = k.reshape(b, nc, w, kh, hd)
    vc = v.reshape(b, nc, w, kh, hd)
    # keys for chunk i: chunk i-1 ++ chunk i  (zero-pad chunk -1)
    k_prev = jnp.pad(kc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    v_prev = jnp.pad(vc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    k2 = jnp.concatenate([k_prev, kc], axis=2)          # (B,nc,2W,K,hd)
    v2 = jnp.concatenate([v_prev, vc], axis=2)
    qg = qc.reshape(b, nc, w, kh, g, hd)
    scores = jnp.einsum("bnqkgh,bnskh->bnkgqs", qg.astype(jnp.float32),
                        k2.astype(jnp.float32)) * scale
    qpos = jnp.arange(w)[:, None] + w                    # position within 2W frame
    kpos = jnp.arange(2 * w)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - w)
    # chunk 0 has no previous chunk: padded keys are masked by position anyway
    first = (jnp.arange(nc) == 0)[None, :, None, None, None, None]
    valid = jnp.where(first, mask[None, None, None, None] & (kpos >= w)[None, None, None, None],
                      mask[None, None, None, None])
    scores = jnp.where(valid, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnkgqs,bnskh->bnqkgh", probs, v2.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

# Attention backend: 'jnp' (blocked_attention — what the CPU dry-run lowers)
# or 'pallas' (kernels/flash_attention.py — the TPU hot path; interpret-mode
# on CPU). Auto-selects pallas on TPU backends; override via set_backend().
_BACKEND = None


def set_backend(name: Optional[str]):
    """'jnp' | 'pallas' | None (auto: pallas on TPU, jnp elsewhere)."""
    global _BACKEND
    _BACKEND = name


def _backend() -> str:
    if _BACKEND is not None:
        return _BACKEND
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def attention_apply(p, x, *, n_heads, n_kv_heads, head_dim, rope_theta,
                    positions=None, causal=True, window: Optional[int] = None,
                    kv_override=None, dtype=None):
    """Train/prefill attention. ``kv_override=(k_src)`` -> cross-attention."""
    dtype = dtype or x.dtype
    b, s, _ = x.shape
    if kv_override is None:
        q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, dtype)
        if positions is None:
            positions = jnp.arange(s)
        cos, sin = rope_freqs(head_dim, rope_theta, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    else:
        # cross-attention: queries from x, keys/values from encoder output
        src = kv_override
        q = (x @ p["wq"].astype(dtype)).reshape(b, s, n_heads, head_dim)
        k = (src @ p["wk"].astype(dtype)).reshape(b, src.shape[1], n_kv_heads, head_dim)
        v = (src @ p["wv"].astype(dtype)).reshape(b, src.shape[1], n_kv_heads, head_dim)
        causal = False
    q = shard_hidden(q, "batch", None, "heads", None)
    k = repeat_kv(k, n_heads)
    v = repeat_kv(v, n_heads)
    k = shard_hidden(k, "batch", None, "heads", None)
    v = shard_hidden(v, "batch", None, "heads", None)
    if _backend() == "pallas" and q.shape[1] % 128 == 0 \
            and k.shape[1] % 128 == 0:
        from repro.kernels.flash_attention import flash_attention_pallas
        bq, sq, h, hd = q.shape
        qf = q.transpose(0, 2, 1, 3).reshape(bq * h, sq, hd)
        kf = k.transpose(0, 2, 1, 3).reshape(bq * h, k.shape[1], hd)
        vf = v.transpose(0, 2, 1, 3).reshape(bq * h, v.shape[1], hd)
        o = flash_attention_pallas(qf, kf, vf, causal=causal, window=window)
        out = o.reshape(bq, h, sq, hd).transpose(0, 2, 1, 3)
    else:
        out = blocked_attention(q, k, v, causal=causal, window=window)
    y = out.reshape(b, s, n_heads * head_dim) @ p["wo"].astype(dtype)
    return y


class KVCache(NamedTuple):
    k: jax.Array           # (B, S_max, K, hd)
    v: jax.Array
    length: jax.Array      # () int32 — tokens currently in cache


def init_kv_cache(batch, max_len, n_kv_heads, head_dim, dtype=jnp.bfloat16):
    shape = (batch, max_len, n_kv_heads, head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.zeros((), jnp.int32))


def attention_decode(p, x, cache: KVCache, *, n_heads, n_kv_heads, head_dim,
                     rope_theta, dtype=None):
    """One-token decode: x (B, 1, D) against a KV cache.

    The softmax reductions run over the (possibly mesh-sharded) cache sequence
    dim; under SPMD that lowers to partial reduce + all-reduce — the
    flash-decode combine emerges from the sharding annotations.
    """
    dtype = dtype or x.dtype
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, dtype)
    pos = cache.length[None]
    cos, sin = rope_freqs(head_dim, rope_theta, pos)      # (1, hd/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    from repro.distributed import current_flash_decode
    fd = current_flash_decode()
    if fd is not None:
        # shard_map flash-decode: local cache update + partial-softmax merge —
        # the sequence-sharded cache never leaves its chips (§Perf HC2).
        from repro.distributed.collectives import seq_sharded_decode_attention
        out, nk, nv = seq_sharded_decode_attention(
            q[:, 0], cache.k, cache.v, k[:, 0], v[:, 0], cache.length,
            fd.mesh, axis=fd.axis, batch_spec=fd.batch_spec)
        y = out.astype(dtype)[:, None, :] @ p["wo"].astype(dtype)
        return y, KVCache(k=nk, v=nv, length=cache.length + 1)

    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
    s_max = cache.k.shape[1]
    g = n_heads // n_kv_heads
    qg = q.reshape(b, 1, n_kv_heads, g, head_dim)
    scale = 1.0 / jnp.sqrt(head_dim).astype(jnp.float32)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        new_k.astype(jnp.float32)) * scale
    valid = jnp.arange(s_max)[None] <= cache.length
    scores = jnp.where(valid[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, new_v.astype(jnp.float32))
    out = out.reshape(b, 1, n_heads * head_dim).astype(dtype)
    y = out @ p["wo"].astype(dtype)
    return y, KVCache(k=new_k, v=new_v, length=cache.length + 1)
