"""Whisper-style encoder-decoder backbone (whisper-tiny).

Per the assignment the conv audio frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, S_enc, D) from input_specs(). Encoder blocks
are bidirectional LayerNorm attention + GELU FFN; decoder blocks are causal
self-attention + cross-attention + FFN. Decode carries self-attn KV caches and
precomputed cross-attn K/V.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ArchConfig
from repro.distributed import shard_hidden
from repro.models.attention import (KVCache, attention_apply, attention_decode,
                                    init_attention, init_kv_cache)
from repro.models.ffn import ffn_apply, init_ffn


def _enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": nn.init_layernorm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, qkv_bias=True, dtype=dtype),
        "ln2": nn.init_layernorm(cfg.d_model, dtype),
        "ffn": init_ffn(k2, cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def _dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = _enc_block_init(key, cfg, dtype)
    p["ln_x"] = nn.init_layernorm(cfg.d_model, dtype)
    p["xattn"] = init_attention(k3, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, qkv_bias=True, dtype=dtype)
    return p


def init_encdec(key, cfg: ArchConfig):
    dtype = cfg.param_dtype
    ed = cfg.encdec
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], ed.enc_layers)
    dec_keys = jax.random.split(ks[1], ed.dec_layers)
    return {
        "enc_layers": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(enc_keys),
        "enc_norm": nn.init_layernorm(cfg.d_model, dtype),
        "dec_embed": nn.normal(ks[2], (cfg.vocab, cfg.d_model), 0.02, dtype),
        "dec_pos": nn.normal(ks[3], (8192, cfg.d_model), 0.02, dtype),
        "dec_layers": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(dec_keys),
        "dec_norm": nn.init_layernorm(cfg.d_model, dtype),
        # whisper ties the output head to the decoder embedding
    }


def _attn(cfg, p, x, **kw):
    return attention_apply(p, x, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                           head_dim=cfg.hd, rope_theta=cfg.rope_theta, **kw)


def encode(params, cfg: ArchConfig, audio_embeds):
    """audio_embeds: (B, S_enc, D) precomputed frame embeddings (stub frontend)."""
    x = audio_embeds.astype(cfg.dtype)
    x = shard_hidden(x, "batch", None, "act_hidden")

    def body(carry, lp):
        h = _attn(cfg, lp["attn"], nn.layernorm_apply(lp["ln1"], carry),
                  causal=False, dtype=cfg.dtype)
        carry = carry + h
        carry = carry + ffn_apply(lp["ffn"], nn.layernorm_apply(lp["ln2"], carry),
                                  "gelu", dtype=cfg.dtype)
        return shard_hidden(carry, "batch", None, "act_hidden"), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return nn.layernorm_apply(params["enc_norm"], x)


def decode_train(params, cfg: ArchConfig, tokens, enc_out):
    """Teacher-forced decoder pass. tokens: (B, S_dec)."""
    b, s = tokens.shape
    pos = params["dec_pos"]
    if s > pos.shape[0]:   # mechanical long-shape support: tile the table
        reps = -(-s // pos.shape[0])
        pos = jnp.tile(pos, (reps, 1))
    x = params["dec_embed"][tokens].astype(cfg.dtype) \
        + pos[:s][None].astype(cfg.dtype)
    x = shard_hidden(x, "batch", None, "act_hidden")

    def body(carry, lp):
        h = _attn(cfg, lp["attn"], nn.layernorm_apply(lp["ln1"], carry),
                  causal=True, dtype=cfg.dtype)
        carry = carry + h
        hx = _attn(cfg, lp["xattn"], nn.layernorm_apply(lp["ln_x"], carry),
                   kv_override=enc_out, dtype=cfg.dtype)
        carry = carry + hx
        carry = carry + ffn_apply(lp["ffn"], nn.layernorm_apply(lp["ln2"], carry),
                                  "gelu", dtype=cfg.dtype)
        return shard_hidden(carry, "batch", None, "act_hidden"), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = nn.layernorm_apply(params["dec_norm"], x)
    logits = x @ params["dec_embed"].T.astype(cfg.dtype)
    return shard_hidden(logits, "batch", None, "vocab")


def encdec_loss(params, cfg: ArchConfig, batch):
    from repro.models.lm import xent_loss
    enc_out = encode(params, cfg, batch["audio_embeds"])
    logits = decode_train(params, cfg, batch["tokens"], enc_out)
    return xent_loss(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

class EncDecCache(NamedTuple):
    self_kv: Any           # stacked (L_dec, ...) KVCache
    cross_k: jax.Array     # (L_dec, B, S_enc, K, hd) precomputed
    cross_v: jax.Array
    pos: jax.Array         # () int32


def init_encdec_cache(params, cfg: ArchConfig, enc_out, max_len: int):
    """Precompute cross-attn K/V from encoder output; empty self-KV caches."""
    b = enc_out.shape[0]
    kv = init_kv_cache(b, max_len, cfg.n_kv_heads, cfg.hd, cfg.dtype)
    ld = cfg.encdec.dec_layers

    def cross_kv(lp):
        src = enc_out.astype(cfg.dtype)
        k = (src @ lp["xattn"]["wk"].astype(cfg.dtype))
        v = (src @ lp["xattn"]["wv"].astype(cfg.dtype))
        if "bk" in lp["xattn"]:
            k = k + lp["xattn"]["bk"].astype(cfg.dtype)
            v = v + lp["xattn"]["bv"].astype(cfg.dtype)
        s = src.shape[1]
        return (k.reshape(b, s, cfg.n_kv_heads, cfg.hd),
                v.reshape(b, s, cfg.n_kv_heads, cfg.hd))

    ck, cv = jax.vmap(cross_kv)(params["dec_layers"])
    return EncDecCache(
        self_kv=jax.tree.map(lambda a: jnp.broadcast_to(a[None], (ld,) + a.shape), kv),
        cross_k=ck, cross_v=cv, pos=jnp.zeros((), jnp.int32))


def encdec_decode_step(params, cfg: ArchConfig, cache: EncDecCache, token):
    """One decoder token against self-KV caches + fixed cross K/V."""
    dtype = cfg.dtype
    b = token.shape[0]
    x = params["dec_embed"][token].astype(dtype) \
        + params["dec_pos"][cache.pos % params["dec_pos"].shape[0]].astype(dtype)

    def body(carry, lp_kv_ck_cv):
        lp, kv, ck, cv = lp_kv_ck_cv
        xs = carry[:, None, :]
        h, new_kv = attention_decode(lp["attn"], nn.layernorm_apply(lp["ln1"], xs),
                                     kv, n_heads=cfg.n_heads,
                                     n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                                     rope_theta=cfg.rope_theta, dtype=dtype)
        carry = carry + h[:, 0]
        # cross attention against precomputed K/V (no cache update)
        xn = nn.layernorm_apply(lp["ln_x"], carry[:, None, :])
        q = (xn @ lp["xattn"]["wq"].astype(dtype))
        if "bq" in lp["xattn"]:
            q = q + lp["xattn"]["bq"].astype(dtype)
        q = q.reshape(b, 1, cfg.n_heads, cfg.hd)
        g = cfg.n_heads // cfg.n_kv_heads
        qg = q.reshape(b, 1, cfg.n_kv_heads, g, cfg.hd)
        sc = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) / jnp.sqrt(cfg.hd)
        pr = jax.nn.softmax(sc, axis=-1)
        hx = jnp.einsum("bkgqs,bskh->bqkgh", pr, cv.astype(jnp.float32))
        hx = hx.reshape(b, 1, cfg.n_heads * cfg.hd).astype(dtype) \
            @ lp["xattn"]["wo"].astype(dtype)
        carry = carry + hx[:, 0]
        y = ffn_apply(lp["ffn"], nn.layernorm_apply(lp["ln2"], carry[:, None, :]),
                      "gelu", dtype=dtype)[:, 0]
        return carry + y, new_kv

    x, new_kv = jax.lax.scan(body, x, (params["dec_layers"], cache.self_kv,
                                       cache.cross_k, cache.cross_v))
    x = nn.layernorm_apply(params["dec_norm"], x[:, None, :])
    logits = (x @ params["dec_embed"].T.astype(dtype))[:, 0]
    return logits, cache._replace(self_kv=new_kv, pos=cache.pos + 1)
