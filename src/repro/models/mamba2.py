"""Mamba-2 (SSD) block — the state-space layer of zamba2 (arXiv:2411.15242).

in_proj -> [z gate | x | B | C | dt]; short causal depthwise conv over
(x,B,C); scalar-per-head decay a_t = exp(-softplus(A_log)·dt_t); SSD core via
the shared chunked linear-attention engine (mode='ssm': C as q, B as k,
dt-scaled x as v); skip D·x; gated RMSNorm; out_proj.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.distributed import shard_hidden
from repro.models.linear_attention import (chunked_linear_attention,
                                           linear_attention_step)


def init_mamba2_block(key, d_model: int, *, state_dim: int = 64,
                      head_dim: int = 64, expand: int = 2, conv_width: int = 4,
                      dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_ch = d_inner + 2 * state_dim          # x, B, C share the conv
    ks = iter(jax.random.split(key, 8))
    proj_out = 2 * d_inner + 2 * state_dim + n_heads
    return {
        "norm": nn.init_rmsnorm(d_model, dtype),
        "in_proj": nn.normal(next(ks), (d_model, proj_out), 0.02, dtype),
        "conv_w": nn.normal(next(ks), (conv_width, conv_ch), 0.1, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((n_heads,), dtype),             # softplus -> ~0.69
        "dt_bias": jnp.full((n_heads,), -2.0, dtype),
        "D": jnp.ones((n_heads,), dtype),
        "gate_norm": nn.init_rmsnorm(d_inner, dtype),
        "out_proj": nn.normal(next(ks), (d_inner, d_model), 0.02, dtype),
    }


def _split_proj(p, xn, d_model, state_dim, head_dim, expand, dtype):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    proj = xn @ p["in_proj"].astype(dtype)
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * state_dim], axis=-1)
    return z, xbc, dt, d_inner, n_heads


def _causal_depthwise_conv(xbc, w, b, *, carry=None):
    """xbc: (B, S, C); w: (K, C). Causal depthwise conv, SiLU activation.

    carry: (B, K-1, C) previous inputs for decode-style continuation."""
    kw = w.shape[0]
    pad = carry if carry is not None else jnp.zeros(
        (xbc.shape[0], kw - 1, xbc.shape[-1]), xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i].astype(xbc.dtype)
              for i in range(kw))
    return jax.nn.silu(out + b.astype(xbc.dtype)), xp[:, -(kw - 1):]


def mamba2_block(p, x, *, state_dim: int = 64, head_dim: int = 64,
                 expand: int = 2, chunk: int = 128, dtype=None,
                 initial_state=None, return_state=False):
    dtype = dtype or x.dtype
    b, s, d_model = x.shape
    xn = nn.rmsnorm_apply(p["norm"], x)
    z, xbc, dt, d_inner, n_heads = _split_proj(p, xn, d_model, state_dim,
                                               head_dim, expand, dtype)
    xbc, _ = _causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"])
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + state_dim], axis=-1)
    xs = shard_hidden(xs, "batch", None, "ffn")

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    log_decay = (-jax.nn.softplus(p["A_log"].astype(jnp.float32)) * dt)
    v = (xs.reshape(b, s, n_heads, head_dim).astype(jnp.float32)
         * dt[..., None]).astype(dtype)
    # B/C shared across heads (n_groups=1): broadcast
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, s, n_heads, state_dim))
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, s, n_heads, state_dim))

    y, state = chunked_linear_attention(
        q, k, v, log_decay[..., None], chunk=chunk, mode="ssm",
        per_channel=False, initial_state=initial_state)
    y = y.astype(dtype) + p["D"].astype(dtype)[None, None, :, None] \
        * xs.reshape(b, s, n_heads, head_dim)
    y = y.reshape(b, s, d_inner)
    y = nn.rmsnorm_apply(p["gate_norm"], y) * jax.nn.silu(z)
    out = x + y @ p["out_proj"].astype(dtype)
    return (out, state) if return_state else out


def mamba2_block_chunk(p, x, state: "Mamba2State", *, state_dim=64,
                       head_dim=64, expand=2, chunk: int = 128, dtype=None):
    """Stateful block over a sequence segment (long-context chunked prefill).
    Equivalent to one full pass when segments are chained (tested)."""
    dtype = dtype or x.dtype
    b, s, d_model = x.shape
    xn = nn.rmsnorm_apply(p["norm"], x)
    z, xbc, dt, d_inner, n_heads = _split_proj(p, xn, d_model, state_dim,
                                               head_dim, expand, dtype)
    xbc, new_conv = _causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"],
                                           carry=state.conv)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + state_dim], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    log_decay = -jax.nn.softplus(p["A_log"].astype(jnp.float32)) * dt
    v = (xs.reshape(b, s, n_heads, head_dim).astype(jnp.float32)
         * dt[..., None]).astype(dtype)
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, s, n_heads, state_dim))
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, s, n_heads, state_dim))
    y, new_ssm = chunked_linear_attention(
        q, k, v, log_decay[..., None], chunk=chunk, mode="ssm",
        per_channel=False, initial_state=state.ssm)
    y = y.astype(dtype) + p["D"].astype(dtype)[None, None, :, None] \
        * xs.reshape(b, s, n_heads, head_dim)
    y = y.reshape(b, s, d_inner)
    y = nn.rmsnorm_apply(p["gate_norm"], y) * jax.nn.silu(z)
    out = x + y @ p["out_proj"].astype(dtype)
    return out, Mamba2State(ssm=new_ssm, conv=new_conv)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

class Mamba2State(NamedTuple):
    ssm: jax.Array         # (B, H, N, head_dim)
    conv: jax.Array        # (B, K-1, conv_ch)


def init_mamba2_state(batch, d_model, *, state_dim=64, head_dim=64, expand=2,
                      conv_width=4, dtype=jnp.float32):
    d_inner = expand * d_model
    h = d_inner // head_dim
    return Mamba2State(
        ssm=jnp.zeros((batch, h, state_dim, head_dim), jnp.float32),
        conv=jnp.zeros((batch, conv_width - 1, d_inner + 2 * state_dim), dtype),
    )


def mamba2_block_step(p, x, state: Mamba2State, *, state_dim=64, head_dim=64,
                      expand=2, dtype=None):
    dtype = dtype or x.dtype
    b, d_model = x.shape
    xn = nn.rmsnorm_apply(p["norm"], x[:, None, :])
    z, xbc, dt, d_inner, n_heads = _split_proj(p, xn, d_model, state_dim,
                                               head_dim, expand, dtype)
    xbc, new_conv = _causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"],
                                           carry=state.conv)
    xs, bmat, cmat = jnp.split(xbc[:, 0], [d_inner, d_inner + state_dim], axis=-1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # (B,H)
    log_decay = -jax.nn.softplus(p["A_log"].astype(jnp.float32)) * dt1
    v = xs.reshape(b, n_heads, head_dim).astype(jnp.float32) * dt1[..., None]
    k = jnp.broadcast_to(bmat[:, None, :], (b, n_heads, state_dim))
    q = jnp.broadcast_to(cmat[:, None, :], (b, n_heads, state_dim))
    y, new_ssm = linear_attention_step(q, k, v, log_decay[..., None],
                                       state.ssm, mode="ssm")
    y = y.astype(dtype) + p["D"].astype(dtype)[None, :, None] \
        * xs.reshape(b, n_heads, head_dim)
    y = y.reshape(b, d_inner)
    y = nn.rmsnorm_apply(p["gate_norm"], y) * jax.nn.silu(z[:, 0])
    out = x + y @ p["out_proj"].astype(dtype)
    return out, Mamba2State(ssm=new_ssm, conv=new_conv)
