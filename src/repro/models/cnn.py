"""YOLO-v3-front CNN for the Tier-A faithful reproduction.

Topology mirrors the Darknet-53 stem through the paper's split layer l=12
(conv/BN/Leaky blocks, residual connections, three stride-2 stages), with a
width multiplier so the same topology trains on CPU at reduced scale
(DESIGN.md §6). At width_mult=1 and input 512x512 the split tensor is exactly
the paper's 64x64x256 with Q=128 input channels.

Layer schedule (channels at width_mult=1):
  conv 32 s1 | conv 64 s2 | res(32,64) | conv 128 s2 | res(64,128) x2 |
  conv 256 s2 <- SPLIT LAYER (l=12): stride 2, L=3, BN, no residual across it.
Edge device runs through the split layer's BN; cloud runs Leaky(sigma) onward.
The cloud tail continues darknet-style (res(128,256) x N) into a classification
head for the synthetic detection-proxy task.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import nn


class CNNConfig(NamedTuple):
    width_mult: float = 1.0
    input_size: int = 512
    num_classes: int = 8
    tail_res_blocks: int = 2
    dtype: object = jnp.float32

    def ch(self, c: int) -> int:
        return max(4, int(round(c * self.width_mult)))

    @property
    def split_p(self) -> int:      # P: channels of the split BN output
        return self.ch(256)

    @property
    def split_q(self) -> int:      # Q: input channels of the split conv
        return self.ch(128)

    @property
    def split_hw(self) -> int:     # spatial size of the split output
        return self.input_size // 8


def _conv_bn(key, cin, cout, ksize, dtype):
    return {"conv": nn.init_conv(key, cin, cout, ksize, bias=False, dtype=dtype),
            "bn": nn.init_batchnorm(cout, dtype)}


def init_cnn(key, cfg: CNNConfig):
    keys = jax.random.split(key, 32)
    d, ch = cfg.dtype, cfg.ch
    ki = iter(keys)
    params = {
        "stem": [
            _conv_bn(next(ki), 3, ch(32), 3, d),            # l1  s1
            _conv_bn(next(ki), ch(32), ch(64), 3, d),       # l2  s2
            _conv_bn(next(ki), ch(64), ch(32), 1, d),       # res1.a
            _conv_bn(next(ki), ch(32), ch(64), 3, d),       # res1.b
            _conv_bn(next(ki), ch(64), ch(128), 3, d),      # l5  s2
            _conv_bn(next(ki), ch(128), ch(64), 1, d),      # res2.a
            _conv_bn(next(ki), ch(64), ch(128), 3, d),      # res2.b
            _conv_bn(next(ki), ch(128), ch(64), 1, d),      # res3.a
            _conv_bn(next(ki), ch(64), ch(128), 3, d),      # res3.b
        ],
        # split layer l=12: conv 3x3 stride 2 -> BN (sigma applied in cloud)
        "split": _conv_bn(next(ki), ch(128), ch(256), 3, d),
        "tail": [],
        "head": None,
    }
    for _ in range(cfg.tail_res_blocks):
        params["tail"].append(_conv_bn(next(ki), ch(256), ch(128), 1, d))
        params["tail"].append(_conv_bn(next(ki), ch(128), ch(256), 3, d))
    params["head"] = nn.init_dense(next(ki), ch(256), cfg.num_classes, dtype=d)
    return params


# strides of the 9 stem conv layers; residual pairs are (a 1x1, b 3x3)
_STEM_STRIDES = [1, 2, 1, 1, 2, 1, 1, 1, 1]
_STEM_RES_AT = {3, 6, 8}  # after these indices, add the pre-block input


def _apply_conv_bn(p, x, stride, *, train=False):
    y = nn.conv_apply(p["conv"], x, stride=stride)
    if train:
        y, new_bn = nn.batchnorm_train_apply(p["bn"], y)
        return nn.leaky_relu(y), {"conv": p["conv"], "bn": new_bn}
    return nn.leaky_relu(nn.batchnorm_apply(p["bn"], y)), p


def cnn_edge(params, img, *, train=False):
    """Mobile-side compute: stem, then split conv + BN (NO activation).

    Returns (x_split_input, z_bn_output[, new_params if train]).
    """
    x = img
    new_stem = []
    shortcut = None
    for i, (p, s) in enumerate(zip(params["stem"], _STEM_STRIDES)):
        if i in {2, 5, 7}:              # entering a residual pair
            shortcut = x
        x, p_new = _apply_conv_bn(p, x, s, train=train)
        if i in _STEM_RES_AT:
            x = x + shortcut
        new_stem.append(p_new)
    x_in = x                            # X^{(l)}: input of the split layer (Q ch)
    z = nn.conv_apply(params["split"]["conv"], x_in, stride=2)
    if train:
        z, new_bn = nn.batchnorm_train_apply(params["split"]["bn"], z)
        new_params = dict(params)
        new_params["stem"] = new_stem
        new_params["split"] = {"conv": params["split"]["conv"], "bn": new_bn}
        return x_in, z, new_params
    z = nn.batchnorm_apply(params["split"]["bn"], z)
    return x_in, z


def cnn_cloud(params, z, *, train=False):
    """Cloud-side compute: sigma (Leaky) of the split layer, tail, head."""
    x = nn.leaky_relu(z)
    new_tail = []
    for i in range(0, len(params["tail"]), 2):
        sc = x
        x, pa = _apply_conv_bn(params["tail"][i], x, 1, train=train)
        x, pb = _apply_conv_bn(params["tail"][i + 1], x, 1, train=train)
        x = x + sc
        new_tail += [pa, pb]
    feat = jnp.mean(x, axis=(1, 2))     # GAP
    logits = nn.dense_apply(params["head"], feat)
    if train:
        new_params = dict(params)
        new_params["tail"] = new_tail
        return logits, new_params
    return logits


def cnn_forward(params, img):
    _, z = cnn_edge(params, img)
    return cnn_cloud(params, z)


def cnn_forward_train(params, img):
    """Full forward with batch-stat BN; returns (logits, params-with-new-EMA)."""
    _, z, p1 = cnn_edge(params, img, train=True)
    logits, p2 = cnn_cloud(p1, z, train=True)
    return logits, p2
