"""Feed-forward variants: SwiGLU (qwen2/pixtral/olmoe/arctic), GELU
(starcoder2/whisper), squared-ReLU (nemotron-4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import nn
from repro.distributed import shard_hidden


def init_ffn(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wdown": nn.normal(k2, (d_ff, d_model), 0.02, dtype)}
    if act == "swiglu":
        p["wgate"] = nn.normal(k1, (d_model, d_ff), 0.02, dtype)
        p["wup"] = nn.normal(k3, (d_model, d_ff), 0.02, dtype)
    else:
        p["wup"] = nn.normal(k1, (d_model, d_ff), 0.02, dtype)
    return p


def ffn_apply(p, x, act: str, *, dtype=None):
    dtype = dtype or x.dtype
    up = x @ p["wup"].astype(dtype)
    up = shard_hidden(up, "batch", None, "ffn")
    if act == "swiglu":
        gate = x @ p["wgate"].astype(dtype)
        gate = shard_hidden(gate, "batch", None, "ffn")
        h = jax.nn.silu(gate) * up
    elif act == "gelu":
        h = jax.nn.gelu(up, approximate=True)
    elif act == "sq_relu":
        h = nn.squared_relu(up)
    else:
        raise ValueError(f"unknown act {act!r}")
    return h @ p["wdown"].astype(dtype)
