"""RWKV-6 (Finch) block — arXiv:2404.05892.

Time mixing with data-dependent token-shift lerp (DDLerp, low-rank), data-
dependent per-channel decay w_t = exp(-exp(w0 + lora(x))), per-head bonus u,
and the wkv linear-attention recurrence (models/linear_attention.py).
Channel mixing is the squared-ReLU token-shift MLP.

Attention-free: train/prefill is the chunked scan, decode is O(1)/token.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.distributed import shard_hidden
from repro.models.linear_attention import (chunked_linear_attention,
                                           linear_attention_step)

_MIX = ("r", "k", "v", "g", "w")


def init_rwkv6_block(key, d_model: int, head_dim: int, *, lora_rank: int = 64,
                     mix_rank: int = 32, d_ff: int | None = None,
                     dtype=jnp.float32):
    d_ff = d_ff or d_model * 7 // 2
    n_heads = d_model // head_dim
    ks = iter(jax.random.split(key, 24))
    p = {
        "ln1": nn.init_layernorm(d_model, dtype),
        "ln2": nn.init_layernorm(d_model, dtype),
        # DDLerp
        "mu_x": jnp.zeros((d_model,), dtype),
        "mu_base": jnp.zeros((5, d_model), dtype),
        "mix_w1": nn.normal(next(ks), (d_model, 5 * mix_rank), 0.02, dtype),
        "mix_w2": nn.normal(next(ks), (5, mix_rank, d_model), 0.02, dtype),
        # projections
        "wr": nn.normal(next(ks), (d_model, d_model), 0.02, dtype),
        "wk": nn.normal(next(ks), (d_model, d_model), 0.02, dtype),
        "wv": nn.normal(next(ks), (d_model, d_model), 0.02, dtype),
        "wg": nn.normal(next(ks), (d_model, d_model), 0.02, dtype),
        "wo": nn.normal(next(ks), (d_model, d_model), 0.02, dtype),
        # data-dependent decay
        "w0": jnp.full((d_model,), -1.0, dtype),      # resting log(-log w)
        "wd_a": nn.normal(next(ks), (d_model, lora_rank), 0.02, dtype),
        "wd_b": nn.normal(next(ks), (lora_rank, d_model), 0.02, dtype),
        "u": nn.normal(next(ks), (n_heads, head_dim), 0.1, dtype),
        "ln_x": nn.init_layernorm(d_model, dtype),    # per-head group norm
        # channel mixing
        "cm_mu_k": jnp.full((d_model,), 0.5, dtype),
        "cm_mu_r": jnp.full((d_model,), 0.5, dtype),
        "cm_wk": nn.normal(next(ks), (d_model, d_ff), 0.02, dtype),
        "cm_wv": nn.normal(next(ks), (d_ff, d_model), 0.02, dtype),
        "cm_wr": nn.normal(next(ks), (d_model, d_model), 0.02, dtype),
    }
    return p


def _token_shift(x, last=None):
    """x[t] -> x[t-1]; first position takes ``last`` (decode carry) or 0."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([first, prev[:, 1:]], axis=1)


def _ddlerp(p, x, dx, dtype):
    """Data-dependent lerp: five mixed inputs (r,k,v,g,w)."""
    xxx = x + dx * p["mu_x"].astype(dtype)
    lora = jnp.tanh(xxx @ p["mix_w1"].astype(dtype))
    b, s, _ = x.shape
    lora = lora.reshape(b, s, 5, -1)
    mus = p["mu_base"].astype(dtype) + jnp.einsum(
        "bsfr,frd->bsfd", lora, p["mix_w2"].astype(dtype))
    return [x + dx * mus[:, :, i, :] for i in range(5)]


def _time_mix_qkvgw(p, x, dx, n_heads, head_dim, dtype):
    b, s, d = x.shape
    xr, xk, xv, xg, xw = _ddlerp(p, x, dx, dtype)
    r = (xr @ p["wr"].astype(dtype)).reshape(b, s, n_heads, head_dim)
    k = (xk @ p["wk"].astype(dtype)).reshape(b, s, n_heads, head_dim)
    v = (xv @ p["wv"].astype(dtype)).reshape(b, s, n_heads, head_dim)
    g = jax.nn.silu(xg @ p["wg"].astype(dtype))
    dd = jnp.tanh(xw @ p["wd_a"].astype(dtype)) @ p["wd_b"].astype(dtype)
    log_decay = -jnp.exp(p["w0"].astype(jnp.float32) + dd.astype(jnp.float32))
    log_decay = log_decay.reshape(b, s, n_heads, head_dim)
    return r, k, v, g, log_decay


def _time_mix_out(p, wkv, g, b, s, d, dtype):
    y = nn.layernorm_apply(p["ln_x"], wkv.reshape(b, s, d).astype(dtype))
    return (y * g) @ p["wo"].astype(dtype)


def rwkv6_time_mix(p, x, *, head_dim: int, chunk: int = 16, dtype=None,
                   initial_state=None, return_state=False):
    dtype = dtype or x.dtype
    b, s, d = x.shape
    n_heads = d // head_dim
    dx = _token_shift(x) - x
    r, k, v, g, log_decay = _time_mix_qkvgw(p, x, dx, n_heads, head_dim, dtype)
    wkv, state = chunked_linear_attention(
        r, k, v, log_decay, bonus=p["u"], chunk=chunk, mode="rwkv",
        initial_state=initial_state)
    y = _time_mix_out(p, wkv.astype(dtype), g, b, s, d, dtype)
    return (y, state) if return_state else y


def rwkv6_channel_mix(p, x, *, dtype=None):
    dtype = dtype or x.dtype
    dx = _token_shift(x) - x
    xk = x + dx * p["cm_mu_k"].astype(dtype)
    xr = x + dx * p["cm_mu_r"].astype(dtype)
    kv = jnp.square(jax.nn.relu(xk @ p["cm_wk"].astype(dtype)))
    kv = shard_hidden(kv, "batch", None, "ffn")
    kv = kv @ p["cm_wv"].astype(dtype)
    return jax.nn.sigmoid(xr @ p["cm_wr"].astype(dtype)) * kv


def rwkv6_block(p, x, *, head_dim: int, chunk: int = 16, dtype=None):
    y = x + rwkv6_time_mix(p, nn.layernorm_apply(p["ln1"], x),
                           head_dim=head_dim, chunk=chunk, dtype=dtype)
    y = y + rwkv6_channel_mix(p, nn.layernorm_apply(p["ln2"], y), dtype=dtype)
    return y


def rwkv6_block_chunk(p, x, state: "RWKV6State", *, head_dim: int,
                      chunk: int = 16, dtype=None):
    """Stateful block over a sequence segment — long-context chunked prefill.

    x: (B, L, D) one segment; ``state`` carries the wkv state and the last
    token of the previous segment for both token shifts. Segment-chained
    results are exactly equal to one full-sequence pass (tests assert this).
    """
    dtype = dtype or x.dtype
    b, s, d = x.shape
    n_heads = d // head_dim
    xn = nn.layernorm_apply(p["ln1"], x)
    dx = _token_shift(xn, last=state.last_tm) - xn
    r, k, v, g, log_decay = _time_mix_qkvgw(p, xn, dx, n_heads, head_dim, dtype)
    wkv, new_wkv = chunked_linear_attention(
        r, k, v, log_decay, bonus=p["u"], chunk=chunk, mode="rwkv",
        initial_state=state.wkv)
    y = x + _time_mix_out(p, wkv.astype(dtype), g, b, s, d, dtype)
    yn = nn.layernorm_apply(p["ln2"], y)
    dxc = _token_shift(yn, last=state.last_cm) - yn
    xk = yn + dxc * p["cm_mu_k"].astype(dtype)
    xr = yn + dxc * p["cm_mu_r"].astype(dtype)
    kv = jnp.square(jax.nn.relu(xk @ p["cm_wk"].astype(dtype))) @ p["cm_wv"].astype(dtype)
    y = y + jax.nn.sigmoid(xr @ p["cm_wr"].astype(dtype)) * kv
    new_state = RWKV6State(wkv=new_wkv, last_tm=xn[:, -1], last_cm=yn[:, -1])
    return y, new_state


# ---------------------------------------------------------------------------
# Decode (recurrent state: wkv state + two token-shift carries)
# ---------------------------------------------------------------------------

class RWKV6State(NamedTuple):
    wkv: jax.Array         # (B, H, dk, dv)
    last_tm: jax.Array     # (B, D) token-shift carry, time mixing
    last_cm: jax.Array     # (B, D) token-shift carry, channel mixing


def init_rwkv6_state(batch, d_model, head_dim, dtype=jnp.float32):
    h = d_model // head_dim
    return RWKV6State(
        wkv=jnp.zeros((batch, h, head_dim, head_dim), jnp.float32),
        last_tm=jnp.zeros((batch, d_model), dtype),
        last_cm=jnp.zeros((batch, d_model), dtype),
    )


def rwkv6_block_step(p, x, state: RWKV6State, *, head_dim: int, dtype=None):
    """x: (B, D) one token. Returns (y (B, D), new_state)."""
    dtype = dtype or x.dtype
    b, d = x.shape
    n_heads = d // head_dim
    xs = x[:, None, :]

    xn = nn.layernorm_apply(p["ln1"], xs)
    dx = state.last_tm[:, None, :] - xn
    r, k, v, g, log_decay = _time_mix_qkvgw(p, xn, dx, n_heads, head_dim, dtype)
    wkv, new_wkv = linear_attention_step(
        r[:, 0], k[:, 0], v[:, 0], log_decay[:, 0], state.wkv,
        bonus=p["u"], mode="rwkv")
    y = x + _time_mix_out(p, wkv[:, None].astype(dtype), g, b, 1, d, dtype)[:, 0]
    new_last_tm = xn[:, 0]

    yn = nn.layernorm_apply(p["ln2"], y[:, None, :])
    dxc = state.last_cm[:, None, :] - yn
    xk = yn + dxc * p["cm_mu_k"].astype(dtype)
    xr = yn + dxc * p["cm_mu_r"].astype(dtype)
    kv = jnp.square(jax.nn.relu(xk @ p["cm_wk"].astype(dtype))) @ p["cm_wv"].astype(dtype)
    y = y + (jax.nn.sigmoid(xr @ p["cm_wr"].astype(dtype)) * kv)[:, 0]
    return y, RWKV6State(wkv=new_wkv, last_tm=new_last_tm, last_cm=yn[:, 0])
