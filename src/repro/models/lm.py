"""Unified LM: one init/forward/prefill/decode covering every assigned family.

  dense  — pre-norm GQA + FFN blocks (qwen2-72b/7b, starcoder2, nemotron-4,
           pixtral backbone)
  moe    — GQA + MoE-FFN blocks (olmoe, arctic w/ dense residual)
  ssm    — RWKV-6 blocks (attention-free)
  hybrid — Mamba-2 backbone with a SHARED full-attention block applied every
           ``shared_attn_every`` layers (zamba2); in long-context mode the
           shared block uses windowed attention (sub-quadratic end to end)

Layers are scanned (stacked params) so the traced HLO is O(1) in depth; the
hybrid schedule scans homogeneous segments and applies the shared block
between segments. Each block is wrapped in jax.checkpoint (remat) for
training memory.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ArchConfig
from repro.distributed import shard_hidden
from repro.models.attention import (KVCache, attention_apply, attention_decode,
                                    init_attention, init_kv_cache)
from repro.models.ffn import ffn_apply, init_ffn
from repro.models.moe import init_moe, moe_apply
from repro.models.mamba2 import (Mamba2State, init_mamba2_block,
                                 init_mamba2_state, mamba2_block,
                                 mamba2_block_step)
from repro.models.rwkv6 import (RWKV6State, init_rwkv6_block, init_rwkv6_state,
                                rwkv6_block, rwkv6_block_step)


# ---------------------------------------------------------------------------
# Norm dispatch
# ---------------------------------------------------------------------------

def _init_norm(cfg, dtype):
    return (nn.init_rmsnorm(cfg.d_model, dtype) if cfg.norm == "rmsnorm"
            else nn.init_layernorm(cfg.d_model, dtype))


def _norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        if getattr(cfg, "norm_grad", "f32") == "bf16":
            return nn.rmsnorm_lowmem_apply(p, x)
        return nn.rmsnorm_apply(p, x)
    return nn.layernorm_apply(p, x)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg: ArchConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": _init_norm(cfg, dtype),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.hd, qkv_bias=cfg.qkv_bias, dtype=dtype),
        "ln2": _init_norm(cfg, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(k2, cfg.d_model, cfg.d_ff, cfg.moe, cfg.act, dtype)
    else:
        p["ffn"] = init_ffn(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def _init_layer(key, cfg: ArchConfig, dtype):
    if cfg.family == "ssm":
        return init_rwkv6_block(key, cfg.d_model, cfg.ssm.head_dim,
                                lora_rank=cfg.ssm.decay_lora,
                                d_ff=cfg.d_ff, dtype=dtype)
    if cfg.family == "hybrid":
        return init_mamba2_block(key, cfg.d_model, state_dim=cfg.ssm.state_dim,
                                 head_dim=cfg.ssm.head_dim, expand=cfg.ssm.expand,
                                 conv_width=cfg.ssm.conv_width, dtype=dtype)
    return _init_attn_block(key, cfg, dtype)


def init_lm(key, cfg: ArchConfig):
    dtype = cfg.param_dtype
    k_emb, k_layers, k_shared, k_head = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params: dict[str, Any] = {
        "layers": jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys),
        "final_norm": _init_norm(cfg, dtype),
    }
    # vlm: train/prefill consume precomputed (vision+text) embeds, but decode
    # still embeds *text* tokens — only the vision tower is stubbed.
    if cfg.embed_inputs or cfg.family == "vlm":
        params["embed"] = nn.normal(k_emb, (cfg.vocab, cfg.d_model), 0.02, dtype)
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        params["lm_head"] = nn.normal(k_head, (cfg.d_model, cfg.vocab), 0.02, dtype)
    if cfg.family == "hybrid":
        params["shared"] = _init_attn_block(k_shared, cfg.with_(moe=None), dtype)
    return params


# ---------------------------------------------------------------------------
# Blocks (forward)
# ---------------------------------------------------------------------------

def _attn_ffn_block(lp, x, cfg: ArchConfig, *, window=None, dtype=None):
    """Returns (y, aux) — aux is the MoE load-balance loss (0 for dense)."""
    h = attention_apply(lp["attn"], _norm(cfg, lp["ln1"], x),
                        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.hd, rope_theta=cfg.rope_theta,
                        causal=True, window=window, dtype=dtype)
    x = x + h
    xn = _norm(cfg, lp["ln2"], x)
    if cfg.moe is not None:
        y, aux = moe_apply(lp["moe"], xn, cfg.moe, cfg.act, cfg.d_ff, dtype=dtype)
    else:
        y, aux = ffn_apply(lp["ffn"], xn, cfg.act, dtype=dtype), 0.0
    x = x + y
    return shard_hidden(x, "batch", None, "act_hidden"), aux


def _ssm_or_hybrid_block(lp, x, cfg: ArchConfig, *, dtype=None):
    if cfg.family == "ssm":
        y = rwkv6_block(lp, x, head_dim=cfg.ssm.head_dim, chunk=cfg.ssm.chunk,
                        dtype=dtype)
    else:
        y = mamba2_block(lp, x, state_dim=cfg.ssm.state_dim,
                         head_dim=cfg.ssm.head_dim, expand=cfg.ssm.expand,
                         chunk=cfg.ssm.chunk, dtype=dtype)
    return shard_hidden(y, "batch", None, "act_hidden")


def _segment_bounds(cfg: ArchConfig):
    """Hybrid schedule: segment ends where the shared attn block is applied."""
    if cfg.family != "hybrid":
        return [(0, cfg.n_layers)]
    step = cfg.hybrid.shared_attn_every
    bounds = []
    i = 0
    while i < cfg.n_layers:
        j = min(i + step, cfg.n_layers)
        bounds.append((i, j))
        i = j
    return bounds


def _scan_layers(layers, x, body, lo, hi):
    """Scan a slice [lo, hi) of the stacked layer params."""
    sliced = jax.tree.map(lambda a: a[lo:hi], layers)
    x, auxes = jax.lax.scan(lambda carry, lp: body(carry, lp), x, sliced)
    return x, jnp.sum(auxes)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

_REMAT_POLICIES = {
    # recompute everything inside a block (min memory, 8ND flops)
    "full": lambda: jax.checkpoint_policies.nothing_saveable,
    # keep matmul outputs, recompute elementwise only (~6.5ND flops)
    "dots": lambda: jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": lambda: jax.checkpoint_policies
        .checkpoint_dots_with_no_batch_dims,
}


def lm_hidden(params, cfg: ArchConfig, *, tokens=None, embeds=None,
              window=None, remat: bool = True, remat_policy: str = "full"):
    """Run the stack; returns (hidden (B,S,D), moe_aux)."""
    dtype = cfg.dtype
    if embeds is None:
        x = params["embed"][tokens].astype(dtype)
    else:
        x = embeds.astype(dtype)
    x = shard_hidden(x, "batch", None, "act_hidden")

    if cfg.family in ("ssm", "hybrid"):
        def body(carry, lp):
            y = _ssm_or_hybrid_block(lp, carry, cfg, dtype=dtype)
            return y, jnp.zeros((), jnp.float32)
    else:
        def body(carry, lp):
            y, aux = _attn_ffn_block(lp, carry, cfg, window=window, dtype=dtype)
            return y, jnp.asarray(aux, jnp.float32)
    if remat:
        policy = _REMAT_POLICIES[remat_policy]()
        body = jax.checkpoint(body, policy=policy)

    aux_total = jnp.zeros((), jnp.float32)
    for (lo, hi) in _segment_bounds(cfg):
        x, aux = _scan_layers(params["layers"], x, body, lo, hi)
        aux_total = aux_total + aux
        if cfg.family == "hybrid":
            shared_window = window or (cfg.hybrid.attn_window_long
                                       if x.shape[1] > 65536 else None)
            sb = partial(_attn_ffn_block, params["shared"], cfg=cfg.with_(moe=None),
                         window=shared_window, dtype=dtype)
            if remat:
                x = jax.checkpoint(lambda t: sb(x=t)[0],
                                   policy=_REMAT_POLICIES[remat_policy]())(x)
            else:
                x = sb(x=x)[0]
    x = _norm(cfg, params["final_norm"], x)
    return x, aux_total


def lm_logits(params, cfg: ArchConfig, hidden):
    if cfg.tie_embeddings and "embed" in params:
        w = params["embed"].T
    else:
        w = params["lm_head"]
    logits = hidden @ w.astype(cfg.dtype)
    return shard_hidden(logits, "batch", None, "vocab")


def lm_forward(params, cfg: ArchConfig, *, tokens=None, embeds=None,
               window=None, remat=True, remat_policy="full"):
    hidden, aux = lm_hidden(params, cfg, tokens=tokens, embeds=embeds,
                            window=window, remat=remat,
                            remat_policy=remat_policy)
    return lm_logits(params, cfg, hidden), aux


def xent_loss(logits, labels):
    """Vocab-sharding-safe cross entropy: logsumexp + one-hot einsum only
    (partial reduce + all-reduce under SPMD; the unsharded (T, V) logits are
    never materialized)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    oh = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.sum(logits * oh, axis=-1)
    return jnp.mean(logz - gold)


def lm_loss(params, cfg: ArchConfig, batch, *, window=None, remat=True,
            remat_policy="full"):
    logits, aux = lm_forward(params, cfg, tokens=batch.get("tokens"),
                             embeds=batch.get("embeds"), window=window,
                             remat=remat, remat_policy=remat_policy)
    loss = xent_loss(logits, batch["labels"])
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux / cfg.n_layers
    return loss


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    """Stacked per-layer decode state. Exactly one of kv/ssm/rwkv is used per
    family; hybrid uses ssm + shared_kv (one KV cache per shared-block call)."""
    kv: Optional[Any] = None          # KVCache with (L, B, S, K, h) leaves
    rwkv: Optional[Any] = None        # RWKV6State with (L, ...) leaves
    ssm: Optional[Any] = None         # Mamba2State with (L, ...) leaves
    shared_kv: Optional[Any] = None   # KVCache with (n_seg, B, S, K, h) leaves


def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int) -> DecodeCache:
    if cfg.family == "ssm":
        st = init_rwkv6_state(batch, cfg.d_model, cfg.ssm.head_dim, cfg.dtype)
        return DecodeCache(rwkv=jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), st))
    if cfg.family == "hybrid":
        st = init_mamba2_state(batch, cfg.d_model, state_dim=cfg.ssm.state_dim,
                               head_dim=cfg.ssm.head_dim, expand=cfg.ssm.expand,
                               conv_width=cfg.ssm.conv_width, dtype=cfg.dtype)
        nseg = len(_segment_bounds(cfg))
        kv = init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, cfg.dtype)
        return DecodeCache(
            ssm=jax.tree.map(lambda a: jnp.broadcast_to(
                a[None], (cfg.n_layers,) + a.shape), st),
            shared_kv=jax.tree.map(lambda a: jnp.broadcast_to(
                a[None], (nseg,) + a.shape), kv))
    kv = init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.hd, cfg.dtype)
    return DecodeCache(kv=jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), kv))


def _attn_block_decode(lp, x, kv: KVCache, cfg: ArchConfig, dtype):
    """x: (B, D) one token through one attention block."""
    xs = x[:, None, :]
    h, new_kv = attention_decode(lp["attn"], _norm(cfg, lp["ln1"], xs), kv,
                                 n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                                 head_dim=cfg.hd, rope_theta=cfg.rope_theta,
                                 dtype=dtype)
    x = x + h[:, 0]
    xn = _norm(cfg, lp["ln2"], x[:, None, :])
    if cfg.moe is not None:
        y, _ = moe_apply(lp["moe"], xn.reshape(1, x.shape[0], -1), cfg.moe,
                         cfg.act, cfg.d_ff, dtype=dtype)
        y = y.reshape(x.shape)
    else:
        y = ffn_apply(lp["ffn"], xn, cfg.act, dtype=dtype)[:, 0]
    return x + y, new_kv


def lm_decode_step(params, cfg: ArchConfig, cache: DecodeCache, token,
                   embeds=None):
    """One decode step. token: (B,) int32 (or embeds (B, D)). Returns
    (logits (B, V), new_cache)."""
    dtype = cfg.dtype
    x = params["embed"][token].astype(dtype) if embeds is None else embeds.astype(dtype)

    if cfg.family == "ssm":
        def body(carry, lp_state):
            lp, st = lp_state
            y, new_st = rwkv6_block_step(lp, carry, st,
                                         head_dim=cfg.ssm.head_dim, dtype=dtype)
            return y, new_st
        x, new_rwkv = jax.lax.scan(body, x, (params["layers"], cache.rwkv))
        new_cache = DecodeCache(rwkv=new_rwkv)
    elif cfg.family == "hybrid":
        new_ssm_segs, new_kv_segs = [], []
        bounds = _segment_bounds(cfg)
        for seg_i, (lo, hi) in enumerate(bounds):
            lp_seg = jax.tree.map(lambda a: a[lo:hi], params["layers"])
            st_seg = jax.tree.map(lambda a: a[lo:hi], cache.ssm)

            def body(carry, lp_state):
                lp, st = lp_state
                y, new_st = mamba2_block_step(
                    lp, carry, st, state_dim=cfg.ssm.state_dim,
                    head_dim=cfg.ssm.head_dim, expand=cfg.ssm.expand, dtype=dtype)
                return y, new_st
            x, new_st_seg = jax.lax.scan(body, x, (lp_seg, st_seg))
            new_ssm_segs.append(new_st_seg)
            kv = jax.tree.map(lambda a: a[seg_i], cache.shared_kv)
            x, new_kv = _attn_block_decode(params["shared"], x, kv,
                                           cfg.with_(moe=None), dtype)
            new_kv_segs.append(new_kv)
        new_cache = DecodeCache(
            ssm=jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_ssm_segs),
            shared_kv=jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_kv_segs))
    else:
        def body(carry, lp_kv):
            lp, kv = lp_kv
            y, new_kv = _attn_block_decode(lp, carry, kv, cfg, dtype)
            return y, new_kv
        x, new_kv = jax.lax.scan(body, x, (params["layers"], cache.kv))
        new_cache = DecodeCache(kv=new_kv)

    x = _norm(cfg, params["final_norm"], x[:, None, :])
    logits = lm_logits(params, cfg, x)[:, 0]
    return logits, new_cache
