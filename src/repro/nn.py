"""Minimal functional NN substrate (no flax/haiku dependency).

Params are plain pytrees (nested dicts of jnp arrays). Every layer is a pair of
pure functions: ``init_*(key, ...) -> params`` and ``*_apply(params, x) -> y``.
Initializers follow standard fan-in scaling. All layers accept a ``dtype``
(compute dtype); params are stored in ``param_dtype``.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def he_normal(key, shape, fan_in, dtype=jnp.float32):
    std = math.sqrt(2.0 / max(fan_in, 1))
    return (std * jax.random.normal(key, shape)).astype(dtype)


def lecun_normal(key, shape, fan_in, dtype=jnp.float32):
    std = math.sqrt(1.0 / max(fan_in, 1))
    return (std * jax.random.normal(key, shape)).astype(dtype)


def normal(key, shape, std=0.02, dtype=jnp.float32):
    return (std * jax.random.normal(key, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def init_dense(key, in_dim, out_dim, *, bias=True, dtype=jnp.float32, std=None):
    kw, kb = jax.random.split(key)
    if std is None:
        w = lecun_normal(kw, (in_dim, out_dim), in_dim, dtype)
    else:
        w = normal(kw, (in_dim, out_dim), std, dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_apply(p, x, *, dtype=None):
    w = p["w"]
    if dtype is not None:
        w = w.astype(dtype)
        x = x.astype(dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Conv2D (NHWC, HWIO kernels)
# ---------------------------------------------------------------------------

def init_conv(key, in_ch, out_ch, ksize, *, bias=True, dtype=jnp.float32):
    kw, kb = jax.random.split(key)
    fan_in = in_ch * ksize * ksize
    p = {"w": he_normal(kw, (ksize, ksize, in_ch, out_ch), fan_in, dtype)}
    if bias:
        p["b"] = jnp.zeros((out_ch,), dtype)
    return p


def conv_apply(p, x, *, stride=1, padding="SAME", dtype=None):
    w = p["w"]
    if dtype is not None:
        w = w.astype(dtype)
        x = x.astype(dtype)
    y = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def conv_transpose_apply(p, x, *, stride=2, dtype=None):
    """Transposed conv (×stride upsampling), NHWC/HWIO."""
    w = p["w"]
    if dtype is not None:
        w = w.astype(dtype)
        x = x.astype(dtype)
    y = jax.lax.conv_transpose(
        x, w,
        strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# BatchNorm (inference-style: apply with stored statistics; training variant
# returns batch stats so the caller can maintain EMA)
# ---------------------------------------------------------------------------

def init_batchnorm(ch, dtype=jnp.float32):
    return {
        "scale": jnp.ones((ch,), dtype),
        "bias": jnp.zeros((ch,), dtype),
        "mean": jnp.zeros((ch,), dtype),
        "var": jnp.ones((ch,), dtype),
    }


def batchnorm_apply(p, x, *, eps=1e-5):
    """Inference BN over the trailing channel dim (NHWC or N...C)."""
    inv = jax.lax.rsqrt(p["var"].astype(x.dtype) + eps)
    return (x - p["mean"].astype(x.dtype)) * inv * p["scale"].astype(x.dtype) \
        + p["bias"].astype(x.dtype)


def batchnorm_inverse(p, z, *, eps=1e-5):
    """Invert inference BN: recover the pre-BN value from the BN output.

    BaF backward prediction starts with exactly this (paper §3.3). Channels
    with |scale| ~ 0 are non-invertible; we guard with a floor.
    """
    scale = p["scale"].astype(z.dtype)
    safe = jnp.where(jnp.abs(scale) < 1e-6, 1e-6, scale)
    std = jnp.sqrt(p["var"].astype(z.dtype) + eps)
    return (z - p["bias"].astype(z.dtype)) / safe * std + p["mean"].astype(z.dtype)


def batchnorm_train_apply(p, x, *, eps=1e-5, momentum=0.97):
    """Training BN: normalize by batch stats, return (y, new_params_with_ema)."""
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axes)
    var = jnp.var(x, axes)
    inv = jax.lax.rsqrt(var + eps)
    y = (x - mean) * inv * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
    new_p = dict(p)
    new_p["mean"] = (momentum * p["mean"] + (1 - momentum) * mean).astype(p["mean"].dtype)
    new_p["var"] = (momentum * p["var"] + (1 - momentum) * var).astype(p["var"].dtype)
    return y, new_p


# ---------------------------------------------------------------------------
# Norms for transformer stacks
# ---------------------------------------------------------------------------

def init_rmsnorm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm_apply(p, x, *, eps=1e-6):
    orig = x.dtype
    x = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(orig)


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_lowmem(scale, x, eps):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)) \
        .astype(x.dtype)


def _rmsnorm_lowmem_fwd(scale, x, eps):
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    y = (xf * inv * scale.astype(jnp.float32)).astype(x.dtype)
    return y, (scale, x, inv.astype(jnp.float32))


def _rmsnorm_lowmem_bwd(eps, res, g):
    """Cotangents stay in the INPUT dtype (bf16): the only fp32 tensors are
    the per-row statistics. Halves the dominant bwd-pass HBM traffic of the
    default fp32-cast rmsnorm (EXPERIMENTS.md §Perf HC1 it5)."""
    scale, x, inv = res
    gs = (g * scale.astype(g.dtype)).astype(x.dtype)       # (B,S,D) bf16
    # row stat in fp32: sum(g*scale*x) / (D * rms^2)
    dot = jnp.sum(gs.astype(jnp.float32) * x.astype(jnp.float32),
                  axis=-1, keepdims=True)
    n = x.shape[-1]
    coef = (dot * inv * inv / n).astype(x.dtype)           # (B,S,1)
    dx = ((gs.astype(jnp.float32) - coef.astype(jnp.float32)
           * x.astype(jnp.float32)) * inv).astype(x.dtype)
    dscale = jnp.sum((g.astype(jnp.float32)
                      * (x.astype(jnp.float32) * inv)),
                     axis=tuple(range(x.ndim - 1))).astype(scale.dtype)
    return dscale, dx


_rmsnorm_lowmem.defvjp(_rmsnorm_lowmem_fwd, _rmsnorm_lowmem_bwd)


def rmsnorm_lowmem_apply(p, x, *, eps=1e-6):
    """rmsnorm with bf16 cotangents (fp32 row stats only)."""
    return _rmsnorm_lowmem(p["scale"], x, eps)


def init_layernorm(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm_apply(p, x, *, eps=1e-5):
    orig = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(orig)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def leaky_relu(x, alpha=0.1):
    return jnp.where(x >= 0, x, alpha * x)


def init_prelu(ch, dtype=jnp.float32, init=0.25):
    return {"alpha": jnp.full((ch,), init, dtype)}


def prelu_apply(p, x):
    a = p["alpha"].astype(x.dtype)
    return jnp.where(x >= 0, x, a * x)


def squared_relu(x):
    return jnp.square(jax.nn.relu(x))


def swiglu(gate, up):
    return jax.nn.silu(gate) * up


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
