"""Deterministic synthetic data pipelines.

Two generators:
  * shapes  — the Tier-A detection-proxy image task (DESIGN.md §6): each image
    contains one dominant geometric shape (class label) plus clutter; the CNN
    must classify the shape. Trends in accuracy-vs-(C, n) are what the paper's
    mAP curves measure, at reduced scale.
  * tokens  — LM token streams with long-range structure (a stationary
    Markov-ish mixture + copy spans) so LM losses move meaningfully during the
    examples and smoke tests.

Both are pure functions of (seed, step): restarting a job mid-stream reproduces
exactly the same batches, which the checkpoint/resume test relies on; and each
host in a multi-host launch slices its own rows via :func:`host_shard_slice`.
"""
from __future__ import annotations

from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Images — shape classification proxy
# ---------------------------------------------------------------------------

class ShapesDatasetConfig(NamedTuple):
    image_size: int = 64
    num_classes: int = 8
    batch_size: int = 16
    noise: float = 0.15


def _render_shapes(key, cfg: ShapesDatasetConfig):
    """Render a batch of images on-device: class k = ring of k+3 blobs."""
    b, s = cfg.batch_size, cfg.image_size
    k_lbl, k_pos, k_rad, k_noise, k_col = jax.random.split(key, 5)
    labels = jax.random.randint(k_lbl, (b,), 0, cfg.num_classes)
    cx = jax.random.uniform(k_pos, (b, 2), minval=0.3, maxval=0.7) * s
    radius = jax.random.uniform(k_rad, (b,), minval=0.15, maxval=0.3) * s
    colors = jax.random.uniform(k_col, (b, 3), minval=0.4, maxval=1.0)

    yy, xx = jnp.mgrid[0:s, 0:s]

    def render_one(label, c, r, col):
        n_blobs = label + 3
        ang = jnp.arange(12) * (2 * jnp.pi / jnp.maximum(n_blobs, 1))
        active = jnp.arange(12) < n_blobs
        bx = c[0] + r * jnp.cos(ang)
        by = c[1] + r * jnp.sin(ang)
        d2 = (xx[None] - bx[:, None, None]) ** 2 + (yy[None] - by[:, None, None]) ** 2
        blob = jnp.exp(-d2 / (2 * (0.06 * s) ** 2)) * active[:, None, None]
        img = jnp.max(blob, axis=0)
        return img[..., None] * col[None, None, :]

    imgs = jax.vmap(render_one)(labels, cx, radius, colors)
    imgs = imgs + cfg.noise * jax.random.normal(k_noise, imgs.shape)
    return imgs.astype(jnp.float32), labels


def shapes_batch_iterator(cfg: ShapesDatasetConfig, seed: int = 0,
                          start_step: int = 0) -> Iterator[tuple]:
    render = jax.jit(lambda k: _render_shapes(k, cfg))
    step = start_step
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        yield render(key)
        step += 1


# ---------------------------------------------------------------------------
# Tokens — LM stream
# ---------------------------------------------------------------------------

class TokenDatasetConfig(NamedTuple):
    vocab_size: int = 32000
    seq_len: int = 512
    batch_size: int = 8
    copy_span: int = 32       # inject copy structure: x[t] = x[t - copy_span]
    copy_prob: float = 0.5


def _token_batch(key, cfg: TokenDatasetConfig):
    k1, k2 = jax.random.split(key)
    # base: per-sequence "topic" restricts tokens to a narrow band -> learnable
    topics = jax.random.randint(k1, (cfg.batch_size, 1), 0,
                                max(cfg.vocab_size // 256, 1))
    base = topics * 256 + jax.random.randint(
        k2, (cfg.batch_size, cfg.seq_len + 1), 0, min(256, cfg.vocab_size))
    base = jnp.minimum(base, cfg.vocab_size - 1)
    # copy structure
    rolled = jnp.roll(base, cfg.copy_span, axis=1)
    mask = jax.random.bernoulli(jax.random.fold_in(key, 7),
                                cfg.copy_prob, base.shape)
    pos_ok = jnp.arange(cfg.seq_len + 1)[None, :] >= cfg.copy_span
    seq = jnp.where(mask & pos_ok, rolled, base)
    return {"tokens": seq[:, :-1].astype(jnp.int32),
            "labels": seq[:, 1:].astype(jnp.int32)}


def token_batch_iterator(cfg: TokenDatasetConfig, seed: int = 0,
                         start_step: int = 0) -> Iterator[dict]:
    gen = jax.jit(lambda k: _token_batch(k, cfg))
    step = start_step
    while True:
        yield gen(jax.random.fold_in(jax.random.PRNGKey(seed), step))
        step += 1


# ---------------------------------------------------------------------------
# Video — temporally correlated camera frames
# ---------------------------------------------------------------------------

def correlated_frames(n_frames: int, *, image_size: int = 32,
                      num_classes: int = 8, drift: float = 0.03,
                      noise: float = 0.02, seed: int = 0) -> np.ndarray:
    """A synthetic camera clip: one scene slowly drifting, (N, S, S, 3).

    Consecutive frames share almost all content — the scene (a rendered
    shapes image) translates by a random sub-pixel-ish walk of scale
    ``drift * image_size`` per frame and picks up a little fresh sensor
    noise. This is the temporal redundancy the session codec's P-frames
    exploit: quantized split activations of adjacent frames differ in few,
    small code steps, so their delta entropy-codes far below an I-frame.

    Pure function of the seed (host-side numpy), deterministic across runs.
    """
    if n_frames < 1:
        raise ValueError("need at least one frame")
    rng = np.random.default_rng(seed)
    cfg = ShapesDatasetConfig(image_size=image_size, num_classes=num_classes,
                              batch_size=1, noise=0.0)
    base, _ = _render_shapes(jax.random.PRNGKey(seed), cfg)
    base = np.asarray(base[0])                       # (S, S, 3)
    frames = np.empty((n_frames, image_size, image_size, 3), np.float32)
    off = np.zeros(2)
    for i in range(n_frames):
        off += rng.normal(scale=drift * image_size, size=2)
        shift = np.round(off).astype(int)
        img = np.roll(base, shift, axis=(0, 1))
        img = img + rng.normal(scale=noise, size=img.shape)
        frames[i] = img.astype(np.float32)
    return frames


# ---------------------------------------------------------------------------
# Multi-host sharding
# ---------------------------------------------------------------------------

def host_shard_slice(batch, host_index: int, host_count: int):
    """Slice a global batch to this host's rows (data-parallel input feed)."""
    def slc(x):
        per = x.shape[0] // host_count
        return x[host_index * per:(host_index + 1) * per]
    return jax.tree.map(slc, batch)
