from repro.data.synthetic import (ShapesDatasetConfig, shapes_batch_iterator,
                                  TokenDatasetConfig, token_batch_iterator,
                                  host_shard_slice)
