"""shard_map collectives: sequence-sharded flash-decode attention.

Problem (EXPERIMENTS.md §Perf hillclimb 2): when kv_heads doesn't divide the
model axis (qwen2-7b: 4 kv heads on a 16-way axis), the KV cache is sharded
over the SEQUENCE dim. Under plain pjit, the decode step's
dynamic-update-slice at a runtime position forces XLA to ALL-GATHER the whole
cache every token (37.6 GB/chip/token for qwen2-7b @32k×128).

Fix: express the decode attention as shard_map over the model axis —
  * each chip holds its local sequence shard of K/V,
  * the new token's K/V is written by exactly the chip whose shard covers
    position ``length`` (local DUS, no collective),
  * each chip computes a partial softmax (running max/normalizer) over its
    shard, and the partials combine with one tiny psum/pmax — the classic
    flash-decode merge. Wire bytes per token: O(B·H·hd) instead of the cache.

q/k/v/new-token inputs are replicated across the model axis (they are
KB-sized); only the cache is distributed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

NEG_INF = -1e30


def _local_update(cache, new, length, axis: str, s_local: int):
    """Write ``new`` (B, 1, K, hd) at global position ``length`` if it falls
    inside this chip's shard; otherwise leave the shard untouched."""
    idx = jax.lax.axis_index(axis)
    local_pos = length - idx * s_local
    in_shard = (local_pos >= 0) & (local_pos < s_local)
    pos = jnp.clip(local_pos, 0, s_local - 1)
    # select on the UPDATE (1 token), not the whole cache — the whole-cache
    # jnp.where would materialize a full cache copy per layer per step
    cur = jax.lax.dynamic_slice_in_dim(cache, pos, 1, axis=1)
    upd = jnp.where(in_shard, new.astype(cache.dtype), cur)
    return jax.lax.dynamic_update_slice_in_dim(cache, upd, pos, axis=1)


def _partial_attention(q, k, v, length, axis: str, s_local: int):
    """Partial softmax over the local shard. q: (B,H,1,hd); k/v: (B,S_loc,K,hd).
    Returns combined output (B, H, hd) after the cross-shard merge."""
    b, h, _, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    idx = jax.lax.axis_index(axis)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qg = q.reshape(b, kh, g, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, kf) * scale   # (B,K,g,S_loc)
    valid = (jnp.arange(s_local) + idx * s_local) <= length
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)

    m_loc = jnp.max(scores, axis=-1)                          # (B,K,g)
    p = jnp.exp(scores - m_loc[..., None])
    l_loc = jnp.sum(p, axis=-1)
    o_loc = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))

    m_glob = jax.lax.pmax(m_loc, axis)
    corr = jnp.exp(m_loc - m_glob)
    l_glob = jax.lax.psum(l_loc * corr, axis)
    o_glob = jax.lax.psum(o_loc * corr[..., None], axis)
    out = o_glob / jnp.maximum(l_glob, 1e-20)[..., None]
    return out.reshape(b, h * hd)


def seq_sharded_decode_attention(q, cache_k, cache_v, new_k, new_v, length,
                                 mesh, *, axis: str = "model",
                                 batch_spec=None):
    """One-token attention against a sequence-sharded KV cache.

    q: (B, H, hd) current query (RoPE applied), replicated over ``axis``.
    cache_k/v: (B, S, K, hd) sharded P(batch_spec, axis, None, None).
    new_k/v: (B, K, hd) this token's K/V, replicated over ``axis``.
    Returns (out (B, H*hd) f32, new_cache_k, new_cache_v).
    """
    s = cache_k.shape[1]
    n_shards = mesh.shape[axis]
    s_local = s // n_shards
    # only ``axis`` is manual inside the shard_map; the batch/data sharding
    # stays automatic (pjit handles it outside), so specs mention only axis.
    cache_spec = P(None, axis, None, None)
    rep = P()

    def f(qf, ck, cv, nk, nv, ln):
        ck = _local_update(ck, nk[:, None], ln, axis, s_local)
        cv = _local_update(cv, nv[:, None], ln, axis, s_local)
        out = _partial_attention(qf[:, :, None, :], ck, cv, ln, axis, s_local)
        return out, ck, cv

    return shard_map(
        f, mesh=mesh,
        in_specs=(rep, cache_spec, cache_spec, rep, rep, P()),
        out_specs=(rep, cache_spec, cache_spec),
        axis_names={axis}, check_vma=False,
    )(q, cache_k, cache_v, new_k, new_v, length)
