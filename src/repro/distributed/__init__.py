from repro.distributed.api import (axis_ctx, logical_axes, shard_hidden,
                                   current_rules, AxisRules,
                                   flash_decode_ctx, current_flash_decode)
