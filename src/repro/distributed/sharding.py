"""Parameter / optimizer-state / cache PartitionSpecs.

2D "megatron + ZeRO-3" layout: the tensor dimension of every large matrix is
sharded over the ``model`` axis and the remaining dimension over ``data``
(fully-sharded parameters; XLA all-gathers per layer inside the scanned body).
Optimizer state reuses the param spec verbatim (optim/adamw.py state is
congruent with params by construction).

Rules are name-based on the param-tree path, with a divisibility guard: a dim
is only sharded if the mesh axis size divides it (e.g. whisper's 51865 vocab
stays replicated). ``serve_weight_sharding='tp'`` drops the data-axis factor
for decode (weights stay resident, no per-layer all-gather).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path-substring, spec template) — first match wins. Templates use logical
# names resolved against the mesh: 'M' = model axis, 'D' = data/fsdp axis.
# Position in the template aligns with the LAST ndim dims of the leaf (the
# leading stacked-layer dim, if any, is always unsharded).
_RULES = [
    # embeddings / heads
    (("embed",),       ("M", "D")),
    (("dec_embed",),   ("M", "D")),
    (("dec_pos",),     (None, "D")),
    (("lm_head",),     ("D", "M")),
    # attention
    (("attn", "wq"),   ("D", "M")),
    (("attn", "wk"),   ("D", "M")),
    (("attn", "wv"),   ("D", "M")),
    (("attn", "wo"),   ("M", "D")),
    (("xattn", "wq"),  ("D", "M")),
    (("xattn", "wk"),  ("D", "M")),
    (("xattn", "wv"),  ("D", "M")),
    (("xattn", "wo"),  ("M", "D")),
    (("attn", "bq"),   ("M",)),
    (("attn", "bk"),   ("M",)),
    (("attn", "bv"),   ("M",)),
    (("xattn", "bq"),  ("M",)),
    (("xattn", "bk"),  ("M",)),
    (("xattn", "bv"),  ("M",)),
    # MoE (leading expert dim -> model axis = expert parallelism)
    (("moe", "router"), ("D", None)),
    (("moe", "wup"),    ("M", "D", None)),
    (("moe", "wgate"),  ("M", "D", None)),
    (("moe", "wdown"),  ("M", None, "D")),
    # dense FFN (also matches arctic's moe.dense residual)
    (("wgate",),       ("D", "M")),
    (("wup",),         ("D", "M")),
    (("wdown",),       ("M", "D")),
    # rwkv6
    (("mix_w1",),      ("D", None)),
    (("mix_w2",),      (None, None, "D")),
    (("wd_a",),        ("D", None)),
    (("wd_b",),        (None, "D")),
    (("cm_wk",),       ("D", "M")),
    (("cm_wv",),       ("M", "D")),
    (("cm_wr",),       ("D", "M")),
    (("wr",),          ("D", "M")),
    (("wg",),          ("D", "M")),
    (("wo",),          ("M", "D")),
    (("wk",),          ("D", "M")),
    (("wv",),          ("D", "M")),
    # mamba2
    (("in_proj",),     ("D", "M")),
    (("out_proj",),    ("M", "D")),
    (("conv_w",),      (None, "M")),
    (("conv_b",),      ("M",)),
    (("gate_norm",),   ("M",)),
    # BaF stream predictor (pod-boundary compression)
    (("l1", "w"),      ("D", "M")),
    (("l2", "w"),      ("M", "D")),
    (("l3", "w"),      ("D", "M")),
    (("l4", "w"),      ("M", "D")),
]


def _path_names(path) -> tuple:
    out = []
    for k in path:
        if hasattr(k, "key"):          # DictKey
            out.append(str(k.key))
        elif hasattr(k, "name"):       # GetAttrKey (NamedTuple fields)
            out.append(str(k.name))
        elif hasattr(k, "idx"):        # SequenceKey
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= mesh.shape[a]
        return n
    return mesh.shape[name]


def param_pspec(path, leaf, mesh: Mesh, *, model_axis="model",
                data_axis: Optional[str] = "data") -> P:
    names = _path_names(path)
    shape = leaf.shape
    for keys, tmpl in _RULES:
        if all(any(k == n for n in names) for k in keys):
            ndim = len(shape)
            nt = len(tmpl)
            if nt > ndim:     # template longer than leaf (unstacked variant)
                tmpl = tmpl[-ndim:]
                nt = len(tmpl)
            spec = [None] * ndim
            for i, t in enumerate(tmpl):
                dim = ndim - nt + i
                if t is None:
                    continue
                ax = model_axis if t == "M" else data_axis
                if ax is None:
                    continue
                if shape[dim] % _axis_size(mesh, ax) == 0 and shape[dim] > 1:
                    spec[dim] = ax
            return P(*spec)
    return P()   # norms, scalars, small tables: replicated


def params_pspecs(params, mesh: Mesh, *, data_axis="data"):
    """Pytree of PartitionSpecs congruent with ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_pspec(p, l, mesh, data_axis=data_axis), params)


def params_shardings(params, mesh: Mesh, *, data_axis="data"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        params_pspecs(params, mesh, data_axis=data_axis))


def opt_state_pspecs(opt_state, params_specs):
    """AdamW state: count replicated, mu/nu congruent with params."""
    from repro.optim.adamw import AdamWState
    return AdamWState(count=P(), mu=params_specs, nu=params_specs)


# ---------------------------------------------------------------------------
# Activations / batches / caches
# ---------------------------------------------------------------------------

def batch_pspec(global_batch: int, mesh: Mesh, *, multi_pod: bool):
    """Shard the batch over (pod, data) when divisible; drop axes otherwise
    (long_500k's batch=1 stays replicated)."""
    axes = (("pod", "data") if multi_pod else ("data",))
    usable = []
    prod = 1
    for a in axes:
        if global_batch % (prod * mesh.shape[a]) == 0:
            usable.append(a)
            prod *= mesh.shape[a]
    if not usable:
        return None
    return tuple(usable) if len(usable) > 1 else usable[0]


def cache_pspecs(cache, mesh: Mesh, batch_axes, *, model_axis="model",
                 seq_fallback: bool = True):
    """KV caches: (L, B, S, K, hd) -> batch over data/pod, kv-heads over model
    when divisible; when not divisible, the sequence dim goes over model
    (flash-decode combine) if ``seq_fallback`` else the cache is replicated
    across model (per-chip copy; no collective on the decode path —
    EXPERIMENTS.md §Perf hillclimb lever).
    SSM states: (L, B, H, dk, dv) -> batch + heads-if-divisible."""
    msize = mesh.shape[model_axis]

    def spec(path, leaf):
        names = _path_names(path)
        nd = leaf.ndim
        if "length" in names or "pos" in names or nd <= 1:
            return P()
        s = [None] * nd
        # leading dim is the stacked-layer axis (L); batch is dim 1
        if nd >= 2 and batch_axes is not None and \
                leaf.shape[1] % int(np.prod([mesh.shape[a] for a in
                                             (batch_axes if isinstance(batch_axes, tuple)
                                              else (batch_axes,))])) == 0:
            s[1] = batch_axes
        if any(n in ("k", "v", "cross_k", "cross_v", "shared_k", "shared_v")
               for n in names) and nd == 5:
            # (L, B, S, K, hd)
            if leaf.shape[3] % msize == 0:
                s[3] = model_axis
            elif seq_fallback and leaf.shape[2] % msize == 0:
                s[2] = model_axis
        elif "wkv" in names or "ssm" in names:
            # (L, B, H, dk, dv): shard value dim over model (heads rarely divide)
            if leaf.shape[2] % msize == 0:
                s[2] = model_axis
            elif leaf.shape[-1] % msize == 0:
                s[-1] = model_axis
        elif "conv" in names and nd == 4:
            if leaf.shape[-1] % msize == 0:
                s[-1] = model_axis
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, cache)
