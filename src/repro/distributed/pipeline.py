"""Pod-boundary activation compression (DESIGN.md §2 Tier C).

In multi-pod pipeline mode the hidden state crossing the ``pod`` axis rides
the slowest link in the system (inter-pod DCN, O(10 GB/s) vs 819 GB/s HBM).
This module maps the paper's scheme onto that hop:

  sender pod:   per-channel n-bit quantization (eq. 4) of the (B, S, D) hidden
                stream -> uint8 codes + fp16 side info     [kernels/quantize]
  wire:         jax.lax.ppermute of codes + side info over the ``pod`` axis —
                n/16 of the bf16 bytes (4x fewer at n=8, 8x at n=4)
  receiver pod: dequantize (eq. 5), then optionally BaF-restore: the receiver
                re-applies its FROZEN first block to the backward-predicted
                input and consolidates the transmitted channels (eq. 6) —
                the paper's exact back-and-forth, with "layer l" = the
                pipeline-stage boundary block.

Implemented with jax.shard_map over ONLY the pod axis so it composes with the
surrounding pjit sharding of batch/model dims (same pattern as
optim/grad_compress.py).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.baf import baf_stream_predict
from repro.core.quant import QuantParams


def _quantize_stream(x: jax.Array, bits: int):
    """(..., D) -> (codes uint8, mins f16 (D,), maxs f16 (D,)); per-channel
    stats over all leading dims (one side-info row per transfer)."""
    levels = (1 << bits) - 1
    axes = tuple(range(x.ndim - 1))
    # widen the fp16-rounded max to the next representable, saturating at
    # finite fp16 (±65504): an inf bound zeroes every code and restores NaN
    # on the receiving pod (same fix as core/quant + kernels/quantize).
    f16_max = jnp.asarray(65504.0, jnp.float16)
    mn = jnp.maximum(jnp.min(x, axis=axes).astype(jnp.float16), -f16_max)
    mx = jnp.max(x, axis=axes).astype(jnp.float16)
    mx = jnp.minimum(
        jnp.maximum(mx, jnp.nextafter(mx, jnp.asarray(jnp.inf, jnp.float16))),
        f16_max)
    m = mn.astype(jnp.float32)
    rng = jnp.maximum(mx.astype(jnp.float32) - m, 1e-12)
    scaled = (x.astype(jnp.float32) - m) / rng * levels
    codes = jnp.clip(jnp.round(scaled), 0, levels).astype(jnp.uint8)
    return codes, mn, mx


def _dequantize_stream(codes, mn, mx, bits: int, dtype):
    levels = (1 << bits) - 1
    m = mn.astype(jnp.float32)
    return (codes.astype(jnp.float32) / levels
            * (mx.astype(jnp.float32) - m) + m).astype(dtype)


def wire_bytes(x: jax.Array, bits: int) -> tuple[int, int]:
    """(compressed, uncompressed-bf16) DCN bytes for one transfer of x."""
    d = x.shape[-1]
    comp = x.size * bits // 8 + d * 4       # codes + fp16 min/max
    return comp, x.size * 2


def compressed_pod_transfer(x: jax.Array, mesh, *, bits: int = 8,
                            pod_axis: str = "pod",
                            perm: Optional[list] = None,
                            dtype=jnp.bfloat16) -> jax.Array:
    """Move the hidden stream one pod forward with n-bit codes on the wire.

    x: (B, S, D) (arbitrarily sharded over data/model inside each pod —
    shard_map only binds the pod axis). Returns the received, dequantized
    tensor on the next pod. perm defaults to the ring (i -> i+1).
    """
    npod = mesh.shape[pod_axis]
    perm = perm or [(i, (i + 1) % npod) for i in range(npod)]

    def f(xl):
        codes, mn, mx = _quantize_stream(xl, bits)
        codes = jax.lax.ppermute(codes, pod_axis, perm)
        mn = jax.lax.ppermute(mn, pod_axis, perm)
        mx = jax.lax.ppermute(mx, pod_axis, perm)
        return _dequantize_stream(codes, mn, mx, bits, dtype)

    return shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                     axis_names={pod_axis}, check_vma=False)(x)


def baf_restore_stream(z_hat: jax.Array, *, baf_params, forward_fn: Callable,
                       sel_idx, codes=None, qp: QuantParams | None = None,
                       dtype=None) -> jax.Array:
    """Receiver-side BaF restoration for a C-channel-subset transfer.

    z_hat: (B, S, C) dequantized transmitted channels. forward_fn is the
    receiver's frozen boundary block; returns all-D-channel estimate with the
    transmitted channels consolidated (eq. 6) when codes are supplied.
    """
    return baf_stream_predict(baf_params, forward_fn, sel_idx, z_hat,
                              codes=codes, qp=qp, dtype=dtype)


def subset_pod_transfer(x: jax.Array, mesh, *, sel_idx, baf_params,
                        forward_fn: Callable, bits: int = 8,
                        pod_axis: str = "pod", consolidation: bool = True,
                        dtype=jnp.bfloat16) -> jax.Array:
    """The paper's full scheme on the pod boundary: transmit only the selected
    C channels, quantized; restore all D channels on the receiving pod via
    back-and-forth prediction. Wire bytes: C/D · n/16 of the bf16 transfer."""
    npod = mesh.shape[pod_axis]
    perm = [(i, (i + 1) % npod) for i in range(npod)]
    sel = jnp.asarray(sel_idx, jnp.int32)

    def f(xl):
        z_sel = xl[..., sel]
        codes, mn, mx = _quantize_stream(z_sel, bits)
        codes = jax.lax.ppermute(codes, pod_axis, perm)
        mn = jax.lax.ppermute(mn, pod_axis, perm)
        mx = jax.lax.ppermute(mx, pod_axis, perm)
        z_hat = _dequantize_stream(codes, mn, mx, bits, dtype)
        qp = QuantParams(mins=mn, maxs=mx, bits=bits)
        return baf_restore_stream(
            z_hat, baf_params=baf_params, forward_fn=forward_fn, sel_idx=sel,
            codes=codes if consolidation else None,
            qp=qp if consolidation else None, dtype=dtype).astype(dtype)

    return shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                     axis_names={pod_axis}, check_vma=False)(x)
