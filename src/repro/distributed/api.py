"""Logical-axis sharding shim.

Models annotate activations with *logical* axis names ("batch", "seq",
"hidden", "heads", "ffn", "experts", "vocab"); the launch layer binds those to
physical mesh axes with an :class:`AxisRules` context. Outside any context the
annotations are no-ops, so the exact same model code runs single-device smoke
tests and 512-chip dry-runs.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class AxisRules:
    """logical axis name -> physical mesh axis (str, tuple of str, or None)."""
    rules: dict = field(default_factory=dict)

    def spec(self, *logical: Optional[str]) -> P:
        return P(*[self.rules.get(a) if a else None for a in logical])


_state = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def axis_ctx(rules: AxisRules):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def logical_axes(*names: Optional[str]) -> Optional[P]:
    r = current_rules()
    return r.spec(*names) if r is not None else None


def shard_hidden(x, *names: Optional[str]):
    """with_sharding_constraint by logical axis names; no-op w/o a context."""
    spec = logical_axes(*names)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# Flash-decode context -------------------------------------------------------
# When set, attention_decode routes the KV-cache update + softmax through the
# shard_map flash-decode (distributed/collectives.py) instead of plain pjit —
# the sequence-sharded cache is never all-gathered.

@dataclass(frozen=True)
class FlashDecode:
    mesh: object
    axis: str = "model"
    batch_spec: object = "data"


def current_flash_decode() -> Optional[FlashDecode]:
    return getattr(_state, "flash_decode", None)


@contextlib.contextmanager
def flash_decode_ctx(mesh, *, axis: str = "model", batch_spec="data"):
    prev = getattr(_state, "flash_decode", None)
    _state.flash_decode = FlashDecode(mesh=mesh, axis=axis,
                                      batch_spec=batch_spec)
    try:
        yield
    finally:
        _state.flash_decode = prev


# Canonical rule sets -------------------------------------------------------

def train_rules(multi_pod: bool, *, seq_parallel: bool = True) -> AxisRules:
    """Training: batch -> (pod,)data; tensor dims -> model; fsdp -> data.

    seq_parallel=False leaves the residual stream replicated across the model
    axis (plain Megatron TP) — trades per-chip activation memory for the
    per-layer activation all-gathers that act_hidden sharding implies
    (EXPERIMENTS.md §Perf hillclimb lever)."""
    batch = ("pod", "data") if multi_pod else ("data",)
    return AxisRules(rules={
        "batch": batch,
        "seq": None,
        "act_hidden": "model" if seq_parallel else None,
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "experts": "model",
        "ffn_expert": None,      # expert F dim: expert dim already on model
        "vocab": "model",
        "fsdp": "data",
        "seq_model": "model",    # KV-cache / long-context seq sharding
    })


def serve_rules(multi_pod: bool, *, weight_mode: str = "2d",
                seq_parallel: bool = True) -> AxisRules:
    """Serving: like training but batch never crosses pods for one request
    wave; weight_mode '2d' keeps fsdp sharding (all-gather per layer),
    'tp' keeps weights only tensor-sharded (fsdp unbound)."""
    batch = ("pod", "data") if multi_pod else ("data",)
    return AxisRules(rules={
        "batch": batch,
        "seq": None,
        "act_hidden": "model" if seq_parallel else None,
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "experts": "model",
        "ffn_expert": None,
        "vocab": "model",
        "fsdp": "data" if weight_mode == "2d" else None,
        "seq_model": "model",
    })
