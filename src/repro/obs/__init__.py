"""Observability for the serving stack: deterministic virtual-clock traces,
mergeable metrics, zero-cost stage hooks, and schema'd benchmark records.

  * :mod:`repro.obs.trace`   — span trees on the gateway's virtual clock,
    exported as Chrome/Perfetto trace-event JSON; byte-identical under
    replay with a deterministic cost model.
  * :mod:`repro.obs.metrics` — counters, gauges, mergeable log-bucket
    histograms; Prometheus-style text dump.
  * :mod:`repro.obs.hooks`   — process-global ``timed``/``observe`` hooks
    for deep pipeline/codec code; strict no-ops until a registry is
    installed.
  * :mod:`repro.obs.bench`   — ``BENCH_<name>.json`` schema + regression
    comparison (driven by benchmarks/compare.py).

Imports only stdlib + numpy-free modules; safe to import from anywhere in
the package (pipeline and codec depend on it via hooks).
"""
from repro.obs.bench import (SCHEMA_VERSION, bench_record, compare,
                             format_report, load_bench, metric, write_bench)
from repro.obs.metrics import (GROWTH, Counter, Gauge, LogHistogram,
                               MetricsRegistry)
from repro.obs.trace import (Span, Tracer, reconcile_trace,
                             validate_chrome_trace)

__all__ = [
    "SCHEMA_VERSION", "bench_record", "compare", "format_report",
    "load_bench", "metric", "write_bench",
    "GROWTH", "Counter", "Gauge", "LogHistogram", "MetricsRegistry",
    "Span", "Tracer", "reconcile_trace", "validate_chrome_trace",
]
