"""Low-overhead metrics: counters, gauges, and mergeable log-bucket
histograms, with a Prometheus-style text dump.

This is the aggregation layer under :class:`repro.serve.telemetry.Telemetry`
and the stage-timer hooks (:mod:`repro.obs.hooks`). Design constraints, in
order:

  * **Recording is cheap.** ``Counter.inc`` / ``Histogram.observe`` are a
    dict lookup plus a couple of float ops — no locks, no label-string
    formatting, no allocation on the hot path once a series exists. Callers
    on hot loops should hold the metric object (returned by
    ``registry.counter(...)``) instead of re-resolving it per event.
  * **Histograms are mergeable.** :class:`LogHistogram` buckets observations
    on a geometric grid, so two histograms (per-tenant, per-shard, per-run)
    merge by adding bucket counts — the property the store-every-record
    numpy percentile path lacks. Memory is O(occupied buckets), not
    O(observations), which is what makes long serving runs affordable.
  * **Bounded percentile error.** With the default ``growth = 2**(1/8)``
    a bucket spans ~9% of relative range; the nearest-rank percentile read
    off the bucket grid is within one bucket (<= ~9% relative) of the exact
    sample percentile, and exact min/max clamping makes single-observation
    (and p0/p100) reads exact.
  * **Deterministic text dump.** ``to_prometheus_text`` orders families and
    series lexicographically so dumps diff cleanly across runs.

No JAX, no serve imports — anything may depend on this module.
"""
from __future__ import annotations

import math

# Default bucket growth factor: 8 buckets per octave (~9.05% wide buckets,
# ~4.4% worst-case error at the geometric bucket midpoint).
GROWTH = 2.0 ** 0.125


class Counter:
    """Monotonically increasing value."""
    kind = "counter"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter increments must be >= 0, got {v}")
        self.value += v


class Gauge:
    """Last-written value (queue depth, utilization, backlog)."""
    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class LogHistogram:
    """Mergeable histogram over geometric (log-spaced) buckets.

    Bucket ``b`` holds values in ``[growth**b, growth**(b+1))``; zeros get
    their own bucket. Exact ``count`` / ``total`` / ``vmin`` / ``vmax`` ride
    alongside the bucket counts, so means are exact and percentile reads are
    clamped into the observed range (a single observation reports exactly
    itself at any percentile).
    """
    kind = "histogram"
    __slots__ = ("growth", "_lg", "buckets", "zero_count", "count", "total",
                 "vmin", "vmax")

    def __init__(self, growth: float = GROWTH):
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        self.growth = float(growth)
        self._lg = math.log(self.growth)
        self.buckets: dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def bucket_index(self, v: float) -> int:
        # small epsilon keeps exact powers of `growth` in their own bucket
        # despite log() rounding
        return int(math.floor(math.log(v) / self._lg + 1e-9))

    def observe(self, v: float) -> None:
        v = float(v)
        if v < 0 or math.isnan(v):
            raise ValueError(f"histogram observations must be >= 0, got {v}")
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v == 0.0:
            self.zero_count += 1
        else:
            b = self.bucket_index(v)
            self.buckets[b] = self.buckets.get(b, 0) + 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    # -- merging -------------------------------------------------------------
    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """Fold ``other`` into self (bucket grids must match)."""
        if abs(other.growth - self.growth) > 1e-12:
            raise ValueError(f"cannot merge histograms with different bucket "
                             f"growth ({self.growth} vs {other.growth})")
        for b, n in other.buckets.items():
            self.buckets[b] = self.buckets.get(b, 0) + n
        self.zero_count += other.zero_count
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    @classmethod
    def merged(cls, hists) -> "LogHistogram":
        """A fresh histogram holding the union of ``hists``."""
        hists = list(hists)
        out = cls(growth=hists[0].growth if hists else GROWTH)
        for h in hists:
            out.merge(h)
        return out

    # -- percentiles ---------------------------------------------------------
    def percentile(self, p: float) -> float:
        """Nearest-rank percentile off the bucket grid.

        Matches ``numpy.percentile(..., method="higher")`` to within one
        bucket (<= ``growth - 1`` relative error), exactly at the observed
        min/max. Raises on an empty histogram — an explicit error beats a
        silent NaN.
        """
        if self.count == 0:
            raise ValueError("no observations")
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile must be in [0, 100], got {p}")
        rank = max(1, math.ceil(p / 100.0 * self.count))   # nearest rank
        if rank >= self.count:
            return float(self.vmax)       # the max observation is exact
        seen = self.zero_count
        if rank <= seen:
            return 0.0
        for b in sorted(self.buckets):
            seen += self.buckets[b]
            if rank <= seen:
                rep = self.growth ** (b + 0.5)             # geometric middle
                return float(min(max(rep, self.vmin), self.vmax))
        return float(self.vmax)                            # numeric safety


class MetricsRegistry:
    """Keyed store of metric series: ``(name, sorted label items)`` -> metric.

    ``counter`` / ``gauge`` / ``histogram`` are get-or-create; re-registering
    a name with a different metric kind is an error (one name, one kind, as
    in Prometheus). ``collect`` and ``to_prometheus_text`` iterate in sorted
    order so output is deterministic.
    """

    def __init__(self):
        self._series: dict[tuple, object] = {}

    def _get(self, cls, name: str, labels: dict, **kwargs):
        key = (name, tuple(sorted(labels.items())))
        m = self._series.get(key)
        if m is None:
            m = cls(**kwargs)
            self._series[key] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, growth: float = GROWTH,
                  **labels) -> LogHistogram:
        return self._get(LogHistogram, name, labels, growth=growth)

    def get(self, name: str, **labels):
        """The existing series, or None — never creates."""
        return self._series.get((name, tuple(sorted(labels.items()))))

    def __len__(self) -> int:
        return len(self._series)

    def collect(self):
        """Yield ``(name, labels_dict, metric)`` in deterministic order."""
        for (name, labels) in sorted(self._series):
            yield name, dict(labels), self._series[(name, labels)]

    def histograms(self, name: str):
        """All histogram series registered under ``name`` (any labels)."""
        return [m for n, _, m in self.collect()
                if n == name and isinstance(m, LogHistogram)]

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold another registry's series into this one (shard fan-in):
        counters add, gauges take the other's value, histograms merge."""
        for key, m in other._series.items():
            mine = self._series.get(key)
            if mine is None:
                if isinstance(m, LogHistogram):
                    mine = LogHistogram(growth=m.growth)
                else:
                    mine = type(m)()
                self._series[key] = mine
            if isinstance(m, Counter):
                mine.inc(m.value)
            elif isinstance(m, Gauge):
                mine.set(m.value)
            else:
                mine.merge(m)
        return self

    # -- text dump -----------------------------------------------------------
    @staticmethod
    def _fmt_labels(labels: dict, extra: dict | None = None) -> str:
        items = dict(labels)
        if extra:
            items.update(extra)
        if not items:
            return ""

        def esc(v) -> str:
            return str(v).replace("\\", "\\\\").replace('"', '\\"')

        body = ",".join(f'{k}="{esc(v)}"' for k, v in sorted(items.items()))
        return "{" + body + "}"

    def to_prometheus_text(self) -> str:
        """Prometheus exposition-style dump, deterministically ordered."""
        lines: list[str] = []
        seen_type: set[str] = set()
        for name, labels, m in self.collect():
            if name not in seen_type:
                seen_type.add(name)
                lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, (Counter, Gauge)):
                lines.append(f"{name}{self._fmt_labels(labels)} "
                             f"{m.value:.10g}")
            else:
                cum = 0
                if m.zero_count:
                    cum += m.zero_count
                    lines.append(f"{name}_bucket"
                                 f"{self._fmt_labels(labels, {'le': '0'})} "
                                 f"{cum}")
                for b in sorted(m.buckets):
                    cum += m.buckets[b]
                    le = f"{m.growth ** (b + 1):.6g}"
                    lines.append(f"{name}_bucket"
                                 f"{self._fmt_labels(labels, {'le': le})} "
                                 f"{cum}")
                lines.append(f"{name}_bucket"
                             f"{self._fmt_labels(labels, {'le': '+Inf'})} "
                             f"{m.count}")
                lines.append(f"{name}_sum{self._fmt_labels(labels)} "
                             f"{m.total:.10g}")
                lines.append(f"{name}_count{self._fmt_labels(labels)} "
                             f"{m.count}")
        return "\n".join(lines) + ("\n" if lines else "")
