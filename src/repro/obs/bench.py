"""Schema'd benchmark records (``BENCH_<name>.json``) and the trajectory
comparison that gates on them.

Every benchmark under benchmarks/ writes one record per run:

    {
      "schema": "repro-bench/1",
      "name": "codec",
      "git_sha": "<HEAD or 'unknown'>",
      "config": {...inputs that must match for a comparison to be fair...},
      "metrics": {
        "rans_vs_zlib_8bit": {"value": 0.82, "better": "lower",
                               "tolerance": 0.05},
        ...
      },
      "raw": {...optional, full benchmark output, never compared...}
    }

``benchmarks/compare.py`` loads a current and a baseline record and fails
(exit 1) when any gated metric regressed beyond its tolerance. Rules:

  * the **baseline**'s ``tolerance`` gates; ``tolerance: null`` marks a
    metric informational (wall-clock throughputs on shared CI runners) —
    reported, never failed;
  * ``better`` gives the regression direction: ``lower`` fails when
    ``current > baseline * (1 + tol)``, ``higher`` when
    ``current < baseline * (1 - tol)``; a zero baseline compares
    absolutely against ``tol``;
  * a gated metric missing from the current record fails (a benchmark that
    silently stopped measuring something is itself a regression);
  * differing ``config`` fails unless explicitly allowed — comparing a
    smoke run against a full run is meaningless, not a pass.
"""
from __future__ import annotations

import json
import os
import subprocess
from dataclasses import dataclass

SCHEMA_VERSION = "repro-bench/1"
_BETTER = ("lower", "higher")


def git_sha(cwd: str | None = None) -> str:
    """Best-effort HEAD sha for the bench record's provenance field.

    The canonical allowlisted best-effort site (lint rule RA06, see
    docs/ANALYSIS.md): every failure mode has the same meaning — "no git
    identity available here" — and a committed fallback. Even so, the
    handler names the concrete types it expects (git missing/unrunnable ->
    OSError, nonzero exit/timeout -> SubprocessError) rather than a
    blanket ``except Exception``, so a genuine bug (say, a TypeError from
    a bad ``cwd``) still surfaces loudly.
    """
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except (subprocess.SubprocessError, OSError):
        return os.environ.get("GITHUB_SHA", "unknown")


def metric(value: float, *, better: str = "lower",
           tolerance: float | None = None) -> dict:
    """One metric entry. ``tolerance=None`` = informational (never gates)."""
    if better not in _BETTER:
        raise ValueError(f"better must be one of {_BETTER}, got {better!r}")
    if tolerance is not None and tolerance < 0:
        raise ValueError(f"tolerance must be >= 0, got {tolerance}")
    return {"value": float(value), "better": better, "tolerance": tolerance}


def bench_record(name: str, *, config: dict, metrics: dict,
                 raw=None) -> dict:
    rec = {"schema": SCHEMA_VERSION, "name": name, "git_sha": git_sha(),
           "config": config, "metrics": metrics}
    if raw is not None:
        rec["raw"] = raw
    validate_record(rec)
    return rec


def validate_record(rec) -> None:
    """Raise ValueError unless ``rec`` is a well-formed bench record."""
    if not isinstance(rec, dict):
        raise ValueError("bench record must be a JSON object")
    if rec.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"unsupported schema {rec.get('schema')!r} "
                         f"(want {SCHEMA_VERSION!r})")
    if not isinstance(rec.get("name"), str) or not rec["name"]:
        raise ValueError("bench record needs a non-empty string 'name'")
    if not isinstance(rec.get("config"), dict):
        raise ValueError("bench record needs a 'config' object")
    metrics = rec.get("metrics")
    if not isinstance(metrics, dict):
        raise ValueError("bench record needs a 'metrics' object")
    for key, m in metrics.items():
        if not isinstance(m, dict) or "value" not in m:
            raise ValueError(f"metric {key!r}: needs a 'value'")
        if not isinstance(m["value"], (int, float)):
            raise ValueError(f"metric {key!r}: value must be a number")
        if m.get("better", "lower") not in _BETTER:
            raise ValueError(f"metric {key!r}: better must be in {_BETTER}")
        tol = m.get("tolerance")
        if tol is not None and (not isinstance(tol, (int, float)) or tol < 0):
            raise ValueError(f"metric {key!r}: tolerance must be null or a "
                             f"number >= 0")


def write_bench(path, record: dict) -> None:
    validate_record(record)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
        f.write("\n")


def load_bench(path) -> dict:
    with open(path) as f:
        rec = json.load(f)
    validate_record(rec)
    return rec


# ---------------------------------------------------------------------------
# Comparison
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Delta:
    """One line of a comparison report."""
    key: str
    status: str          # ok | improved | regressed | info | missing | new
                         # | name-mismatch | config-drift
    message: str
    base: float | None = None
    cur: float | None = None

    @property
    def failed(self) -> bool:
        return self.status in ("regressed", "missing", "name-mismatch",
                               "config-drift")


def _ratio_txt(base: float, cur: float) -> str:
    if base == 0:
        return f"{base:.6g} -> {cur:.6g}"
    return f"{base:.6g} -> {cur:.6g} ({cur / base:+.1%} rel)".replace(
        "+0.0%", "+0%")


def compare(current: dict, baseline: dict, *,
            allow_config_drift: bool = False) -> tuple[bool, list[Delta]]:
    """Gate ``current`` against ``baseline``; (ok, report deltas)."""
    validate_record(current)
    validate_record(baseline)
    deltas: list[Delta] = []
    if current["name"] != baseline["name"]:
        deltas.append(Delta(
            key="name", status="name-mismatch",
            message=f"comparing {current['name']!r} against "
                    f"{baseline['name']!r}"))
        return False, deltas
    drift = sorted(k for k in set(current["config"]) | set(baseline["config"])
                   if current["config"].get(k) != baseline["config"].get(k))
    for k in drift:
        deltas.append(Delta(
            key=f"config.{k}",
            status="info" if allow_config_drift else "config-drift",
            message=f"config {k!r}: baseline "
                    f"{baseline['config'].get(k)!r} vs current "
                    f"{current['config'].get(k)!r}"))
    for key in sorted(baseline["metrics"]):
        bm = baseline["metrics"][key]
        base = float(bm["value"])
        if key not in current["metrics"]:
            tol = bm.get("tolerance")
            deltas.append(Delta(
                key=key, status="missing" if tol is not None else "info",
                base=base,
                message=f"gated metric disappeared from current record"
                if tol is not None else "informational metric not emitted"))
            continue
        cur = float(current["metrics"][key]["value"])
        better = bm.get("better", "lower")
        tol = bm.get("tolerance")
        txt = _ratio_txt(base, cur)
        if tol is None:
            deltas.append(Delta(key=key, status="info", base=base, cur=cur,
                                message=txt))
            continue
        if base == 0.0:
            bad = cur > tol if better == "lower" else cur < -tol
            good = cur < -tol if better == "lower" else cur > tol
        elif better == "lower":
            bad, good = cur > base * (1 + tol), cur < base * (1 - tol)
        else:
            bad, good = cur < base * (1 - tol), cur > base * (1 + tol)
        status = "regressed" if bad else ("improved" if good else "ok")
        deltas.append(Delta(key=key, status=status, base=base, cur=cur,
                            message=f"{txt} [tol {tol:g}, better {better}]"))
    for key in sorted(set(current["metrics"]) - set(baseline["metrics"])):
        deltas.append(Delta(
            key=key, status="new", cur=float(current["metrics"][key]["value"]),
            message="new metric (no baseline)"))
    ok = not any(d.failed for d in deltas)
    return ok, deltas


def format_report(deltas: list[Delta], *, verbose: bool = True) -> str:
    lines = []
    for d in deltas:
        if not verbose and d.status in ("ok", "info", "new"):
            continue
        lines.append(f"[{d.status.upper():>9}] {d.key}: {d.message}")
    counts: dict[str, int] = {}
    for d in deltas:
        counts[d.status] = counts.get(d.status, 0) + 1
    lines.append("summary: " + ", ".join(
        f"{v} {k}" for k, v in sorted(counts.items())))
    return "\n".join(lines)
