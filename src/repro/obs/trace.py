"""Virtual-clock span traces for the serving gateway, exported as
Chrome/Perfetto trace-event JSON.

Every span is keyed to the gateway's **virtual clock** (channel / scheduler /
executor times), never to the wall clock. Under a deterministic cost model
(``LinearCostModel``) the virtual clock depends only on the workload, so two
runs of the same workload export **byte-identical** trace JSON — the same
replay property PR 5 pinned for telemetry, now extended to traces. Wall-time
stage measurements (how long host decode actually took) belong in
:mod:`repro.obs.metrics` histograms via :mod:`repro.obs.hooks`; putting them
in a trace would destroy determinism.

Span taxonomy (see docs/OBSERVABILITY.md):

  ``request``          per served request, spanning submit->response; children
                       partition it exactly:
  ``sched.wait``         encode done -> uplink grant (DRR scheduler)
  ``channel.transmit``   uplink grant -> arrival at the cloud
  ``exec.queue``         arrival -> executor service start
  ``cloud.compute``      executor service (batched decode+restore+forward)
  ``exec.batch``       per executor ticket, on its queue's own track
  instants: ``submit``, ``edge.encode``, ``admission.shed``

The per-request children are built from the *same* floats the telemetry
record holds, summed in the same order — so per-request span durations
reconcile with ``RequestRecord.total_latency_s`` exactly (0 ulp), and the
<1e-9 s acceptance bound holds trivially. :func:`reconcile_trace` checks it.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

_US = 1e6          # trace-event timestamps are microseconds


@dataclass
class Span:
    span_id: int
    name: str
    t0: float                     # virtual seconds
    t1: float
    track: str                    # display track (maps to a Perfetto tid)
    parent: int | None = None
    attrs: dict = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


@dataclass
class Instant:
    name: str
    t: float
    track: str
    attrs: dict = field(default_factory=dict)


class Tracer:
    """Collects spans/instants on the virtual clock; exports trace-event JSON.

    Deterministic by construction: span ids are assignment-ordered, tracks
    get tids in first-use order, attributes are sorted at export, and the
    JSON dump is canonical (sorted keys, fixed separators). Emission is a
    couple of appends — cheap enough to leave on in benchmarks (the overhead
    gate in benchmarks/serve_gateway.py pins this).
    """

    def __init__(self, *, process_name: str = "repro-gateway"):
        self.process_name = process_name
        self.spans: list[Span] = []
        self.instants: list[Instant] = []
        self._children: dict[int, list[int]] = {}
        self._tids: dict[str, int] = {}

    def __len__(self) -> int:
        return len(self.spans)

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[track] = tid
        return tid

    # -- emission ------------------------------------------------------------
    def span(self, name: str, t0: float, t1: float, *, track: str = "gateway",
             parent: int | None = None, **attrs) -> int:
        """Record a closed span [t0, t1]; returns its id (usable as parent)."""
        sid = len(self.spans)
        self.spans.append(Span(span_id=sid, name=name, t0=float(t0),
                               t1=float(t1), track=track, parent=parent,
                               attrs=attrs))
        self._tid(track)
        if parent is not None:
            self._children.setdefault(parent, []).append(sid)
        return sid

    def instant(self, name: str, t: float, *, track: str = "gateway",
                **attrs) -> None:
        """Record a point event (submission, shed, encode-done)."""
        self.instants.append(Instant(name=name, t=float(t), track=track,
                                     attrs=attrs))
        self._tid(track)

    # -- structure -----------------------------------------------------------
    def children(self, span_id: int) -> list[Span]:
        return [self.spans[i] for i in self._children.get(span_id, [])]

    def roots(self, name: str | None = None) -> list[Span]:
        return [s for s in self.spans if s.parent is None
                and (name is None or s.name == name)]

    def validate(self, *, eps: float = 0.0) -> None:
        """Span-tree invariants: durations non-negative, parents exist,
        children nest inside their parents. Raises ValueError on violation."""
        n = len(self.spans)
        for s in self.spans:
            if s.t1 < s.t0:
                raise ValueError(f"span {s.span_id} ({s.name}): "
                                 f"t1 {s.t1} < t0 {s.t0}")
            if s.parent is not None:
                if not 0 <= s.parent < n:
                    raise ValueError(f"span {s.span_id} ({s.name}): "
                                     f"unknown parent {s.parent}")
                p = self.spans[s.parent]
                if s.t0 < p.t0 - eps or s.t1 > p.t1 + eps:
                    raise ValueError(
                        f"span {s.span_id} ({s.name}) "
                        f"[{s.t0}, {s.t1}] escapes parent "
                        f"{p.span_id} ({p.name}) [{p.t0}, {p.t1}]")

    # -- export --------------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome/Perfetto trace-event JSON object (load via chrome://tracing
        or ui.perfetto.dev). Timestamps are virtual-clock microseconds."""
        events: list[dict] = []
        pid = 1
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name",
                       "args": {"name": self.process_name}})
        for track, tid in sorted(self._tids.items(), key=lambda kv: kv[1]):
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name", "args": {"name": track}})
        for s in self.spans:
            args = {k: s.attrs[k] for k in sorted(s.attrs)}
            args["span_id"] = s.span_id
            if s.parent is not None:
                args["parent"] = s.parent
            events.append({"ph": "X", "pid": pid, "tid": self._tids[s.track],
                           "name": s.name, "cat": "virtual",
                           "ts": s.t0 * _US, "dur": (s.t1 - s.t0) * _US,
                           "args": args})
        for i in self.instants:
            events.append({"ph": "i", "pid": pid, "tid": self._tids[i.track],
                           "name": i.name, "cat": "virtual", "s": "t",
                           "ts": i.t * _US,
                           "args": {k: i.attrs[k] for k in sorted(i.attrs)}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        """Canonical JSON: identical virtual clocks => identical bytes."""
        return json.dumps(self.to_chrome(), sort_keys=True,
                          separators=(",", ":"))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())


def validate_chrome_trace(obj) -> int:
    """Structural validation of a trace-event JSON object (the format
    chrome://tracing / Perfetto ingests). Returns the event count; raises
    ValueError with a specific complaint otherwise."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be an object with a traceEvents array")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be an array")
    for k, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {k}: not an object")
        for field_name in ("ph", "name", "pid", "tid"):
            if field_name not in ev:
                raise ValueError(f"event {k}: missing {field_name!r}")
        ph = ev["ph"]
        if ph == "X":
            if "ts" not in ev or "dur" not in ev:
                raise ValueError(f"event {k}: complete event needs ts+dur")
            if ev["dur"] < 0:
                raise ValueError(f"event {k}: negative duration {ev['dur']}")
        elif ph == "i":
            if "ts" not in ev:
                raise ValueError(f"event {k}: instant event needs ts")
        elif ph != "M":
            raise ValueError(f"event {k}: unsupported phase {ph!r}")
    return len(events)


def reconcile_trace(tracer: Tracer, telemetry) -> float:
    """Max |sum(child span durations) - total_latency_s| over all served
    records. Every telemetry record must have a matching ``request`` span
    (keyed by tenant + req_id) whose children partition it; raises if one
    is missing. The acceptance bound is < 1e-9 s; by construction (same
    floats, same summation order) the error is exactly 0.0."""
    sums: dict[tuple, float] = {}
    for root in tracer.roots("request"):
        kids = sorted(tracer.children(root.span_id),
                      key=lambda s: (s.t0, s.span_id))
        if not kids:
            raise ValueError(f"request span {root.span_id} has no children")
        total = 0.0
        for s in kids:
            total += s.t1 - s.t0
        sums[(root.attrs.get("tenant"), root.attrs.get("req_id"))] = total
    err = 0.0
    for rec in telemetry.records:
        key = (rec.tenant, rec.req_id)
        if key not in sums:
            raise ValueError(f"no request span for tenant={rec.tenant!r} "
                             f"req_id={rec.req_id}")
        err = max(err, abs(sums[key] - rec.total_latency_s))
    return err
