"""Zero-cost-when-disabled instrumentation hooks for deep library code.

The gateway takes explicit ``tracer=`` / ``metrics=`` arguments, but stages
buried under it — ``pipeline.plan`` encode/decode/restore, the rANS codec's
encode/decode loops — cannot thread a registry through every call site
without polluting the pipeline API. This module gives them a process-global
hook instead:

    from repro.obs import hooks
    with hooks.timed("pipeline.encode", backend=op.wire_backend):
        ...body...

When no registry is installed (the default), ``timed`` returns one shared
no-op context manager and ``observe``/``count`` return immediately after a
single ``is None`` check — the hot path stays untouched, which is what lets
the tracing-enabled gateway hold >=0.95x untraced throughput (the CI obs job
gates this).

Wall-clock durations recorded here go **only** into metrics histograms,
never into the virtual-clock trace — traces stay byte-identical under
replay (see repro.obs.trace).
"""
from __future__ import annotations

import contextlib
import time

from repro.obs.metrics import MetricsRegistry

_REGISTRY: MetricsRegistry | None = None


class _NullTimer:
    """Shared no-op timer handed out when instrumentation is disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullTimer()


class _StageTimer:
    __slots__ = ("_hist", "_t0")

    def __init__(self, hist):
        self._hist = hist
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._hist.observe(time.perf_counter() - self._t0)
        return False


def install(registry: MetricsRegistry) -> None:
    """Route stage timers/observations into ``registry`` until uninstall."""
    global _REGISTRY
    _REGISTRY = registry


def uninstall() -> None:
    global _REGISTRY
    _REGISTRY = None


def installed() -> MetricsRegistry | None:
    return _REGISTRY


def enabled() -> bool:
    return _REGISTRY is not None


@contextlib.contextmanager
def active(registry: MetricsRegistry):
    """Scoped install (benchmarks, tests): uninstalls on exit, always."""
    install(registry)
    try:
        yield registry
    finally:
        uninstall()


def timed(stage: str, **labels):
    """Context manager timing its body into the ``stage_seconds`` histogram
    labeled ``stage=...`` (wall clock). No-op when disabled."""
    r = _REGISTRY
    if r is None:
        return _NULL
    return _StageTimer(r.histogram("stage_seconds", stage=stage, **labels))


def observe(name: str, value: float, **labels) -> None:
    """Record one histogram observation (lane occupancy, batch widths)."""
    r = _REGISTRY
    if r is not None:
        r.histogram(name, **labels).observe(value)


def count(name: str, value: float = 1.0, **labels) -> None:
    """Bump a counter. No-op when disabled."""
    r = _REGISTRY
    if r is not None:
        r.counter(name, **labels).inc(value)
