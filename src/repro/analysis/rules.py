"""Rule registry for the invariant linter.

Each rule is a function ``check(ctx) -> list[Violation]`` over one parsed
file, registered in :data:`RULES` with an id, a one-line title, and the
regression class it guards against. Rules are pure AST + config — no
imports of the code under analysis, no third-party deps — so the pass runs
identically on a tree that does not even import (a syntax error is itself
reported, not crashed on).

Scoping and allowlists live in :data:`CONFIG`; :func:`config_fingerprint`
hashes the whole configuration (rule ids included) into the baseline file so
CI fails on silent config drift — loosening a scope is a reviewed change,
exactly like raising the tier-1 failure budget would be.
"""
from __future__ import annotations

import ast
import hashlib
import json
from dataclasses import dataclass, field
from typing import Callable

from repro.analysis.engine import FileContext, Violation

# ---------------------------------------------------------------------------
# Configuration (hashed into the baseline; edits are config drift)
# ---------------------------------------------------------------------------

CONFIG: dict = {
    # RA01: files under these prefixes must never read a wall clock. obs/ is
    # in scope because the tracer (obs/trace.py) must stay on the gateway's
    # VIRTUAL clock for byte-identical trace JSON; hooks.py is the one
    # sanctioned wall-clock sink (stage timers, never trace/telemetry input).
    "virtual_clock_scope": [
        "src/repro/serve/", "src/repro/session/", "src/repro/codec/",
        "src/repro/pipeline/", "src/repro/obs/", "src/repro/tasks/",
    ],
    "virtual_clock_allow_files": {
        "src/repro/obs/hooks.py":
            "the sanctioned wall-clock measurement sink: stage timers feed "
            "metrics histograms only, never the trace or replay state",
    },
    "wall_clock_calls": [
        "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
        "time.perf_counter", "time.perf_counter_ns",
        "time.process_time", "time.process_time_ns",
        "time.localtime", "time.gmtime", "time.ctime", "time.strftime",
        "datetime.datetime.now", "datetime.datetime.utcnow",
        "datetime.datetime.today", "datetime.date.today",
    ],
    # RA02: legacy global-state RNG entry points (numpy legacy API + stdlib
    # random module). jax.random / np.random.Generator are the sanctioned
    # explicit-state APIs and are never flagged.
    "legacy_np_random": [
        "rand", "randn", "randint", "random", "random_sample", "ranf",
        "sample", "choice", "shuffle", "permutation", "uniform", "normal",
        "standard_normal", "beta", "binomial", "poisson", "exponential",
        "seed", "get_state", "set_state", "RandomState",
    ],
    "legacy_py_random": [
        "random", "randint", "randrange", "choice", "choices", "shuffle",
        "sample", "uniform", "gauss", "normalvariate", "betavariate",
        "expovariate", "seed", "getrandbits",
    ],
    # RA02b: set-iteration order must not reach wire bytes / schedules /
    # serialized output; scoped to the modules that produce them.
    "set_iteration_scope": [
        "src/repro/serve/", "src/repro/session/", "src/repro/codec/",
        "src/repro/core/", "src/repro/pipeline/", "src/repro/obs/",
        "src/repro/tasks/",
    ],
    # RA03: the only files allowed to touch the version-skewed jax surface.
    "compat_shims": ["src/repro/kernels/compat.py", "src/repro/compat.py"],
    # RA05: host-sync calls inside traced (jit / shard_map / pallas) bodies.
    "host_sync_scope": ["src/repro/"],
    # RA06: best-effort sites where a silent catch-all is the contract.
    # obs/bench.py is the canonical example: git_sha() falls back to
    # $GITHUB_SHA — but even there the except is narrowed to the concrete
    # (SubprocessError, OSError) pair, so the allowlist entry documents the
    # contract rather than hiding a blanket handler.
    "silent_except_allow_files": {
        "src/repro/obs/bench.py":
            "best-effort git metadata: every failure path falls back to "
            "$GITHUB_SHA / 'unknown'; handlers stay typed regardless",
    },
}


def config_fingerprint() -> str:
    """Hash of everything that changes what the pass flags."""
    payload = {"config": CONFIG, "rules": sorted(RULES)}
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


# ---------------------------------------------------------------------------
# Shared AST helpers
# ---------------------------------------------------------------------------

def build_alias_map(tree: ast.AST) -> dict[str, str]:
    """Local name -> dotted origin, from every import in the file.

    ``import numpy as np`` -> {"np": "numpy"}; ``from time import
    perf_counter`` -> {"perf_counter": "time.perf_counter"}; ``from datetime
    import datetime`` -> {"datetime": "datetime.datetime"}. Function-level
    imports are folded in too — resolution is per-file, not per-scope, which
    is the right bias for a linter (a shadowed import is its own smell).
    """
    alias: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    alias[a.asname] = a.name
                else:
                    alias[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                alias[a.asname or a.name] = f"{node.module}.{a.name}"
    return alias


def dotted_parts(node: ast.AST) -> list[str] | None:
    """['np', 'random', 'rand'] for the expression ``np.random.rand``."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return None


def resolve(alias: dict[str, str], node: ast.AST) -> str | None:
    """Fully-qualified dotted name of an expression, through the imports."""
    parts = dotted_parts(node)
    if not parts:
        return None
    head = alias.get(parts[0], parts[0])
    return ".".join([head] + parts[1:])


def _in_scope(path: str, prefixes: list[str]) -> bool:
    return any(path.startswith(p) for p in prefixes)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Rule:
    id: str
    title: str
    guards: str                          # the regression class this catches
    check: Callable[[FileContext], list]
    fixable: bool = False


RULES: dict[str, Rule] = {}


def _register(rule: Rule) -> Rule:
    RULES[rule.id] = rule
    return rule


def _v(rule_id: str, ctx: FileContext, node: ast.AST, message: str) -> Violation:
    return Violation(rule=rule_id, path=ctx.path,
                     line=getattr(node, "lineno", 1),
                     col=getattr(node, "col_offset", 0), message=message)


# ---------------------------------------------------------------------------
# RA01 — virtual-clock purity
# ---------------------------------------------------------------------------

def _check_ra01(ctx: FileContext) -> list:
    if not _in_scope(ctx.path, CONFIG["virtual_clock_scope"]):
        return []
    if ctx.path in CONFIG["virtual_clock_allow_files"]:
        return []
    wall = set(CONFIG["wall_clock_calls"])
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            name = resolve(ctx.alias, node.func)
            if name in wall:
                out.append(_v("RA01", ctx, node,
                              f"wall-clock call {name}() on a virtual-clock "
                              f"path; replay gates require the event-loop "
                              f"clock (or an allowlisted measurement site)"))
    return out


_register(Rule(
    id="RA01", title="virtual-clock purity", check=_check_ra01,
    guards="one time.time() in serve/session/codec/pipeline/obs breaks "
           "bit-identical replay, byte-identical traces, and session "
           "signatures all at once"))


# ---------------------------------------------------------------------------
# RA02 — determinism: legacy RNG + set-iteration order
# ---------------------------------------------------------------------------

def _is_setish(node: ast.AST, alias: dict[str, str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        name = resolve(alias, node.func)
        if name in ("set", "frozenset"):
            return True
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return (_is_setish(node.left, alias)
                or _is_setish(node.right, alias))
    return False


def _check_ra02(ctx: FileContext) -> list:
    out = []
    np_legacy = set(CONFIG["legacy_np_random"])
    py_legacy = set(CONFIG["legacy_py_random"])
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            name = resolve(ctx.alias, node.func)
            if not name:
                continue
            parts = name.split(".")
            if (len(parts) == 3 and parts[0] == "numpy"
                    and parts[1] == "random" and parts[2] in np_legacy):
                out.append(_v("RA02", ctx, node,
                              f"legacy global-state RNG {name}(); thread an "
                              f"explicit np.random.Generator "
                              f"(np.random.default_rng(seed)) instead"))
            elif (len(parts) == 2 and parts[0] == "random"
                    and parts[1] in py_legacy):
                out.append(_v("RA02", ctx, node,
                              f"stdlib global-state RNG {name}(); use an "
                              f"explicit random.Random(seed) or "
                              f"np.random.default_rng(seed)"))
    if _in_scope(ctx.path, CONFIG["set_iteration_scope"]):
        # results consumed by an order-insensitive reducer are fine:
        # sorted(x for x in set(...)) is the *fix*, not a violation, and a
        # SetComp built from a set stays unordered by construction.
        unordered_ok: set[int] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                name = resolve(ctx.alias, node.func)
                if name in ("sorted", "min", "max", "sum", "any", "all",
                            "len", "set", "frozenset"):
                    for a in node.args:
                        unordered_ok.add(id(a))

        def flag_iter(it: ast.AST) -> None:
            if _is_setish(it, ctx.alias):
                out.append(_v("RA02", ctx, it,
                              "iteration over a set: ordering is "
                              "hash-randomized and must never reach wire "
                              "bytes, schedules, or serialized output — "
                              "wrap in sorted(...)"))
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.For, ast.AsyncFor)):
                flag_iter(node.iter)
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp,
                                   ast.DictComp)):
                if id(node) in unordered_ok:
                    continue
                for gen in node.generators:
                    flag_iter(gen.iter)
            elif isinstance(node, ast.Call):
                name = resolve(ctx.alias, node.func)
                if name in ("list", "tuple", "enumerate") and node.args:
                    flag_iter(node.args[0])
    return out


_register(Rule(
    id="RA02", title="determinism: no unseeded/global RNG, no set-order "
                     "into wire bytes or schedules",
    check=_check_ra02, fixable=True,
    guards="hash-randomized or process-global entropy feeding wire bytes, "
           "scheduler order, or serialized output silently breaks replay "
           "signatures and RD caches"))


# ---------------------------------------------------------------------------
# RA03 — compat discipline (version-skewed jax surface only via shims)
# ---------------------------------------------------------------------------

def _check_ra03(ctx: FileContext) -> list:
    if ctx.path in CONFIG["compat_shims"]:
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax.experimental" or a.name.startswith(
                        "jax.experimental."):
                    out.append(_v("RA03", ctx, node,
                                  f"raw import of {a.name}: the "
                                  f"jax.experimental surface renames across "
                                  f"releases; route through "
                                  f"kernels/compat.py or repro/compat.py"))
        elif isinstance(node, ast.ImportFrom) and node.module:
            if (node.module == "jax.experimental"
                    or node.module.startswith("jax.experimental.")):
                out.append(_v("RA03", ctx, node,
                              f"raw 'from {node.module} import ...': route "
                              f"through kernels/compat.py or "
                              f"repro/compat.py (the PR-2 API-skew class)"))
            elif node.module == "jax" and any(
                    a.name == "shard_map" for a in node.names):
                out.append(_v("RA03", ctx, node,
                              "'from jax import shard_map' skews across "
                              "releases (axis_names/auto, check_vma/"
                              "check_rep); use repro.compat.shard_map"))
        elif isinstance(node, ast.Attribute):
            name = resolve(ctx.alias, node)
            if not name:
                continue
            if name.startswith("jax.experimental."):
                out.append(_v("RA03", ctx, node,
                              f"raw use of {name}: route through the compat "
                              f"shims"))
            elif name == "jax.shard_map":
                out.append(_v("RA03", ctx, node,
                              "jax.shard_map called directly; "
                              "repro.compat.shard_map translates the "
                              "axis_names/check_vma spelling across jax "
                              "versions"))
            elif node.attr in ("CompilerParams", "TPUCompilerParams") and (
                    "pltpu" in name.split(".") or "pallas" in name):
                out.append(_v("RA03", ctx, node,
                              f"{name} is the renamed-across-releases "
                              f"compiler-params class; use "
                              f"kernels.compat.CompilerParams / "
                              f"tpu_compiler_params(...)"))
    return out


_register(Rule(
    id="RA03", title="compat discipline: version-skewed jax APIs only via "
                     "the compat shims",
    check=_check_ra03,
    guards="the exact API-skew class that caused the 40 seed failures PR 2 "
           "burned down (CompilerParams/TPUCompilerParams, shard_map "
           "spellings, pallas module moves)"))


# ---------------------------------------------------------------------------
# RA05 — host-sync inside traced code
# ---------------------------------------------------------------------------

_TRACED_ENTRY_TAILS = ("jit", "shard_map", "pallas_call")


def _traced_function_defs(ctx: FileContext) -> list[ast.FunctionDef]:
    defs: dict[str, list[ast.FunctionDef]] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)
    traced: list[ast.FunctionDef] = []
    traced_names: set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                name = resolve(ctx.alias, target) or ""
                if name.split(".")[-1] in ("jit",):
                    traced.append(node)
                elif name.split(".")[-1] == "partial" and isinstance(
                        dec, ast.Call):
                    for a in dec.args:
                        an = resolve(ctx.alias, a) or ""
                        if an.split(".")[-1] == "jit":
                            traced.append(node)
                            break
        elif isinstance(node, ast.Call):
            name = resolve(ctx.alias, node.func) or ""
            if name.split(".")[-1] in _TRACED_ENTRY_TAILS:
                for a in node.args[:1]:
                    if isinstance(a, ast.Name):
                        traced_names.add(a.id)
    for name in traced_names:
        traced.extend(defs.get(name, []))
    return traced


def _check_ra05(ctx: FileContext) -> list:
    if not _in_scope(ctx.path, CONFIG["host_sync_scope"]):
        return []
    out = []
    seen: set[int] = set()
    for fn in _traced_function_defs(ctx):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call) or id(node) in seen:
                continue
            seen.add(id(node))
            where = f"traced body {fn.name}()"
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                out.append(_v("RA05", ctx, node,
                              f".item() inside {where}: host sync on a "
                              f"traced value (ConcretizationTypeError on "
                              f"jit, a stall at best)"))
                continue
            name = resolve(ctx.alias, node.func)
            if name in ("numpy.asarray", "numpy.array"):
                out.append(_v("RA05", ctx, node,
                              f"{name}() inside {where}: forces a device "
                              f"sync / fails under tracing; use jnp or move "
                              f"to the host side"))
            elif (isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int", "bool")
                    and node.func.id not in ctx.alias
                    and len(node.args) == 1
                    and not isinstance(node.args[0], ast.Constant)):
                out.append(_v("RA05", ctx, node,
                              f"builtin {node.func.id}() on a non-literal "
                              f"inside {where}: concretizes a traced value"))
    return out


_register(Rule(
    id="RA05", title="no host-sync (.item()/float()/np.asarray) in traced "
                     "bodies",
    check=_check_ra05,
    guards="host syncs inside jit/shard_map/Pallas bodies crash under "
           "tracing or silently serialize the device pipeline"))


# ---------------------------------------------------------------------------
# RA06 — silent failure
# ---------------------------------------------------------------------------

def _silent_body(body: list[ast.stmt]) -> bool:
    for stmt in body:
        if isinstance(stmt, (ast.Pass, ast.Continue)):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(
                stmt.value, ast.Constant):
            continue                      # docstring / Ellipsis
        return False
    return True


def _check_ra06(ctx: FileContext) -> list:
    if ctx.path in CONFIG["silent_except_allow_files"]:
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            out.append(_v("RA06", ctx, node,
                          "bare 'except:' swallows KeyboardInterrupt and "
                          "SystemExit too; name the concrete exception "
                          "types"))
            continue
        name = resolve(ctx.alias, node.type)
        if name in ("Exception", "BaseException") and _silent_body(node.body):
            out.append(_v("RA06", ctx, node,
                          f"'except {name}: pass' silently discards every "
                          f"failure; narrow to the concrete types or "
                          f"handle/log the error"))
    return out


_register(Rule(
    id="RA06", title="no silent catch-alls", check=_check_ra06, fixable=True,
    guards="a swallowed exception on a serving or codec path turns a loud "
           "failure into a wrong-bytes one"))


# RA04 lives in repro.analysis.wire (it is cross-file: formats + revision
# constants + the committed fingerprint file); importing it here would cycle.
RA04_ID = "RA04"
RA04_TITLE = ("wire-format hygiene: pack/unpack symmetry, CRC coverage, and "
              "fingerprinted layouts that fail the build when edited without "
              "a codec_revision() bump")


# RA00 is the meta-rule for pragma hygiene (reason mandatory, no unused or
# unknown suppressions). It is emitted by the engine, never baselined.
RA00_ID = "RA00"
