"""RA04 — wire-format hygiene and fingerprinted layouts.

Three wire families leave this repo: **BaF2** (the EncodedTensor container,
``core/codec.py``), **RTC1** (the rANS container, ``codec/container.py``)
and **SSF1** (session frames, ``session/codec.py``). Their layouts are
replayed, cached (RD tables key on :func:`repro.serve.codec_revision`) and
fuzzed byte-for-byte, so an edit to any ``struct`` format string without a
revision bump silently invalidates every one of those guarantees.

This module extracts, per family and purely from the AST:

  * every ``struct`` format string (``struct.Struct``/``pack``/``pack_into``
    /``unpack``/``unpack_from``/``calcsize``), f-string formats canonicalized
    with ``{}`` placeholders,
  * the revision constants that :func:`repro.serve.codec_revision` (or the
    session header) is built from — magic bytes + version ints,
  * whether the module computes a CRC (``zlib.crc32``/``adler32``) at all,

and checks them against the committed ``wire_schema.json``:

  * layout changed, revision unchanged  -> **RA04**: bump the revision
    constant (that is what "codec_revision() bump" means mechanically);
  * revision changed (with or without a layout change) -> **RA04**: the
    fingerprint file is stale; regenerate with ``--update-wire-schema`` so
    the new layout is committed and reviewed next to the bump;
  * a pack format with no matching unpack/Struct, or a family module with
    no CRC call -> **RA04** directly.

RA04 findings are *hard*: never baselined, never pragma-suppressed — a wire
change is correct only when the fingerprint file changes with it.
"""
from __future__ import annotations

import ast
import hashlib
import json
import os

from repro.analysis.engine import Violation

WIRE_SCHEMA_VERSION = "repro-wire-schema/1"

# family -> modules holding its struct formats,
#           [(module, constant name), ...] forming its revision identity,
#           crc_modules: where the CRC discipline covering its payload
#           bytes lives. BaF2 delegates: its header is validated
#           structurally (magic + explicit side-info/payload lengths +
#           trailing-garbage rejection) and its payload integrity is the
#           entropy backend's — RTC1 CRCs for rans/rans-ctx, zlib's
#           built-in adler32 for zlib — so the delegate is the RTC1
#           module. Adding a header CRC to BaF2 itself would change the
#           wire layout and break every bit-identical gate; if that trade
#           is ever taken it must ride a codec_revision() bump.
FAMILIES: dict[str, dict] = {
    "BaF2": {
        "modules": ["src/repro/core/codec.py"],
        "crc_modules": ["src/repro/codec/container.py"],
        "revision_consts": [
            ("src/repro/core/codec.py", "MAGIC"),
            ("src/repro/pipeline/op.py", "WIRE_PROFILE_VERSION"),
        ],
    },
    "RTC1": {
        "modules": ["src/repro/codec/container.py"],
        "crc_modules": ["src/repro/codec/container.py"],
        "revision_consts": [
            ("src/repro/codec/container.py", "MAGIC"),
            ("src/repro/codec/container.py", "VERSION"),
        ],
    },
    "SSF1": {
        "modules": ["src/repro/session/codec.py"],
        "crc_modules": ["src/repro/session/codec.py"],
        "revision_consts": [
            ("src/repro/session/codec.py", "SESSION_MAGIC"),
            ("src/repro/pipeline/op.py", "SESSION_WIRE_VERSION"),
        ],
    },
}

_STRUCT_FNS = {"Struct": "struct", "calcsize": "both",
               "pack": "pack", "pack_into": "pack",
               "unpack": "unpack", "unpack_from": "unpack"}


def _canonical_format(node: ast.AST) -> str | None:
    """Format-string argument as a canonical text; ``{}`` for dynamic parts."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.replace(" ", "")
    if isinstance(node, ast.JoinedStr):
        parts = []
        for v in node.values:
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                parts.append(v.value.replace(" ", ""))
            else:
                parts.append("{}")
        return "".join(parts)
    return None


def _module_formats(tree: ast.AST) -> tuple[list[dict], bool]:
    """([{kind, format}, ...] sorted, module references a CRC at all)."""
    from repro.analysis.rules import build_alias_map, resolve
    alias = build_alias_map(tree)
    found: list[dict] = []
    has_crc = False
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = resolve(alias, node.func) or ""
        parts = name.split(".")
        if parts[-1] in ("crc32", "adler32"):
            has_crc = True
        if (len(parts) >= 2 and parts[-2] == "struct"
                and parts[-1] in _STRUCT_FNS and node.args):
            fmt = _canonical_format(node.args[0])
            if fmt is not None:
                found.append({"kind": _STRUCT_FNS[parts[-1]], "format": fmt})
    found.sort(key=lambda d: (d["format"], d["kind"]))
    return found, has_crc


def _module_constant(tree: ast.AST, name: str):
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name and isinstance(
                        node.value, ast.Constant):
                    return node.value.value
    return None


def _parse(root: str, rel: str) -> ast.AST | None:
    try:
        with open(os.path.join(root, rel), encoding="utf-8") as f:
            return ast.parse(f.read(), filename=rel)
    except (OSError, SyntaxError):
        return None


def extract_family(root: str, family: str) -> dict | None:
    """{"revision": str, "formats": [...], "layout_sha256": str} or None
    when a family module is missing/unparseable (reported by the caller)."""
    spec = FAMILIES[family]
    formats: list[dict] = []
    for rel in spec["modules"]:
        tree = _parse(root, rel)
        if tree is None:
            return None
        fmts, _ = _module_formats(tree)
        formats.extend(fmts)
    crc_ok = True
    for rel in spec.get("crc_modules", spec["modules"]):
        tree = _parse(root, rel)
        if tree is None:
            return None
        _, has_crc = _module_formats(tree)
        crc_ok = crc_ok and has_crc
    rev_parts: list[str] = []
    for rel, const in spec["revision_consts"]:
        tree = _parse(root, rel)
        value = _module_constant(tree, const) if tree is not None else None
        if value is None:
            return None
        if isinstance(value, bytes):
            value = value.decode("ascii", "backslashreplace")
        rev_parts.append(f"{const}={value}")
    formats.sort(key=lambda d: (d["format"], d["kind"]))
    blob = json.dumps(formats, sort_keys=True, separators=(",", ":"))
    return {"revision": "/".join(rev_parts), "formats": formats,
            "layout_sha256": hashlib.sha256(blob.encode()).hexdigest(),
            "has_crc": crc_ok}


def build_wire_schema(root: str) -> dict:
    families = {}
    for family in sorted(FAMILIES):
        ext = extract_family(root, family)
        if ext is not None:
            families[family] = {"revision": ext["revision"],
                                "layout_sha256": ext["layout_sha256"],
                                "formats": ext["formats"]}
    return {"schema": WIRE_SCHEMA_VERSION, "families": families}


def write_wire_schema(root: str, path: str) -> dict:
    schema = build_wire_schema(root)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(schema, f, indent=1, sort_keys=True)
        f.write("\n")
    return schema


def _strip_endian(fmt: str) -> str:
    return fmt.lstrip("<>=!@")


def _hygiene(family: str, ext: dict) -> list[Violation]:
    """Per-family pack/unpack symmetry + CRC coverage.

    Symmetry is prefix-aware: a packed field sequence is readable when some
    unpack/Struct format *starts with* it — e.g. RTC1 packs the chunk CRC
    body as ``"<II"`` + payload and reads it back through the ``"<III"``
    (count|n_words|crc) Struct.
    """
    spec = FAMILIES[family]
    path = spec["modules"][0]
    out: list[Violation] = []
    packs = {d["format"] for d in ext["formats"] if d["kind"] == "pack"}
    unpacks = {d["format"] for d in ext["formats"]
               if d["kind"] in ("unpack", "struct", "both")}
    readable = {_strip_endian(f) for f in unpacks}
    for fmt in sorted(packs):
        bare = _strip_endian(fmt)
        if not any(r.startswith(bare) for r in readable):
            out.append(Violation(
                rule="RA04", path=path, line=1, col=0,
                message=f"{family}: pack format {fmt!r} has no matching "
                        f"unpack/Struct in the family module — a "
                        f"write-only layout cannot round-trip"))
    if not ext["has_crc"]:
        crc_mods = spec.get("crc_modules", spec["modules"])
        out.append(Violation(
            rule="RA04", path=path, line=1, col=0,
            message=f"{family}: no CRC (zlib.crc32/adler32) in its "
                    f"integrity module(s) {crc_mods}; wire integrity "
                    f"checks are mandatory for every format"))
    return out


def check_wire_schema(root: str, schema_path: str) -> tuple[list[Violation],
                                                            dict]:
    """All RA04 violations + a per-family summary for the JSON report."""
    violations: list[Violation] = []
    summary: dict = {}
    try:
        with open(schema_path, encoding="utf-8") as f:
            committed = json.load(f)
        if committed.get("schema") != WIRE_SCHEMA_VERSION:
            raise ValueError(f"unsupported wire schema "
                             f"{committed.get('schema')!r}")
        committed_families = committed.get("families", {})
    except FileNotFoundError:
        committed_families = None
        violations.append(Violation(
            rule="RA04", path=os.path.relpath(schema_path, root), line=1,
            col=0, message="no committed wire_schema.json; run 'python -m "
                           "repro.analysis --update-wire-schema' and commit "
                           "the fingerprints"))
    except ValueError as e:
        committed_families = None
        violations.append(Violation(
            rule="RA04", path=os.path.relpath(schema_path, root), line=1,
            col=0, message=f"bad wire schema file: {e}"))

    for family in sorted(FAMILIES):
        spec = FAMILIES[family]
        mod = spec["modules"][0]
        present = any(os.path.exists(os.path.join(root, rel))
                      for rel in spec["modules"])
        if not present:
            # a tree without the family at all (test fixtures, partial
            # checkouts) has nothing to fingerprint — unless the committed
            # schema says the family should exist, in which case its
            # disappearance IS a wire change
            if committed_families and family in committed_families:
                violations.append(Violation(
                    rule="RA04", path=mod, line=1, col=0,
                    message=f"{family}: registered in wire_schema.json but "
                            f"its module(s) are gone — removing a wire "
                            f"family is a revision event; regenerate the "
                            f"schema deliberately"))
                summary[family] = {"status": "registered-but-absent"}
            else:
                summary[family] = {"status": "absent"}
            continue
        ext = extract_family(root, family)
        if ext is None:
            violations.append(Violation(
                rule="RA04", path=mod, line=1, col=0,
                message=f"{family}: family module or revision constant "
                        f"missing/unparseable — wire families must stay "
                        f"extractable"))
            summary[family] = {"status": "unextractable"}
            continue
        violations.extend(_hygiene(family, ext))
        if committed_families is None:
            summary[family] = {"status": "no-baseline",
                               "revision": ext["revision"]}
            continue
        entry = committed_families.get(family)
        if entry is None:
            violations.append(Violation(
                rule="RA04", path=mod, line=1, col=0,
                message=f"{family}: not in the committed wire schema; "
                        f"register it with --update-wire-schema"))
            summary[family] = {"status": "unregistered",
                               "revision": ext["revision"]}
            continue
        same_layout = entry.get("layout_sha256") == ext["layout_sha256"]
        same_rev = entry.get("revision") == ext["revision"]
        if same_layout and same_rev:
            summary[family] = {"status": "ok", "revision": ext["revision"]}
        elif not same_layout and same_rev:
            changed = sorted(
                {d["format"] for d in ext["formats"]}
                ^ {d["format"] for d in entry.get("formats", [])})
            violations.append(Violation(
                rule="RA04", path=mod, line=1, col=0,
                message=f"{family}: wire layout changed (formats "
                        f"{changed}) without a codec_revision() bump — "
                        f"bump "
                        f"{'/'.join(c for _, c in FAMILIES[family]['revision_consts'])} "
                        f"and regenerate the fingerprints "
                        f"(--update-wire-schema)"))
            summary[family] = {"status": "layout-changed-no-bump",
                               "revision": ext["revision"]}
        else:
            violations.append(Violation(
                rule="RA04", path=mod, line=1, col=0,
                message=f"{family}: revision is now {ext['revision']!r} "
                        f"(fingerprint file has {entry.get('revision')!r}) "
                        f"— stale wire_schema.json; regenerate with "
                        f"--update-wire-schema and commit it with the "
                        f"bump"))
            summary[family] = {"status": "stale-fingerprint",
                               "revision": ext["revision"]}
    return violations, summary
