"""CLI: ``python -m repro.analysis [--check] [--json FILE] [--fix] ...``

Exit codes: 0 clean (or informational run), 1 failed ``--check``, 2 usage.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _default_root() -> str:
    """The tree this installed package belongs to: src/repro/analysis/ is
    three levels below the repo root, so a scratch copy of the repo analyzed
    with PYTHONPATH=<copy>/src checks the copy, not the original."""
    here = os.path.abspath(os.path.dirname(__file__))
    cand = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return cand


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Invariant linter: determinism, virtual-clock purity, "
                    "compat discipline, and wire-format hygiene "
                    "(rules RA01..RA06; see docs/ANALYSIS.md)")
    parser.add_argument("paths", nargs="*",
                        help="specific files to analyze (default: src/, "
                             "benchmarks/, examples/, tests/ under --root)")
    parser.add_argument("--root", default=_default_root(),
                        help="repo root (default: the tree this package "
                             "is imported from)")
    parser.add_argument("--check", action="store_true",
                        help="gate against the baseline + wire fingerprints; "
                             "exit 1 on any failure")
    parser.add_argument("--json", metavar="FILE",
                        help="write the machine-readable report ('-' for "
                             "stdout)")
    parser.add_argument("--fix", action="store_true",
                        help="apply the mechanical autofixes (RA02 legacy "
                             "RNG -> default_rng, RA06 bare except -> typed) "
                             "before analyzing")
    parser.add_argument("--update-baseline", action="store_true",
                        help="rewrite the ratchet baseline from the current "
                             "unsuppressed violation counts")
    parser.add_argument("--update-wire-schema", action="store_true",
                        help="regenerate the committed wire-format "
                             "fingerprints (only alongside a revision bump)")
    parser.add_argument("--baseline", metavar="FILE",
                        help="baseline path (default: "
                             "src/repro/analysis/baseline.json)")
    parser.add_argument("--wire-schema", metavar="FILE",
                        help="wire schema path (default: "
                             "src/repro/analysis/wire_schema.json)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    parser.add_argument("--quiet", action="store_true",
                        help="only failures and the summary line")
    args = parser.parse_args(argv)

    from repro.analysis import engine, fixes, rules, wire

    if args.list_rules:
        for rule in sorted(rules.RULES.values(), key=lambda r: r.id):
            fix = " [--fix]" if rule.fixable else ""
            print(f"{rule.id}{fix}: {rule.title}")
            print(f"      guards: {rule.guards}")
        print(f"{rules.RA04_ID}: {rules.RA04_TITLE}")
        print("RA00: pragma hygiene (reason mandatory, no unused/unknown "
              "suppressions); never baselineable")
        return 0

    root = os.path.abspath(args.root)
    baseline_path = args.baseline or engine.default_baseline_path(root)
    schema_path = args.wire_schema or engine.default_wire_schema_path(root)

    if args.fix:
        applied = 0
        for rel in engine.discover_files(root, paths=args.paths or None):
            for fix in fixes.fix_file(os.path.join(root, rel)):
                applied += 1
                if not args.quiet:
                    print(f"fixed {rel}:{fix.line} [{fix.rule}] "
                          f"{fix.description}")
        print(f"--fix applied {applied} rewrite(s)")

    if args.update_wire_schema:
        schema = wire.write_wire_schema(root, schema_path)
        for family, entry in sorted(schema["families"].items()):
            print(f"wire schema {family}: revision {entry['revision']} "
                  f"layout {entry['layout_sha256'][:12]}")

    result = engine.run_analysis(root, paths=args.paths or None,
                                 baseline_path=baseline_path,
                                 wire_schema_path=schema_path)

    if args.update_baseline:
        engine.write_baseline(baseline_path, result.counts,
                              rules.config_fingerprint())
        print(f"baseline updated: {sum(result.counts.values())} "
              f"violation(s) across {len(result.counts)} rule:file key(s)")
        result = engine.run_analysis(root, paths=args.paths or None,
                                     baseline_path=baseline_path,
                                     wire_schema_path=schema_path)

    if not args.quiet:
        for v in result.violations:
            if v.suppressed:
                continue
            print(f"{v.path}:{v.line}:{v.col} [{v.rule}] {v.message}")
        suppressed = [v for v in result.violations if v.suppressed]
        for v in suppressed:
            print(f"{v.path}:{v.line}:{v.col} [{v.rule}] suppressed -- "
                  f"{v.reason}")

    if args.json:
        payload = json.dumps(result.to_json(), indent=1, sort_keys=True)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w", encoding="utf-8") as f:
                f.write(payload + "\n")

    n_unsup = len(result.unsuppressed())
    n_sup = len(result.violations) - n_unsup
    wire_ok = all(e.get("status") in ("ok", "absent")
                  for e in result.wire.values())
    print(f"repro.analysis: {result.files_scanned} files, "
          f"{n_unsup} unsuppressed violation(s), {n_sup} suppressed, "
          f"wire schema {'ok' if wire_ok else 'FAILED'}")

    if args.check:
        for failure in result.failures:
            print(f"CHECK FAILED: {failure}", file=sys.stderr)
        if result.failures:
            return 1
        print("check passed: ratchet, pragmas, and wire fingerprints clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
