"""``--fix``: autofixes for the mechanical rules (RA02 legacy RNG, RA06).

Only rewrites with an unambiguous mechanical translation are applied:

  * **RA06** — a bare ``except:`` whose body actually handles something
    becomes ``except Exception:`` (typed, no longer swallows
    ``KeyboardInterrupt``/``SystemExit``). A *silent* handler
    (``except: pass``) is NOT autofixed: only a human knows which concrete
    failure is expected there.
  * **RA02** — ``np.random.RandomState(seed)`` becomes
    ``np.random.default_rng(seed)``; a module using the legacy seeded
    global API (``np.random.seed(N)`` followed by ``np.random.rand(...)``
    etc.) is rewritten onto an explicit generator::

        np.random.seed(7)            ->  rng = np.random.default_rng(7)
        x = np.random.rand(3, 4)     ->  x = rng.random((3, 4))
        i = np.random.randint(0, 9)  ->  i = rng.integers(0, 9)

    Unseeded legacy calls (no ``np.random.seed`` in the file) are left for
    a human: inventing a seed would hide the bug the rule exists to catch.

Fixes are AST-located, text-applied (comments and formatting survive), and
idempotent — a second ``--fix`` run is a no-op.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass

from repro.analysis.rules import (_silent_body, build_alias_map,
                                  dotted_parts, resolve)

# legacy np.random function -> (Generator method, wrap positional args in a
# shape tuple — the rand/randn calling convention difference)
_GEN_METHOD = {
    "rand": ("random", True),
    "randn": ("standard_normal", True),
    "randint": ("integers", False),
    "random": ("random", False),
    "random_sample": ("random", False),
    "ranf": ("random", False),
    "sample": ("random", False),
    "choice": ("choice", False),
    "shuffle": ("shuffle", False),
    "permutation": ("permutation", False),
    "uniform": ("uniform", False),
    "normal": ("normal", False),
    "standard_normal": ("standard_normal", False),
}


@dataclass(frozen=True)
class Fix:
    rule: str
    line: int
    description: str


def _line_offsets(source: str) -> list[int]:
    offsets, pos = [0], 0
    for line in source.splitlines(keepends=True):
        pos += len(line)
        offsets.append(pos)
    return offsets


class _Edits:
    def __init__(self, source: str):
        self.source = source
        self.offsets = _line_offsets(source)
        self.edits: list[tuple[int, int, str]] = []

    def at(self, lineno: int, col: int) -> int:
        return self.offsets[lineno - 1] + col

    def replace(self, node: ast.AST, text: str) -> None:
        self.edits.append((self.at(node.lineno, node.col_offset),
                           self.at(node.end_lineno, node.end_col_offset),
                           text))

    def insert(self, lineno: int, col: int, text: str) -> None:
        pos = self.at(lineno, col)
        self.edits.append((pos, pos, text))

    def apply(self) -> str:
        out = self.source
        for start, end, text in sorted(self.edits, reverse=True):
            out = out[:start] + text + out[end:]
        return out


def fix_source(source: str) -> tuple[str, list[Fix]]:
    """Apply every mechanical fix; returns (new source, applied fixes)."""
    try:
        tree = ast.parse(source)
    except SyntaxError:
        return source, []
    alias = build_alias_map(tree)
    edits = _Edits(source)
    fixes: list[Fix] = []

    # ---- RA06: bare except with a real body -> except Exception ----------
    for node in ast.walk(tree):
        if (isinstance(node, ast.ExceptHandler) and node.type is None
                and not _silent_body(node.body)):
            # the handler node starts at the 'except' keyword
            pos = edits.at(node.lineno, node.col_offset)
            if source[pos:pos + 6] == "except":
                edits.edits.append((pos, pos + 6, "except Exception"))
                fixes.append(Fix("RA06", node.lineno,
                                 "bare 'except:' -> 'except Exception:'"))

    # ---- RA02: numpy legacy RNG ------------------------------------------
    def np_random_fn(call: ast.Call) -> str | None:
        name = resolve(alias, call.func)
        if not name:
            return None
        parts = name.split(".")
        if len(parts) == 3 and parts[:2] == ["numpy", "random"]:
            return parts[2]
        return None

    def src_of(node: ast.AST) -> str:
        return ast.get_source_segment(source, node) or ""

    # RandomState(seed) -> default_rng(seed), wherever it appears
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and np_random_fn(node) == "RandomState":
            prefix = ".".join(dotted_parts(node.func)[:-1])
            edits.replace(node.func, f"{prefix}.default_rng")
            fixes.append(Fix("RA02", node.lineno,
                             "np.random.RandomState -> "
                             "np.random.default_rng"))

    # seeded global API -> explicit generator
    seed_stmts = [
        stmt for stmt in ast.walk(tree)
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call)
        and np_random_fn(stmt.value) == "seed"]
    if seed_stmts:
        first_seed = min(seed_stmts, key=lambda s: s.lineno)
        module_names = {n.id for n in ast.walk(tree)
                        if isinstance(n, ast.Name)}
        rng = "rng" if "rng" not in module_names else "_repro_rng"
        for stmt in seed_stmts:
            call = stmt.value
            prefix = ".".join(dotted_parts(call.func)[:-1])
            head = (f"{rng} = {prefix}.default_rng"
                    if stmt is first_seed else f"{rng} = {prefix}.default_rng")
            edits.replace(call.func, head)
            fixes.append(Fix("RA02", stmt.lineno,
                             f"np.random.seed(...) -> {rng} = "
                             f"np.random.default_rng(...)"))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call) or node.lineno <= \
                    first_seed.lineno:
                continue
            fn = np_random_fn(node)
            if fn not in _GEN_METHOD:
                continue
            method, tuple_args = _GEN_METHOD[fn]
            edits.replace(node.func, f"{rng}.{method}")
            if tuple_args and len(node.args) >= 1:
                args_txt = ", ".join(src_of(a) for a in node.args)
                wrapped = (f"({args_txt},)" if len(node.args) == 1
                           else f"({args_txt})")
                first, last = node.args[0], node.args[-1]
                edits.edits.append((
                    edits.at(first.lineno, first.col_offset),
                    edits.at(last.end_lineno, last.end_col_offset),
                    wrapped))
            fixes.append(Fix("RA02", node.lineno,
                             f"np.random.{fn} -> {rng}.{method}"))

    if not fixes:
        return source, []
    return edits.apply(), fixes


def fix_file(path: str) -> list[Fix]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    fixed, fixes = fix_source(source)
    if fixes and fixed != source:
        with open(path, "w", encoding="utf-8") as f:
            f.write(fixed)
    return fixes
