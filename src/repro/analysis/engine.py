"""Analysis engine: file discovery, pragmas, the ratchet baseline, reports.

The engine is deliberately boring: parse every ``*.py`` under the roots,
hand each file to every registered rule, attach inline suppressions, fold
in the cross-file RA04 wire check, then gate against the committed baseline.

Suppression pragma grammar (reason mandatory)::

    <code>  # repro: allow[RA01] -- measures real compute wall for the cost fit
    # repro: allow[RA02, RA06] -- fuzz harness: entropy is the point

A pragma suppresses matching violations on its own line or the line below
(for own-line pragmas above a statement). Pragma hygiene is rule RA00 —
missing reason, unknown rule id, or a pragma that suppresses nothing — and
RA00/RA04 violations are *hard*: they fail ``--check`` directly and can
never be ratcheted into the baseline.

Baseline file (``src/repro/analysis/baseline.json``)::

    {"schema": "repro-analysis-baseline/1",
     "config_fingerprint": "<sha256 of rules+config>",
     "violations": {"RA05:src/repro/foo.py": 2, ...}}

``--check`` fails when (a) any ``RULE:path`` count exceeds its baseline
entry beyond ``$MAX_LINT_VIOLATIONS`` (default 0) total excess, (b) any
baseline entry exceeds the current count — a fixed violation must lower
the baseline in the same commit, mirroring the tier-1 ratchet, (c) the
config fingerprint drifted, or (d) any hard (RA00/RA04/parse) violation
exists.
"""
from __future__ import annotations

import ast
import io
import json
import os
import re
import tokenize
from dataclasses import dataclass, field, replace

BASELINE_SCHEMA = "repro-analysis-baseline/1"
REPORT_SCHEMA = "repro-analysis/1"
DEFAULT_ROOTS = ("src", "benchmarks", "examples", "tests")
HARD_RULES = ("RA00", "RA04", "PARSE")

_PRAGMA_RE = re.compile(
    r"#\s*repro:\s*allow\[([^\]]*)\]\s*(?:--\s*(\S.*\S|\S))?\s*$")


@dataclass(frozen=True)
class Violation:
    rule: str
    path: str                    # repo-relative, posix separators
    line: int
    col: int
    message: str
    suppressed: bool = False
    reason: str | None = None

    def key(self) -> str:
        return f"{self.rule}:{self.path}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "suppressed": self.suppressed, "reason": self.reason}


@dataclass(frozen=True)
class Pragma:
    line: int
    rules: tuple[str, ...]
    reason: str | None


@dataclass
class FileContext:
    """One parsed file as the rules see it."""
    path: str                    # repo-relative
    source: str
    tree: ast.AST
    alias: dict[str, str]
    pragmas: dict[int, Pragma] = field(default_factory=dict)


@dataclass
class AnalysisResult:
    root: str
    violations: list[Violation]          # every finding, suppressed included
    counts: dict[str, int]               # unsuppressed, baselineable, by key
    failures: list[str]                  # why --check fails (empty = ok)
    wire: dict                           # per-family fingerprint summary
    files_scanned: int = 0

    @property
    def ok(self) -> bool:
        return not self.failures

    def unsuppressed(self) -> list[Violation]:
        return [v for v in self.violations if not v.suppressed]

    def to_json(self) -> dict:
        by_rule: dict[str, int] = {}
        for v in self.unsuppressed():
            by_rule[v.rule] = by_rule.get(v.rule, 0) + 1
        return {"schema": REPORT_SCHEMA, "root": self.root,
                "files_scanned": self.files_scanned,
                "ok": self.ok, "failures": self.failures,
                "violations": [v.to_json() for v in self.violations],
                "counts_by_rule": dict(sorted(by_rule.items())),
                "counts_by_key": dict(sorted(self.counts.items())),
                "wire": self.wire}


# ---------------------------------------------------------------------------
# Pragmas
# ---------------------------------------------------------------------------

def parse_pragmas(source: str, path: str) -> tuple[dict[int, Pragma],
                                                   list[Violation]]:
    """Comment pragmas via tokenize (never matches inside string literals)."""
    pragmas: dict[int, Pragma] = {}
    bad: list[Violation] = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.string) for t in tokens
                    if t.type == tokenize.COMMENT]
    except (tokenize.TokenError, SyntaxError, IndentationError):
        comments = [(i + 1, line[line.index("#"):])
                    for i, line in enumerate(source.splitlines())
                    if "#" in line]
    for lineno, text in comments:
        m = _PRAGMA_RE.search(text)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        reason = m.group(2)
        if not rules:
            bad.append(Violation(
                rule="RA00", path=path, line=lineno, col=0,
                message="suppression pragma names no rule ids"))
            continue
        if not reason:
            bad.append(Violation(
                rule="RA00", path=path, line=lineno, col=0,
                message=f"suppression pragma for {', '.join(rules)} has no "
                        f"reason; write '# repro: allow[ID] -- why'"))
            continue
        pragmas[lineno] = Pragma(line=lineno, rules=rules, reason=reason)
    return pragmas, bad


def _apply_pragmas(ctx: FileContext, violations: list[Violation],
                   known_rules: set[str]) -> tuple[list[Violation],
                                                   list[Violation]]:
    """Mark suppressed violations; return (violations, RA00 hygiene extras).

    A pragma applies to its own line, or — when written as an own-line
    comment (possibly with further ``#`` continuation lines under it) — to
    the first statement below the comment block.
    """
    lines = ctx.source.splitlines()

    def pragma_for(line: int) -> Pragma | None:
        if line in ctx.pragmas:
            return ctx.pragmas[line]
        l = line - 1
        while 1 <= l <= len(lines) and lines[l - 1].lstrip().startswith("#"):
            if l in ctx.pragmas:
                return ctx.pragmas[l]
            l -= 1
        return None

    used: set[int] = set()
    out: list[Violation] = []
    for v in violations:
        pragma = pragma_for(v.line)
        if pragma and v.rule in pragma.rules:
            used.add(pragma.line)
            out.append(replace(v, suppressed=True, reason=pragma.reason))
        else:
            out.append(v)
    extras: list[Violation] = []
    for lineno, pragma in sorted(ctx.pragmas.items()):
        unknown = [r for r in pragma.rules if r not in known_rules]
        if unknown:
            extras.append(Violation(
                rule="RA00", path=ctx.path, line=lineno, col=0,
                message=f"pragma names unknown rule id(s) "
                        f"{', '.join(unknown)}"))
        elif lineno not in used:
            extras.append(Violation(
                rule="RA00", path=ctx.path, line=lineno, col=0,
                message=f"unused suppression for "
                        f"{', '.join(pragma.rules)}: nothing on this or the "
                        f"next line violates it — delete the pragma"))
    return out, extras


# ---------------------------------------------------------------------------
# Discovery + per-file pass
# ---------------------------------------------------------------------------

def discover_files(root: str, roots: tuple[str, ...] = DEFAULT_ROOTS,
                   paths: list[str] | None = None) -> list[str]:
    """Repo-relative posix paths of every ``*.py`` under the roots."""
    if paths:
        rels = []
        for p in paths:
            ap = os.path.abspath(p)
            rels.append(os.path.relpath(ap, root).replace(os.sep, "/"))
        return sorted(rels)
    found: list[str] = []
    for sub in roots:
        base = os.path.join(root, sub)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    rel = os.path.relpath(os.path.join(dirpath, fn), root)
                    found.append(rel.replace(os.sep, "/"))
    return sorted(found)


def analyze_file(root: str, rel: str) -> tuple[FileContext | None,
                                               list[Violation]]:
    from repro.analysis import rules as _rules
    abspath = os.path.join(root, rel)
    try:
        with open(abspath, encoding="utf-8") as f:
            source = f.read()
    except OSError as e:
        return None, [Violation(rule="PARSE", path=rel, line=1, col=0,
                                message=f"unreadable: {e}")]
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as e:
        return None, [Violation(rule="PARSE", path=rel,
                                line=e.lineno or 1, col=e.offset or 0,
                                message=f"syntax error: {e.msg}")]
    pragmas, bad = parse_pragmas(source, rel)
    ctx = FileContext(path=rel, source=source, tree=tree,
                      alias=_rules.build_alias_map(tree), pragmas=pragmas)
    violations: list[Violation] = list(bad)
    for rule in _rules.RULES.values():
        violations.extend(rule.check(ctx))
    applied, extras = _apply_pragmas(
        ctx, [v for v in violations if v.rule != "RA00"],
        set(_rules.RULES) | {"RA04"})
    return ctx, ([v for v in violations if v.rule == "RA00"]
                 + extras + applied)


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------

def default_baseline_path(root: str) -> str:
    return os.path.join(root, "src", "repro", "analysis", "baseline.json")


def default_wire_schema_path(root: str) -> str:
    return os.path.join(root, "src", "repro", "analysis", "wire_schema.json")


def load_baseline(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if data.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"unsupported baseline schema "
                         f"{data.get('schema')!r} (want {BASELINE_SCHEMA!r})")
    return data


def write_baseline(path: str, counts: dict[str, int],
                   fingerprint: str) -> None:
    data = {"schema": BASELINE_SCHEMA, "config_fingerprint": fingerprint,
            "violations": dict(sorted(
                (k, v) for k, v in counts.items() if v))}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")


# ---------------------------------------------------------------------------
# The full pass
# ---------------------------------------------------------------------------

def run_analysis(root: str, *, paths: list[str] | None = None,
                 baseline_path: str | None = None,
                 wire_schema_path: str | None = None,
                 max_violations: int | None = None) -> AnalysisResult:
    """Run every rule + the wire check and gate against the baseline.

    ``max_violations`` defaults to ``$MAX_LINT_VIOLATIONS`` (default 0): the
    total count of unsuppressed violations in excess of their baseline
    entries that the run tolerates — the direct analogue of the tier-1
    ``MAX_TIER1_FAILURES`` budget, and like it, meant to stay at 0.
    """
    from repro.analysis import rules as _rules
    from repro.analysis import wire as _wire

    root = os.path.abspath(root)
    if max_violations is None:
        max_violations = int(os.environ.get("MAX_LINT_VIOLATIONS", "0"))
    baseline_path = baseline_path or default_baseline_path(root)
    wire_schema_path = wire_schema_path or default_wire_schema_path(root)

    files = discover_files(root, paths=paths)
    violations: list[Violation] = []
    for rel in files:
        _, file_violations = analyze_file(root, rel)
        violations.extend(file_violations)

    wire_violations, wire_summary = _wire.check_wire_schema(
        root, wire_schema_path)
    violations.extend(wire_violations)

    counts: dict[str, int] = {}
    for v in violations:
        if not v.suppressed and v.rule not in HARD_RULES:
            counts[v.key()] = counts.get(v.key(), 0) + 1

    failures: list[str] = []
    hard = [v for v in violations if not v.suppressed and v.rule in HARD_RULES]
    for v in hard:
        failures.append(f"{v.path}:{v.line} [{v.rule}] {v.message}")

    try:
        baseline = load_baseline(baseline_path)
    except FileNotFoundError:
        baseline = None
        failures.append(
            f"no baseline at {os.path.relpath(baseline_path, root)}; run "
            f"'python -m repro.analysis --update-baseline' and commit it")
    except ValueError as e:
        baseline = None
        failures.append(f"bad baseline: {e}")

    if baseline is not None:
        fp = _rules.config_fingerprint()
        if baseline.get("config_fingerprint") != fp:
            failures.append(
                "config drift: the rule set or its scopes/allowlists "
                "changed but the baseline was not regenerated; rerun "
                "'python -m repro.analysis --update-baseline' so the "
                "change is reviewed, not silent")
        base_counts = {k: int(v) for k, v in
                       baseline.get("violations", {}).items()}
        excess = 0
        for key in sorted(set(counts) | set(base_counts)):
            cur, base = counts.get(key, 0), base_counts.get(key, 0)
            if cur > base:
                excess += cur - base
                failures.append(
                    f"ratchet regression: {key} has {cur} unsuppressed "
                    f"violation(s), baseline allows {base}")
            elif cur < base:
                failures.append(
                    f"stale baseline: {key} improved to {cur} (baseline "
                    f"{base}) — lower the baseline in this commit "
                    f"(--update-baseline); the ratchet only ever tightens")
        if excess and excess <= max_violations:
            # inside the explicit budget: drop only the regression lines
            failures = [f for f in failures
                        if not f.startswith("ratchet regression:")]

    return AnalysisResult(root=root, violations=violations, counts=counts,
                          failures=failures, wire=wire_summary,
                          files_scanned=len(files))
