"""Runtime replay sanitizer: dynamic coverage behind the static rules.

The static pass (RA01/RA02) proves no *call site* in the scoped modules
reads a wall clock or legacy RNG; this context manager proves no *code
path* does, by patching the entry points to raise for the duration of a
replay run::

    from repro.analysis import replay_sanitizer

    with replay_sanitizer():
        _, report = manager.run(frames)        # raises on time.time() etc.
    assert report.signature() == expected

What is patched by default:

  * ``time.time/time_ns/monotonic/monotonic_ns/process_time/process_time_ns``
    — the clocks that would leak wall time into virtual-clock state;
  * the legacy global-state numpy RNG (``np.random.rand/randint/seed/...``
    and ``np.random.random``) and the stdlib ``random`` module functions —
    process-global entropy that would desynchronize replays.

``time.perf_counter`` is deliberately NOT patched by default: it is the
sanctioned measurement clock at the RA01-allowlisted sites (the gateway
warm-timing helpers, ``obs/hooks.py``) which legitimately run inside a
replay — their readings feed measured-cost telemetry, never replayed
state. Pass ``strict=True`` to forbid it too (useful when replaying under
``LinearCostModel``/frozen ``CalibratedCostModel``, where nothing should
measure at all).

Explicit-state APIs — ``np.random.default_rng``, ``np.random.Generator``,
``random.Random(seed)`` instances, ``jax.random`` — keep working: seeded
streams are exactly what replay relies on.
"""
from __future__ import annotations

import random as _py_random
import time as _time
from contextlib import contextmanager

import numpy as _np

__all__ = ["ReplaySanitizerError", "replay_sanitizer"]


class ReplaySanitizerError(RuntimeError):
    """A forbidden wall-clock / global-RNG entry point fired during a
    sanitized replay run."""


_TIME_FNS = ("time", "time_ns", "monotonic", "monotonic_ns",
             "process_time", "process_time_ns")
_STRICT_TIME_FNS = ("perf_counter", "perf_counter_ns")
_NP_RANDOM_FNS = ("random", "rand", "randn", "randint", "random_sample",
                  "ranf", "sample", "choice", "shuffle", "permutation",
                  "uniform", "normal", "standard_normal", "seed",
                  "get_state", "set_state")
_PY_RANDOM_FNS = ("random", "randint", "randrange", "choice", "choices",
                  "shuffle", "sample", "uniform", "gauss", "normalvariate",
                  "betavariate", "expovariate", "seed", "getrandbits")


def _forbid(qualname: str, hint: str):
    def _raise(*args, **kwargs):
        raise ReplaySanitizerError(
            f"{qualname}() called during a sanitized replay run; {hint} "
            f"(rules RA01/RA02, docs/ANALYSIS.md)")
    _raise.__name__ = f"forbidden_{qualname.replace('.', '_')}"
    return _raise


@contextmanager
def replay_sanitizer(*, strict: bool = False):
    """Patch wall-clock + legacy-RNG entry points to raise; restore on exit.

    strict : also forbid ``time.perf_counter`` — only for replays where even
             the allowlisted measurement sites must stay cold (frozen cost
             models).
    """
    patched: list[tuple[object, str, object]] = []

    def patch(mod, name: str, hint: str) -> None:
        original = getattr(mod, name, None)
        if original is None:                 # pragma: no cover - numpy skew
            return
        patched.append((mod, name, original))
        setattr(mod, name, _forbid(f"{mod.__name__}.{name}", hint))

    clock_hint = ("replay paths must read the event-loop virtual clock; "
                  "wall measurement belongs only at allowlisted sites "
                  "using time.perf_counter")
    rng_hint = ("thread an explicitly seeded np.random.Generator / "
                "random.Random through instead")
    fns = _TIME_FNS + (_STRICT_TIME_FNS if strict else ())
    for name in fns:
        patch(_time, name, clock_hint)
    for name in _NP_RANDOM_FNS:
        patch(_np.random, name, rng_hint)
    for name in _PY_RANDOM_FNS:
        patch(_py_random, name, rng_hint)
    try:
        yield
    finally:
        for mod, name, original in reversed(patched):
            setattr(mod, name, original)
