"""`repro.analysis`: the invariant linter + replay sanitizer.

Every CI gate this repo ships — bit-identical replay under
``LinearCostModel``/``CalibratedCostModel``, byte-identical traces,
SSF1/RTC1/BaF2 wire stability, session replay signatures — rests on
invariants that used to live only in reviewers' heads:

  * no wall clock on virtual-clock paths (one silent ``time.time()`` in the
    gateway event loop breaks every replay gate at once),
  * no unseeded legacy RNG or set-iteration order feeding wire bytes or
    schedules,
  * no raw ``jax.experimental`` use outside the compat shims (the exact
    API-skew class behind the 40 seed failures PR 2 burned down),
  * no wire-layout change without a :func:`repro.serve.codec_revision` bump.

This package makes them machine-checked. ``python -m repro.analysis --check``
runs an AST-based pass (stdlib only, no third-party deps) over ``src/``,
``benchmarks/``, ``examples/`` and ``tests/``, compares unsuppressed
violations against the committed ratchet baseline
(``src/repro/analysis/baseline.json`` — counts may only go down, mirroring
the tier-1 failure ratchet), verifies the committed wire-schema fingerprints
(``wire_schema.json``) for the BaF2/RTC1/SSF1 formats, and emits a
machine-readable JSON report for CI.

Layout:

  * :mod:`repro.analysis.rules`     — the rule registry (RA01..RA06) + config
  * :mod:`repro.analysis.engine`    — file discovery, pragmas, ratchet, report
  * :mod:`repro.analysis.wire`      — RA04 wire-schema fingerprints
  * :mod:`repro.analysis.fixes`     — the ``--fix`` autofixer (mechanical rules)
  * :mod:`repro.analysis.sanitizer` — the opt-in runtime replay sanitizer

Suppressions are inline pragmas with a mandatory reason::

    t0 = time.perf_counter()  # repro: allow[RA01] -- measures real compute wall time

A pragma without a reason, or one that suppresses nothing, is itself a
violation (rule RA00) and can never be baselined away. See docs/ANALYSIS.md
for the full catalog and workflow.
"""
from __future__ import annotations

from repro.analysis.engine import (AnalysisResult, Violation, load_baseline,
                                   run_analysis, write_baseline)
from repro.analysis.rules import CONFIG, RULES, config_fingerprint
from repro.analysis.sanitizer import ReplaySanitizerError, replay_sanitizer

__all__ = [
    "AnalysisResult", "Violation", "run_analysis",
    "load_baseline", "write_baseline",
    "CONFIG", "RULES", "config_fingerprint",
    "ReplaySanitizerError", "replay_sanitizer",
]
