"""Back-and-Forth (BaF) prediction — paper §3.3, Fig. 2, eq. (6).

Backward: dequantized selected channels  Ẑ_C  --inverse-BN-->  pre-BN values
          --deconv net (4 conv layers, PReLU, first layer x2 upsample)-->
          estimate of ALL input channels X̃ of the split layer.
Forward:  frozen split-layer conv (stride 2) + BN  -->  estimate Z̃ of ALL P
          BN-output channels.
Consolidation (eq. 6): on the C transmitted channels, keep Z̃ where it falls in
the transmitted quantizer bin, else clamp to the nearest bin boundary
(= clip(Z̃, bin_lo, bin_hi)).

Two variants:
  * conv (faithful, for the Tier-A CNN reproduction),
  * stream (adapted, for (B, S, D) transformer hidden states at pod/split
    boundaries — the "forward" re-application is the frozen transformer block).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.core.quant import QuantParams, bin_bounds, quantize


# ---------------------------------------------------------------------------
# Consolidation — eq. (6)
# ---------------------------------------------------------------------------

def consolidate(z_tilde_sel: jax.Array, codes: jax.Array,
                qp: QuantParams) -> jax.Array:
    """Eq. (6) on the transmitted channels.

    z_tilde_sel : (..., C) BaF estimates of the transmitted channels
    codes       : (..., C) integer codes actually received
    Keeping Z̃ when quantize(Z̃)==code and otherwise clamping to the nearest
    boundary of the code's bin is exactly ``clip(Z̃, bin_lo, bin_hi)``:
    inside the bin the clip is the identity, outside it returns the nearest
    boundary value. Pure-jnp reference; fused kernel in kernels/consolidate.py.
    """
    lo, hi = bin_bounds(codes, qp)
    return jnp.clip(z_tilde_sel.astype(jnp.float32), lo, hi).astype(z_tilde_sel.dtype)


def scatter_consolidated(z_tilde: jax.Array, consolidated: jax.Array,
                         sel_idx: jax.Array) -> jax.Array:
    """Write consolidated transmitted channels back into the full tensor."""
    return z_tilde.at[..., sel_idx].set(consolidated.astype(z_tilde.dtype))


# ---------------------------------------------------------------------------
# Conv BaF predictor (Tier A — faithful)
# ---------------------------------------------------------------------------

class BaFConvConfig(NamedTuple):
    c: int            # transmitted channels
    q: int            # input channels of the split layer (backward target)
    hidden: int = 64  # width of the deconv net (paper does not specify)
    dtype: object = jnp.float32


def init_baf_conv(key, cfg: BaFConvConfig):
    """4 conv layers, 3x3, PReLU except identity on the last (Fig. 2)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.dtype
    return {
        # first layer performs the x2 upsampling (transposed conv)
        "up": nn.init_conv(k1, cfg.c, cfg.hidden, 3, dtype=d),
        "up_act": nn.init_prelu(cfg.hidden, d),
        "c2": nn.init_conv(k2, cfg.hidden, cfg.hidden, 3, dtype=d),
        "c2_act": nn.init_prelu(cfg.hidden, d),
        "c3": nn.init_conv(k3, cfg.hidden, cfg.hidden, 3, dtype=d),
        "c3_act": nn.init_prelu(cfg.hidden, d),
        "c4": nn.init_conv(k4, cfg.hidden, cfg.q, 3, dtype=d),  # identity act
    }


def baf_conv_backward(params, z_hat_sel: jax.Array, bn_sel: dict,
                      *, dtype=None) -> jax.Array:
    """Ẑ_C (B,H,W,C) -> X̃ (B,2H,2W,Q). Starts with inverse BN (paper §3.3)."""
    x = nn.batchnorm_inverse(bn_sel, z_hat_sel)
    x = nn.conv_transpose_apply(params["up"], x, stride=2, dtype=dtype)
    x = nn.prelu_apply(params["up_act"], x)
    x = nn.conv_apply(params["c2"], x, dtype=dtype)
    x = nn.prelu_apply(params["c2_act"], x)
    x = nn.conv_apply(params["c3"], x, dtype=dtype)
    x = nn.prelu_apply(params["c3_act"], x)
    x = nn.conv_apply(params["c4"], x, dtype=dtype)  # identity activation
    return x


def baf_conv_forward(split_conv, split_bn, x_tilde: jax.Array,
                     *, stride=2, dtype=None) -> jax.Array:
    """Forward predictor: frozen layer-l conv + BN -> Z̃ (all P channels)."""
    y = nn.conv_apply(split_conv, x_tilde, stride=stride, dtype=dtype)
    return nn.batchnorm_apply(split_bn, y)


def gather_bn(bn: dict, sel_idx) -> dict:
    """Per-channel BN params restricted to the selected channels."""
    return {k: v[sel_idx] for k, v in bn.items()}


def baf_conv_predict(baf_params, split_conv, split_bn, sel_idx,
                     z_hat_sel: jax.Array, *,
                     codes: jax.Array | None = None,
                     qp: QuantParams | None = None,
                     dtype=None) -> jax.Array:
    """Full BaF pipeline: backward + forward (+ consolidation when codes given).

    Returns Z̃ with all P channels (pre-activation). Training calls this with
    codes=None (consolidation ignored during training, paper §4).
    """
    bn_sel = gather_bn(split_bn, sel_idx)
    x_tilde = baf_conv_backward(baf_params, z_hat_sel, bn_sel, dtype=dtype)
    z_tilde = baf_conv_forward(split_conv, split_bn, x_tilde, dtype=dtype)
    if codes is not None:
        assert qp is not None
        cons = consolidate(z_tilde[..., sel_idx], codes, qp)
        z_tilde = scatter_consolidated(z_tilde, cons, sel_idx)
    return z_tilde


# ---------------------------------------------------------------------------
# Stream BaF predictor (Tier B/C — transformer hidden states)
# ---------------------------------------------------------------------------

class BaFStreamConfig(NamedTuple):
    c: int              # transmitted channels of the D-dim stream
    d_in: int           # dim of the backward-prediction target (block input)
    hidden: int = 512
    dtype: object = jnp.float32


def init_baf_stream(key, cfg: BaFStreamConfig):
    """Gated-MLP backward predictor for (B, S, D) streams.

    4 projections mirroring the conv variant's depth: in -> hidden (PReLU) ->
    hidden (PReLU) -> hidden (PReLU) -> d_in (identity). No upsampling: stream
    splits are stride-1 (DESIGN.md §5).
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.dtype
    return {
        "l1": nn.init_dense(k1, cfg.c, cfg.hidden, dtype=d),
        "a1": nn.init_prelu(cfg.hidden, d),
        "l2": nn.init_dense(k2, cfg.hidden, cfg.hidden, dtype=d),
        "a2": nn.init_prelu(cfg.hidden, d),
        "l3": nn.init_dense(k3, cfg.hidden, cfg.hidden, dtype=d),
        "a3": nn.init_prelu(cfg.hidden, d),
        "l4": nn.init_dense(k4, cfg.hidden, cfg.d_in, dtype=d),
    }


def baf_stream_backward(params, z_hat_sel: jax.Array, *, dtype=None) -> jax.Array:
    x = nn.dense_apply(params["l1"], z_hat_sel, dtype=dtype)
    x = nn.prelu_apply(params["a1"], x)
    x = nn.dense_apply(params["l2"], x, dtype=dtype)
    x = nn.prelu_apply(params["a2"], x)
    x = nn.dense_apply(params["l3"], x, dtype=dtype)
    x = nn.prelu_apply(params["a3"], x)
    return nn.dense_apply(params["l4"], x, dtype=dtype)


def baf_stream_predict(baf_params, forward_fn: Callable[[jax.Array], jax.Array],
                       sel_idx, z_hat_sel: jax.Array, *,
                       codes: jax.Array | None = None,
                       qp: QuantParams | None = None,
                       dtype=None) -> jax.Array:
    """Stream BaF: backward MLP -> frozen block re-application -> consolidation.

    ``forward_fn`` is the frozen sender-side block (the transformer analogue of
    the paper's layer-l conv+BN).
    """
    x_tilde = baf_stream_backward(baf_params, z_hat_sel, dtype=dtype)
    z_tilde = forward_fn(x_tilde)
    if codes is not None:
        assert qp is not None
        cons = consolidate(z_tilde[..., sel_idx], codes, qp)
        z_tilde = scatter_consolidated(z_tilde, cons, sel_idx)
    return z_tilde
