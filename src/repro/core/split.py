"""Split-inference engine: edge -> (quantize/tile/entropy-code) -> channel ->
(decode/dequantize) -> BaF restore -> cloud.  Paper Fig. 1, end to end.

Device-side math (quantize, BaF, consolidation) is jit-able JAX; the entropy
codec is host code (DESIGN.md §4). The engine measures real bits on the wire,
including the C*32 side-info bits, matching the paper's accounting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as wire
from repro.core.baf import baf_conv_predict
from repro.core.quant import QuantParams, compute_quant_params, dequantize, quantize
from repro.core.tiling import tile_batch, untile_batch


@dataclass
class SplitStats:
    total_bits: int
    payload_bits: int
    side_info_bits: int
    raw_bits: int            # uncompressed fp32 full-tensor bits (reference)
    entropy_bits: float      # order-0 entropy floor of the code stream

    @property
    def reduction_vs_raw(self) -> float:
        return 1.0 - self.total_bits / self.raw_bits


class SplitInferenceEngine:
    """Orchestrates the paper's mobile/cloud pipeline for the Tier-A CNN.

    Parameters
    ----------
    params : CNN params (see models/cnn.py)
    baf_params : trained BaF predictor params (core/baf.py)
    sel_idx : ordered selected-channel indices (core/selection.py), length C
    bits : quantizer depth n
    backend : wire codec backend ('zlib' | 'png' | 'raw')
    """

    def __init__(self, params, baf_params, sel_idx, *, bits: int = 8,
                 backend: str = "zlib", consolidation: bool = True):
        from repro.models.cnn import cnn_cloud, cnn_edge  # local: avoid cycle
        self._edge_fn = jax.jit(lambda p, img: cnn_edge(p, img)[1])
        self._cloud_fn = jax.jit(cnn_cloud)
        self.params = params
        self.baf_params = baf_params
        self.sel_idx = jnp.asarray(np.asarray(sel_idx), jnp.int32)
        self.bits = bits
        self.backend = backend
        self.consolidation = consolidation

        def _restore(baf_params, split, codes, qp_mins, qp_maxs):
            qp = QuantParams(qp_mins, qp_maxs, self.bits)
            z_hat_sel = dequantize(codes, qp)
            return baf_conv_predict(
                baf_params, split["conv"], split["bn"], self.sel_idx, z_hat_sel,
                codes=codes if self.consolidation else None,
                qp=qp if self.consolidation else None)

        self._restore_fn = jax.jit(_restore)

    # -- mobile side --------------------------------------------------------
    def encode(self, img) -> tuple[wire.EncodedTensor, SplitStats]:
        z = self._edge_fn(self.params, img)            # (B, H, W, P)
        z_sel = z[..., self.sel_idx]                   # (B, H, W, C)
        # per-example side info, as transmitted in the paper (one m,M per
        # channel per image; counted at 32 bits/channel in total_bits)
        qp = compute_quant_params(z_sel, self.bits, per_example=True)
        codes = np.asarray(quantize(z_sel, qp))
        tiled = np.asarray(tile_batch(jnp.asarray(codes)))   # (B, rH, cW)
        # one tiled image per batch element, concatenated vertically on the wire
        stream = tiled.reshape(-1, tiled.shape[-1])
        enc = wire.encode(stream, qp, backend=self.backend)
        stats = SplitStats(
            total_bits=enc.total_bits(),
            payload_bits=8 * len(enc.payload),
            side_info_bits=8 * len(enc.side_info),
            raw_bits=int(np.prod(z.shape)) * 32,
            entropy_bits=wire.empirical_entropy_bits(codes, self.bits),
        )
        return enc, stats

    # -- cloud side ----------------------------------------------------------
    def decode_and_infer(self, enc: wire.EncodedTensor, batch: int):
        stream, qp = wire.decode(enc)
        tiled = stream.reshape(batch, -1, stream.shape[-1])
        codes = untile_batch(jnp.asarray(tiled), len(self.sel_idx))
        c = len(self.sel_idx)
        mins = jnp.asarray(qp.mins).reshape(batch, 1, 1, c)
        maxs = jnp.asarray(qp.maxs).reshape(batch, 1, 1, c)
        z_tilde = self._restore_fn(self.baf_params, self.params["split"],
                                   codes, mins, maxs)
        return self._cloud_fn(self.params, z_tilde)

    # -- fidelity metrics ------------------------------------------------------
    def fidelity(self, img):
        """Continuous restoration metrics (the mAP proxy saturates on the
        synthetic task; these expose the C/n degradation trends):
        (psnr_db of sigma(Z_tilde) vs sigma(Z), mean KL(cloud || split) of
        the downstream logits)."""
        import jax.nn as jnn
        from repro import nn as _nn
        x_in_z = jax.jit(lambda p, i: __import__("repro.models.cnn",
                         fromlist=["cnn_edge"]).cnn_edge(p, i))(self.params, img)
        z = x_in_z[1]
        z_sel = z[..., self.sel_idx]
        qp = compute_quant_params(z_sel, self.bits, per_example=True)
        codes = quantize(z_sel, qp)
        z_tilde = self._restore_fn(self.baf_params, self.params["split"],
                                   codes, qp.mins, qp.maxs)
        y_true = _nn.leaky_relu(z).astype(jnp.float32)
        y_rest = _nn.leaky_relu(z_tilde).astype(jnp.float32)
        mse = float(jnp.mean(jnp.square(y_true - y_rest)))
        peak = float(jnp.max(jnp.abs(y_true))) or 1.0
        psnr = 10.0 * np.log10(peak * peak / max(mse, 1e-12))
        logits_split = self._cloud_fn(self.params, z_tilde)
        logits_cloud = self._cloud_fn(self.params, z)
        p_cloud = jnn.log_softmax(logits_cloud.astype(jnp.float32))
        p_split = jnn.log_softmax(logits_split.astype(jnp.float32))
        kl = float(jnp.mean(jnp.sum(jnp.exp(p_cloud) * (p_cloud - p_split), -1)))
        return psnr, kl

    # -- end to end ----------------------------------------------------------
    def __call__(self, img):
        enc, stats = self.encode(img)
        blob = enc.to_bytes()                          # actual wire round-trip
        logits = self.decode_and_infer(wire.EncodedTensor.from_bytes(blob),
                                       batch=img.shape[0])
        return logits, stats
