"""Split-inference engine: edge -> (quantize/tile/entropy-code) -> channel ->
(decode/dequantize) -> BaF restore -> cloud.  Paper Fig. 1, end to end.

Device-side math (quantize, BaF, consolidation) is jit-able JAX; the entropy
codec is host code (DESIGN.md §4). The engine measures real bits on the wire,
including the C*32 side-info bits, matching the paper's accounting.

Coding configuration now lives in ``repro.pipeline``: build an
``OperatingPoint``, ``compile`` it against a ``ModelSpec``, and run the plan's
``encode`` / ``decode_batch`` / ``restore``. This module keeps the jitted
device-side restore functions (one trace per ``(C, bits, batch-bucket)``,
shared process-wide) plus ``SplitInferenceEngine``, the single-operating-point
wrapper, which itself executes a plan. The loose-tuple entry points
``encode_activation`` / ``decode_stream`` served their one deprecation
release and are gone — see docs/MIGRATION.md for the mapping.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baf import baf_conv_predict, scatter_consolidated
from repro.core.quant import QuantParams, compute_quant_params, dequantize, quantize


@dataclass(frozen=True)
class ActivationStats:
    """Cheap per-request content descriptor of the selected split channels.

    The quantizer step scales with the content's dynamic range and the PSNR
    peak follows the content's peak, so these two numbers are enough for the
    rate controller to shift calibration-time RD-table PSNRs toward *this*
    request (serve/rate_control.py ContentKeyedController).
    """
    peak: float          # max |z_sel| over the example
    dyn_range: float     # mean over channels of per-channel (max - min)


def activation_stats(z, sel_idx) -> ActivationStats:
    """O(HWC) statistics of ``z[..., sel_idx]`` — no quantize/codec work.

    z: (B, H, W, P) split activation (any leading batch shape); stats are
    aggregated over the whole array (callers pass one request at a time).
    """
    z_sel = np.asarray(z)[..., np.asarray(sel_idx)]
    flat = z_sel.reshape(-1, z_sel.shape[-1]).astype(np.float32)
    peak = float(np.max(np.abs(flat))) if flat.size else 0.0
    rng = float(np.mean(np.max(flat, 0) - np.min(flat, 0))) if flat.size else 0.0
    return ActivationStats(peak=peak, dyn_range=rng)


@dataclass
class SplitStats:
    total_bits: int
    payload_bits: int
    side_info_bits: int
    raw_bits: int            # uncompressed fp32 full-tensor bits (reference)
    entropy_bits: float      # order-0 entropy floor of the code stream
    wire_bits: int = 0       # actual container bytes * 8 (header included) —
                             # what the channel/scheduler meter

    @property
    def reduction_vs_raw(self) -> float:
        return 1.0 - self.total_bits / self.raw_bits


@partial(jax.jit, static_argnames=("bits", "consolidation"))
def restore_codes(baf_params, split, sel_idx, codes, mins, maxs, *,
                  bits: int, consolidation: bool = True):
    """Dequantize + BaF restore at one operating point (reference path).

    One compile per distinct (C, bits, consolidation, batch-bucket shape);
    callers that bucket their batches (serve/batcher.py) never re-trace.
    """
    qp = QuantParams(mins, maxs, bits)
    z_hat_sel = dequantize(codes, qp)
    return baf_conv_predict(
        baf_params, split["conv"], split["bn"], sel_idx, z_hat_sel,
        codes=codes if consolidation else None,
        qp=qp if consolidation else None)


@partial(jax.jit, static_argnames=("bits",))
def restore_codes_fused(baf_params, split, sel_idx, codes, mins, maxs, *,
                        bits: int):
    """Batched restore with the fused Pallas consolidation kernel.

    Same math as ``restore_codes(consolidation=True)`` but eq. (6) runs through
    kernels/consolidate.py: bounds are rebuilt from codes + side info in VMEM
    instead of materializing (lo, hi) in HBM — the hot path for micro-batched
    gateway serving.
    """
    from repro.kernels.consolidate import consolidate_pallas
    qp = QuantParams(mins, maxs, bits)
    z_hat_sel = dequantize(codes, qp)
    z_tilde = baf_conv_predict(baf_params, split["conv"], split["bn"],
                               sel_idx, z_hat_sel)
    b, h, w, c = codes.shape
    r = h * w
    block_r = 512 if r % 512 == 0 else r
    cons = consolidate_pallas(
        z_tilde[..., sel_idx].reshape(b, r, c),
        codes.reshape(b, r, c),
        mins.reshape(b, c), maxs.reshape(b, c),
        bits, block_r=block_r)
    return scatter_consolidated(z_tilde, cons.reshape(b, h, w, c), sel_idx)


@lru_cache(maxsize=1)
def _jitted_cnn_fns():
    # lazy: models.cnn is imported on first use (mirrors the engine's local
    # import), but the jit wrappers are cached so repeated fidelity sweeps
    # (build_rd_table) trace each network once per shape, not once per call
    from repro.models.cnn import cnn_cloud, cnn_edge
    return (jax.jit(lambda p, i: cnn_edge(p, i)[1]), jax.jit(cnn_cloud))


def fidelity_metrics(params, baf_params, sel_idx, img, *, bits: int,
                     consolidation: bool = True, z=None):
    """Continuous restoration metrics at one (C, bits) operating point.

    The mAP proxy saturates on the synthetic task; these expose the C/n
    degradation trends: (psnr_db of sigma(Z_tilde) vs sigma(Z), mean
    KL(cloud || split) of the downstream logits). Pass a precomputed split
    activation ``z`` to skip the edge forward (rate-controller sweeps).
    """
    import jax.nn as jnn

    from repro import nn as _nn

    edge_fn, cloud_fn = _jitted_cnn_fns()
    sel_idx = jnp.asarray(np.asarray(sel_idx), jnp.int32)
    if z is None:
        z = edge_fn(params, img)
    z_sel = z[..., sel_idx]
    qp = compute_quant_params(z_sel, bits, per_example=True)
    codes = quantize(z_sel, qp)
    z_tilde = restore_codes(baf_params, params["split"], sel_idx, codes,
                            qp.mins, qp.maxs, bits=bits,
                            consolidation=consolidation)
    y_true = _nn.leaky_relu(z).astype(jnp.float32)
    y_rest = _nn.leaky_relu(z_tilde).astype(jnp.float32)
    mse = float(jnp.mean(jnp.square(y_true - y_rest)))
    peak = float(jnp.max(jnp.abs(y_true))) or 1.0
    psnr = 10.0 * np.log10(peak * peak / max(mse, 1e-12))
    logits_split = cloud_fn(params, z_tilde)
    logits_cloud = cloud_fn(params, z)
    p_cloud = jnn.log_softmax(logits_cloud.astype(jnp.float32))
    p_split = jnn.log_softmax(logits_split.astype(jnp.float32))
    kl = float(jnp.mean(jnp.sum(jnp.exp(p_cloud) * (p_cloud - p_split), -1)))
    return psnr, kl


# ---------------------------------------------------------------------------
# Single-operating-point engine (thin wrapper over the pure paths)
# ---------------------------------------------------------------------------

class SplitInferenceEngine:
    """Orchestrates the paper's mobile/cloud pipeline for the Tier-A CNN.

    A thin wrapper that compiles one :class:`repro.pipeline.CompressionPlan`
    at construction and executes it end to end (the plan is exposed as
    ``self.plan`` for callers that want the staged API).

    Parameters
    ----------
    params : CNN params (see models/cnn.py)
    baf_params : trained BaF predictor params (core/baf.py)
    sel_idx : ordered selected-channel indices (core/selection.py), length C
    bits : quantizer depth n
    backend : wire codec backend ('zlib' | 'png' | 'raw' | 'rans' | ...)
    """

    def __init__(self, params, baf_params, sel_idx, *, bits: int = 8,
                 backend: str = "zlib", consolidation: bool = True):
        from repro import pipeline                     # lazy: avoid cycle
        from repro.models.cnn import cnn_cloud, cnn_edge
        self._edge_fn = jax.jit(lambda p, img: cnn_edge(p, img)[1])
        self._cloud_fn = jax.jit(cnn_cloud)
        self.params = params
        self.baf_params = baf_params
        self.sel_idx = jnp.asarray(np.asarray(sel_idx), jnp.int32)
        self.bits = bits
        self.backend = backend
        self.consolidation = consolidation
        self.op = pipeline.OperatingPoint(c=int(self.sel_idx.shape[0]),
                                          bits=bits, backend=backend)
        self.spec = pipeline.ModelSpec(sel_idx=np.asarray(sel_idx),
                                       params=params, baf_params=baf_params)
        self.plan = pipeline.compile(self.op, self.spec, fused=False,
                                     consolidation=consolidation)

    # -- mobile side --------------------------------------------------------
    def encode(self, img):
        """Edge forward + plan encode -> (WireBlob, SplitStats)."""
        z = self._edge_fn(self.params, img)            # (B, H, W, P)
        blob = self.plan.encode(z)
        return blob, blob.stats

    # -- cloud side ----------------------------------------------------------
    def decode_and_infer(self, enc, batch: int):
        """Decode + BaF restore + cloud forward.

        Accepts a plan ``WireBlob`` or a bare ``EncodedTensor`` (legacy
        callers that shipped raw wire tensors around).
        """
        from repro import pipeline
        blob = (enc if isinstance(enc, pipeline.WireBlob)
                else pipeline.blob_from_tensor(enc, self.op, batch))
        z_tilde = self.plan.restore(self.plan.decode(blob))
        return self._cloud_fn(self.params, z_tilde)

    # -- fidelity metrics ------------------------------------------------------
    def fidelity(self, img):
        """Continuous restoration metrics — see :func:`fidelity_metrics`."""
        return fidelity_metrics(self.params, self.baf_params, self.sel_idx,
                                img, bits=self.bits,
                                consolidation=self.consolidation)

    # -- end to end ----------------------------------------------------------
    def __call__(self, img):
        blob, stats = self.encode(img)
        # decode parses blob.data through EncodedTensor.from_bytes — the
        # actual wire round-trip, header validation included
        logits = self.decode_and_infer(blob, batch=img.shape[0])
        return logits, stats
