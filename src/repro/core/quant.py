"""Per-channel uniform scalar quantization — paper eqs. (4)-(5).

Channel-last convention: a "tensor" is (..., C) with one (min, max) pair per
channel, stored at fp16 precision as in the paper (C*32 bits of side info).

These are the pure-jnp reference implementations; the fused TPU hot path lives
in ``repro.kernels.quantize`` and is validated against these.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QuantParams(NamedTuple):
    """Side information transmitted with the code stream (fp16, per channel)."""
    mins: jax.Array   # (C,) fp16
    maxs: jax.Array   # (C,) fp16
    bits: int

    @property
    def levels(self) -> int:
        return (1 << self.bits) - 1

    def step(self, dtype=jnp.float32) -> jax.Array:
        rng = self.maxs.astype(dtype) - self.mins.astype(dtype)
        return rng / self.levels

    def side_info_bits(self) -> int:
        # paper §3.2: min and max at fp16 => 32 bits per (channel, example)
        return int(self.mins.size) * 32


def compute_quant_params(x: jax.Array, bits: int, *,
                         per_example: bool = False) -> QuantParams:
    """Per-channel min/max, rounded to fp16 (paper).

    per_example=False: one (m, M) per channel over all leading dims.
    per_example=True : one (m, M) per (batch element, channel) — the paper's
    setting (each transmitted tensor carries its own side info); mins/maxs are
    kept with singleton spatial dims so they broadcast against x.
    """
    if per_example:
        reduce_axes = tuple(range(1, x.ndim - 1))
        mins = jnp.min(x, axis=reduce_axes, keepdims=True).astype(jnp.float16)
        maxs = jnp.max(x, axis=reduce_axes, keepdims=True).astype(jnp.float16)
    else:
        reduce_axes = tuple(range(x.ndim - 1))
        mins = jnp.min(x, axis=reduce_axes).astype(jnp.float16)
        maxs = jnp.max(x, axis=reduce_axes).astype(jnp.float16)
    # fp16 rounding of the max can land *below* a data point; widen to the
    # next representable so codes never exceed 2^n - 1. Saturate at the finite
    # fp16 extremes: nextafter(±65504) and the cast of out-of-range values are
    # ±inf, and an infinite range zeroes every code and dequantizes to NaN.
    f16_max = jnp.asarray(65504.0, jnp.float16)
    mins = jnp.maximum(mins, -f16_max)
    maxs = jnp.minimum(
        jnp.maximum(maxs, jnp.nextafter(maxs, jnp.asarray(jnp.inf, jnp.float16))),
        f16_max)
    return QuantParams(mins=mins, maxs=maxs, bits=bits)


def quantize(x: jax.Array, qp: QuantParams) -> jax.Array:
    """Eq. (4): round((x - m)/(M - m) * (2^n - 1)) -> integer codes (uint8/16/32)."""
    m = qp.mins.astype(jnp.float32)
    M = qp.maxs.astype(jnp.float32)
    rng = jnp.maximum(M - m, 1e-12)
    scaled = (x.astype(jnp.float32) - m) / rng * qp.levels
    codes = jnp.clip(jnp.round(scaled), 0, qp.levels)
    if qp.bits <= 8:
        return codes.astype(jnp.uint8)
    if qp.bits <= 16:
        return codes.astype(jnp.uint16)
    return codes.astype(jnp.uint32)


def dequantize(codes: jax.Array, qp: QuantParams, dtype=jnp.float32) -> jax.Array:
    """Eq. (5): codes/(2^n - 1) * (M - m) + m."""
    m = qp.mins.astype(jnp.float32)
    M = qp.maxs.astype(jnp.float32)
    x = codes.astype(jnp.float32) / qp.levels * (M - m) + m
    return x.astype(dtype)


def bin_bounds(codes: jax.Array, qp: QuantParams):
    """Dequantized bounds of the quantizer bin each code occupies.

    Bin k (obtained by round()) covers scaled values [k-1/2, k+1/2]; mapped back
    to the data domain that is ``m + (k ± 1/2) * step``. Used by consolidation
    (eq. 6): the value closest to an estimate while staying inside the
    transmitted bin is ``clip(estimate, lo, hi)``.
    """
    m = qp.mins.astype(jnp.float32)
    step = qp.step()
    c = codes.astype(jnp.float32)
    lo = m + (c - 0.5) * step
    hi = m + (c + 0.5) * step
    return lo, hi


def quantization_mse(x: jax.Array, bits: int) -> jax.Array:
    """Round-trip MSE at a given bit depth (analysis helper)."""
    qp = compute_quant_params(x, bits)
    return jnp.mean(jnp.square(dequantize(quantize(x, qp), qp) - x))
