"""Lossless wire codec for quantized/tiled tensors — host-side by design.

The paper compresses the tiled image with FLIF (or the lossless tool of [5], or
HEVC). None of those binaries are available here, and entropy coding is branchy
integer code with no TPU analogue (DESIGN.md §4), so the wire format uses:

  * ``zlib``  — DEFLATE over n-bit-packed codes (default; conservative stand-in
                for FLIF: FLIF is strictly better, so reported reductions are a
                lower bound on the paper's),
  * ``png``   — PIL PNG for 8-bit tiled images (the codec of prior work [3]),
  * ``raw``   — n-bit packing only (no entropy coding),
  * plus an empirical-entropy estimate as a codec-independent floor.

Bit accounting follows the paper: payload bits + C*32 bits of fp16 min/max side
info are all counted.
"""
from __future__ import annotations

import io
import math
import struct
import zlib
from dataclasses import dataclass

import numpy as np

from repro.core.quant import QuantParams

MAGIC = b"BaF1"


# ---------------------------------------------------------------------------
# n-bit packing
# ---------------------------------------------------------------------------

def pack_bits(codes: np.ndarray, bits: int) -> bytes:
    """Pack integer codes (values < 2^bits) into a dense little-endian bitstream."""
    flat = np.asarray(codes, dtype=np.uint64).ravel()
    if bits == 8:
        return flat.astype(np.uint8).tobytes()
    if bits == 16:
        return flat.astype(np.uint16).tobytes()
    n = flat.size
    total_bits = n * bits
    out = np.zeros((total_bits + 7) // 8, dtype=np.uint8)
    positions = np.arange(n, dtype=np.uint64) * bits
    for b in range(bits):
        bitpos = positions + b
        byte_idx = (bitpos >> 3).astype(np.int64)
        bit_in_byte = (bitpos & 7).astype(np.uint8)
        vals = ((flat >> np.uint64(b)) & np.uint64(1)).astype(np.uint8)
        np.bitwise_or.at(out, byte_idx, vals << bit_in_byte)
    return out.tobytes()


def unpack_bits(data: bytes, bits: int, count: int) -> np.ndarray:
    buf = np.frombuffer(data, dtype=np.uint8)
    need = (count * bits + 7) // 8
    if len(data) < need:
        raise ValueError(
            f"bitstream too short: {len(data)} bytes but {count} codes at "
            f"{bits} bits need {need}")
    if bits == 8:
        return buf[:count].copy()
    if bits == 16:
        return np.frombuffer(data[:2 * count], dtype=np.uint16).copy()
    out = np.zeros(count, dtype=np.uint32)
    positions = np.arange(count, dtype=np.uint64) * bits
    for b in range(bits):
        bitpos = positions + b
        byte_idx = (bitpos >> 3).astype(np.int64)
        bit_in_byte = (bitpos & 7).astype(np.uint8)
        vals = (buf[byte_idx] >> bit_in_byte) & 1
        out |= vals.astype(np.uint32) << b
    return out


# ---------------------------------------------------------------------------
# Wire format
# ---------------------------------------------------------------------------

@dataclass
class EncodedTensor:
    payload: bytes          # entropy-coded channel codes
    backend: str            # 'zlib' | 'png' | 'raw'
    bits: int
    shape: tuple            # original codes shape, channel-last
    side_info: bytes        # fp16 mins/maxs

    def total_bits(self) -> int:
        """Paper-style accounting: payload + C*32 side-info bits (+ header)."""
        return 8 * (len(self.payload) + len(self.side_info))

    def to_bytes(self) -> bytes:
        hdr = struct.pack(
            "<4sB B B", MAGIC, {"zlib": 0, "png": 1, "raw": 2}[self.backend],
            self.bits, len(self.shape))
        hdr += struct.pack(f"<{len(self.shape)}I", *self.shape)
        hdr += struct.pack("<I", len(self.side_info))
        return hdr + self.side_info + self.payload

    @classmethod
    def from_bytes(cls, data: bytes) -> "EncodedTensor":
        magic, backend_id, bits, ndim = struct.unpack_from("<4sB B B", data, 0)
        assert magic == MAGIC, "bad magic"
        off = 7
        shape = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        (silen,) = struct.unpack_from("<I", data, off)
        off += 4
        side_info = data[off:off + silen]
        payload = data[off + silen:]
        backend = {0: "zlib", 1: "png", 2: "raw"}[backend_id]
        return cls(payload=payload, backend=backend, bits=bits,
                   shape=tuple(shape), side_info=side_info)


def _pack_side_info(qp: QuantParams) -> bytes:
    mins = np.asarray(qp.mins, dtype=np.float16)
    maxs = np.asarray(qp.maxs, dtype=np.float16)
    return mins.tobytes() + maxs.tobytes()


def _unpack_side_info(data: bytes, bits: int) -> QuantParams:
    half = len(data) // 2
    mins = np.frombuffer(data[:half], dtype=np.float16)
    maxs = np.frombuffer(data[half:], dtype=np.float16)
    return QuantParams(mins=mins, maxs=maxs, bits=bits)


def encode(codes: np.ndarray, qp: QuantParams, backend: str = "zlib",
           level: int = 9) -> EncodedTensor:
    """Entropy-code quantized channel codes (any shape, channel-last)."""
    codes = np.asarray(codes)
    if backend == "zlib":
        payload = zlib.compress(pack_bits(codes, qp.bits), level)
    elif backend == "raw":
        payload = pack_bits(codes, qp.bits)
    elif backend == "png":
        from PIL import Image
        if qp.bits > 8:
            raise ValueError("png backend supports <=8 bits")
        if codes.size and codes.min() < 0:
            raise ValueError("png backend: negative codes are invalid")
        if codes.size and codes.max() > 255:
            raise ValueError(
                f"png backend: codes up to {int(codes.max())} do not fit in "
                "8 bits")
        img = codes.astype(np.uint8)
        if img.ndim != 2:
            raise ValueError("png backend expects a 2D tiled image")
        buf = io.BytesIO()
        Image.fromarray(img, mode="L").save(buf, format="PNG", optimize=True)
        payload = buf.getvalue()
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return EncodedTensor(payload=payload, backend=backend, bits=qp.bits,
                         shape=tuple(codes.shape), side_info=_pack_side_info(qp))


def decode(enc: EncodedTensor) -> tuple[np.ndarray, QuantParams]:
    qp = _unpack_side_info(enc.side_info, enc.bits)
    count = int(np.prod(enc.shape))
    if enc.backend == "zlib":
        codes = unpack_bits(zlib.decompress(enc.payload), enc.bits, count)
    elif enc.backend == "raw":
        codes = unpack_bits(enc.payload, enc.bits, count)
    elif enc.backend == "png":
        from PIL import Image
        img = np.asarray(Image.open(io.BytesIO(enc.payload)))
        codes = img.ravel()[:count]
    else:
        raise ValueError(enc.backend)
    dtype = np.uint8 if enc.bits <= 8 else (np.uint16 if enc.bits <= 16 else np.uint32)
    return codes.astype(dtype).reshape(enc.shape), qp


def empirical_entropy_bits(codes: np.ndarray, bits: int) -> float:
    """Order-0 empirical entropy of the code stream, in total bits.

    Codec-independent floor used in benchmarks to separate "what the quantizer
    achieved" from "what DEFLATE managed to realize".
    """
    flat = np.asarray(codes).ravel()
    counts = np.bincount(flat.astype(np.int64), minlength=1 << bits)
    p = counts[counts > 0] / flat.size
    return float(-np.sum(p * np.log2(p)) * flat.size)
