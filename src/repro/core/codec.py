"""Lossless wire codec for quantized/tiled tensors — host-side by design.

The paper compresses the tiled image with FLIF (or the lossless tool of [5],
or HEVC). This repo now ships a real entropy coder of its own: the
context-adaptive interleaved rANS subsystem in ``repro.codec``, surfaced
here behind a backend registry so every caller keeps the same
``encode``/``decode`` API:

  * ``rans``     — interleaved multi-stream rANS with static per-channel
                   frequency tables (on-device Pallas histogram -> host
                   coding pass); per-tile chunks, partial decode.
  * ``rans-ctx`` — the same coder with an adaptive quantized-up-neighbor /
                   channel context model; nothing transmitted but lane
                   states, typically at or below the order-0 entropy floor
                   on BaF residual tiles.
  * ``zlib``     — DEFLATE over n-bit-packed codes (legacy default).
  * ``png``      — PIL PNG for 8-bit tiled images (the codec of prior
                   work [3]).
  * ``raw``      — n-bit packing only (no entropy coding).

plus :func:`empirical_entropy_bits` as a codec-independent order-0 floor.

The rANS backends code the channel-last code tensor directly (their
container is documented in ``repro/codec/container.py``); the image-style
backends expect the pre-tiled 2D stream — ``backend_wants_tiling`` tells
``core/split.py`` which detour to take.

Wire format (``EncodedTensor.to_bytes``): ``BaF2`` magic, backend id, bit
depth, shape, explicit side-info and payload lengths. ``from_bytes``
validates structurally — bad magic, unknown backend, every truncation, and
trailing garbage each raise a distinct ``ValueError`` — so corrupt blobs
fail at the header, not deep inside ``unpack_bits``.

Bit accounting follows the paper: ``total_bits`` counts payload + C*32 bits
of fp16 min/max side info; ``wire_bits`` additionally counts the container
header — the number the serving channel/scheduler actually meter.
"""
from __future__ import annotations

import io
import struct
import zlib
from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.core.quant import QuantParams

MAGIC = b"BaF2"
_OLD_MAGICS = (b"BaF1",)


# ---------------------------------------------------------------------------
# n-bit packing
# ---------------------------------------------------------------------------

def pack_bits(codes: np.ndarray, bits: int) -> bytes:
    """Pack integer codes (values < 2^bits) into a dense little-endian bitstream."""
    flat = np.asarray(codes, dtype=np.uint64).ravel()
    if bits == 8:
        return flat.astype(np.uint8).tobytes()
    if bits == 16:
        # explicit little-endian, matching unpack's '<u2' view — the wire
        # format must not depend on host byte order
        return flat.astype("<u2").tobytes()
    n = flat.size
    total_bits = n * bits
    out = np.zeros((total_bits + 7) // 8, dtype=np.uint8)
    positions = np.arange(n, dtype=np.uint64) * bits
    for b in range(bits):
        bitpos = positions + b
        byte_idx = (bitpos >> 3).astype(np.int64)
        bit_in_byte = (bitpos & 7).astype(np.uint8)
        vals = ((flat >> np.uint64(b)) & np.uint64(1)).astype(np.uint8)
        np.bitwise_or.at(out, byte_idx, vals << bit_in_byte)
    return out.tobytes()


def unpack_bits(data: bytes, bits: int, count: int) -> np.ndarray:
    return unpack_bits_batch([data], bits, count)[0]


def unpack_bits_batch(streams: list[bytes], bits: int,
                      count: int) -> np.ndarray:
    """Unpack N equal-length bitstreams in one vectorized pass -> (N, count).

    Every stream packs exactly ``count`` codes at ``bits`` each (all wire
    payloads of one micro-batch bucket share an operating point and shape),
    so the per-bit gather loop runs ``bits`` times *total* instead of
    ``bits`` times per request — the coalesced host decode the batched
    pipeline (repro.pipeline) is built on.
    """
    n = len(streams)
    need = (count * bits + 7) // 8
    for i, s in enumerate(streams):
        if len(s) < need:
            raise ValueError(
                f"bitstream {i} too short: {len(s)} bytes but {count} codes "
                f"at {bits} bits need {need}")
    buf = np.stack([np.frombuffer(s, dtype=np.uint8, count=need)
                    for s in streams]) if n else np.empty((0, need), np.uint8)
    if bits == 8:
        return buf[:, :count].copy()
    if bits == 16:
        return np.ascontiguousarray(buf[:, :2 * count]).view("<u2")[:, :count]
    out = np.zeros((n, count), dtype=np.uint32)
    positions = np.arange(count, dtype=np.uint64) * bits
    for b in range(bits):
        bitpos = positions + b
        byte_idx = (bitpos >> 3).astype(np.int64)
        bit_in_byte = (bitpos & 7).astype(np.uint8)
        vals = (buf[:, byte_idx] >> bit_in_byte) & 1
        out |= vals.astype(np.uint32) << b
    return out


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _Backend:
    name: str
    wire_id: int
    tiled: bool        # expects the pre-tiled 2D image (core/split.py)
    encode: Callable   # (codes, bits, level) -> payload bytes
    decode: Callable   # (payload, shape, bits, count) -> flat/shaped codes
    # optional coalesced decode across N same-shape payloads:
    # (payloads, shape, bits, count) -> (N, count) codes. None = the batched
    # pipeline falls back to a per-payload loop over ``decode``.
    decode_batch: Callable | None = None


_REGISTRY: dict[str, _Backend] = {}
_BY_ID: dict[int, str] = {}
# name -> registrar called on first use, so importing core.codec never pulls
# in the rANS subsystem (and its Pallas kernels); populated at module bottom
_LAZY: dict[str, Callable[[], None]] = {}


def register_backend(name: str, wire_id: int, *, tiled: bool,
                     encode: Callable, decode: Callable,
                     decode_batch: Callable | None = None) -> None:
    if name in _REGISTRY:
        raise ValueError(f"backend {name!r} already registered")
    if wire_id in _BY_ID:
        raise ValueError(f"wire id {wire_id} already taken by "
                         f"{_BY_ID[wire_id]!r}")
    _REGISTRY[name] = _Backend(name=name, wire_id=wire_id, tiled=tiled,
                               encode=encode, decode=decode,
                               decode_batch=decode_batch)
    _BY_ID[wire_id] = name


def _get_backend(name: str) -> _Backend:
    if name not in _REGISTRY and name in _LAZY:
        _LAZY[name]()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered: "
                         f"{sorted(set(_REGISTRY) | set(_LAZY))}") from None


def backend_wants_tiling(name: str) -> bool:
    """Does this backend expect the channels tiled into a 2D image?"""
    return _get_backend(name).tiled


def backend_names() -> list[str]:
    return sorted(set(_REGISTRY) | set(_LAZY))


# -- built-in backends ------------------------------------------------------

def _zlib_encode(codes, bits, level):
    return zlib.compress(pack_bits(codes, bits), level)


def _zlib_decode(payload, shape, bits, count):
    return unpack_bits(zlib.decompress(payload), bits, count)


def _zlib_decode_batch(payloads, shape, bits, count):
    return unpack_bits_batch([zlib.decompress(p) for p in payloads],
                             bits, count)


def _raw_encode(codes, bits, level):
    return pack_bits(codes, bits)


def _raw_decode(payload, shape, bits, count):
    return unpack_bits(payload, bits, count)


def _raw_decode_batch(payloads, shape, bits, count):
    return unpack_bits_batch(list(payloads), bits, count)


def _png_encode(codes, bits, level):
    from PIL import Image
    if bits > 8:
        raise ValueError("png backend supports <=8 bits")
    if codes.size and codes.min() < 0:
        raise ValueError("png backend: negative codes are invalid")
    if codes.size and codes.max() > 255:
        raise ValueError(
            f"png backend: codes up to {int(codes.max())} do not fit in "
            "8 bits")
    img = codes.astype(np.uint8)
    if img.ndim != 2:
        raise ValueError("png backend expects a 2D tiled image")
    buf = io.BytesIO()
    Image.fromarray(img, mode="L").save(buf, format="PNG", optimize=True)
    return buf.getvalue()


def _png_decode(payload, shape, bits, count):
    from PIL import Image
    img = np.asarray(Image.open(io.BytesIO(payload)))
    return img.ravel()[:count]


register_backend("zlib", 0, tiled=True, encode=_zlib_encode,
                 decode=_zlib_decode, decode_batch=_zlib_decode_batch)
register_backend("png", 1, tiled=True, encode=_png_encode,
                 decode=_png_decode)
register_backend("raw", 2, tiled=True, encode=_raw_encode,
                 decode=_raw_decode, decode_batch=_raw_decode_batch)


def _register_rans_backends() -> None:
    if "rans" in _REGISTRY:
        return
    from repro.codec import (decode_tensor, encode_adaptive_tensor,
                             encode_static_tensor)
    from repro.codec.batch import decode_tensor_batch

    def _batch(payloads, shape, bits, count):
        # chunk-level interleave across the whole batch of containers —
        # one decode loop per coding geometry instead of one per blob
        return decode_tensor_batch(list(payloads), shape, bits)

    register_backend(
        "rans", 3, tiled=False,
        encode=lambda codes, bits, level: encode_static_tensor(codes, bits),
        decode=lambda payload, shape, bits, count:
            decode_tensor(payload, shape, bits),
        decode_batch=_batch)
    register_backend(
        "rans-ctx", 4, tiled=False,
        encode=lambda codes, bits, level:
            encode_adaptive_tensor(codes, bits),
        decode=lambda payload, shape, bits, count:
            decode_tensor(payload, shape, bits),
        decode_batch=_batch)


_LAZY["rans"] = _register_rans_backends
_LAZY["rans-ctx"] = _register_rans_backends


# ---------------------------------------------------------------------------
# Wire format
# ---------------------------------------------------------------------------

@dataclass
class EncodedTensor:
    payload: bytes          # entropy-coded channel codes
    backend: str            # registry name ('zlib'|'png'|'raw'|'rans'|...)
    bits: int
    shape: tuple            # original codes shape, channel-last
    side_info: bytes        # fp16 mins/maxs

    def total_bits(self) -> int:
        """Paper-style accounting: payload + C*32 side-info bits."""
        return 8 * (len(self.payload) + len(self.side_info))

    def header_bytes(self) -> int:
        return 7 + 4 * len(self.shape) + 8

    def wire_bits(self) -> int:
        """Everything that crosses the channel: header + side info + payload.

        This is what the serving channel meters and the scheduler budgets;
        ``total_bits`` stays the paper's (header-free) reporting quantity.
        """
        return 8 * (self.header_bytes() + len(self.side_info)
                    + len(self.payload))

    def to_bytes(self) -> bytes:
        hdr = struct.pack("<4sB B B", MAGIC,
                          _get_backend(self.backend).wire_id,
                          self.bits, len(self.shape))
        hdr += struct.pack(f"<{len(self.shape)}I", *self.shape)
        hdr += struct.pack("<II", len(self.side_info), len(self.payload))
        return hdr + self.side_info + self.payload

    @classmethod
    def from_bytes(cls, data: bytes) -> "EncodedTensor":
        if len(data) < 7:
            raise ValueError(
                f"truncated wire header: {len(data)} bytes, need >= 7")
        magic, backend_id, bits, ndim = struct.unpack_from("<4sB B B", data, 0)
        if magic in _OLD_MAGICS:
            raise ValueError(
                f"unsupported wire-format version {magic.decode('ascii', 'replace')} "
                f"(this build writes {MAGIC.decode('ascii')}; re-encode)")
        if magic != MAGIC:
            raise ValueError(f"bad magic {magic!r}")
        if backend_id not in _BY_ID:
            # rans ids are lazily registered; resolve them before failing
            for lazy in _LAZY:
                _get_backend(lazy)
            if backend_id not in _BY_ID:
                raise ValueError(f"unknown backend id {backend_id}")
        off = 7
        if off + 4 * ndim + 8 > len(data):
            raise ValueError(
                f"truncated wire header: {ndim}-d shape + lengths need "
                f"{off + 4 * ndim + 8} bytes, have {len(data)}")
        shape = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        silen, plen = struct.unpack_from("<II", data, off)
        off += 8
        if off + silen > len(data):
            raise ValueError(
                f"truncated side info: header claims {silen} bytes, "
                f"{len(data) - off} remain")
        side_info = data[off:off + silen]
        off += silen
        if off + plen > len(data):
            raise ValueError(
                f"truncated payload: header claims {plen} bytes, "
                f"{len(data) - off} remain")
        payload = data[off:off + plen]
        off += plen
        if off != len(data):
            raise ValueError(
                f"{len(data) - off} bytes of trailing garbage after payload")
        return cls(payload=payload, backend=_BY_ID[backend_id], bits=bits,
                   shape=tuple(shape), side_info=side_info)


def _pack_side_info(qp: QuantParams) -> bytes:
    mins = np.asarray(qp.mins, dtype=np.float16)
    maxs = np.asarray(qp.maxs, dtype=np.float16)
    return mins.tobytes() + maxs.tobytes()


def _unpack_side_info(data: bytes, bits: int) -> QuantParams:
    half = len(data) // 2
    mins = np.frombuffer(data[:half], dtype=np.float16)
    maxs = np.frombuffer(data[half:], dtype=np.float16)
    return QuantParams(mins=mins, maxs=maxs, bits=bits)


def encode(codes: np.ndarray, qp: QuantParams, backend: str = "zlib",
           level: int = 9) -> EncodedTensor:
    """Entropy-code quantized channel codes (any shape, channel-last)."""
    codes = np.asarray(codes)
    be = _get_backend(backend)
    payload = be.encode(codes, qp.bits, level)
    return EncodedTensor(payload=payload, backend=backend, bits=qp.bits,
                         shape=tuple(codes.shape), side_info=_pack_side_info(qp))


def decode(enc: EncodedTensor) -> tuple[np.ndarray, QuantParams]:
    qp = _unpack_side_info(enc.side_info, enc.bits)
    count = int(np.prod(enc.shape)) if enc.shape else 1
    be = _get_backend(enc.backend)
    codes = np.asarray(be.decode(enc.payload, enc.shape, enc.bits, count))
    dtype = np.uint8 if enc.bits <= 8 else (np.uint16 if enc.bits <= 16 else np.uint32)
    return codes.astype(dtype).reshape(enc.shape), qp


def decode_many(encs: "list[EncodedTensor]") -> tuple[np.ndarray,
                                                      list[QuantParams]]:
    """Decode N same-(backend, bits, shape) tensors -> ((N, *shape), qps).

    The batched host-decode primitive behind ``repro.pipeline``'s
    ``CompressionPlan.decode_batch``: backends that registered a
    ``decode_batch`` hook (zlib, raw) coalesce the per-payload numpy loops
    into one vectorized pass; the rest fall back to a per-payload loop but
    still hand the caller one stacked array.
    """
    if not encs:
        raise ValueError("decode_many needs at least one tensor")
    first = encs[0]
    for e in encs[1:]:
        if (e.backend, e.bits, e.shape) != (first.backend, first.bits,
                                            first.shape):
            raise ValueError(
                f"decode_many requires a homogeneous batch; got "
                f"({e.backend}, {e.bits}, {e.shape}) vs "
                f"({first.backend}, {first.bits}, {first.shape})")
    be = _get_backend(first.backend)
    count = int(np.prod(first.shape)) if first.shape else 1
    if be.decode_batch is not None:
        codes = np.asarray(be.decode_batch([e.payload for e in encs],
                                           first.shape, first.bits, count))
    else:
        codes = np.stack([
            np.asarray(be.decode(e.payload, e.shape, e.bits, count)).ravel()
            for e in encs])
    dtype = (np.uint8 if first.bits <= 8
             else (np.uint16 if first.bits <= 16 else np.uint32))
    codes = codes.astype(dtype, copy=False).reshape(
        (len(encs),) + tuple(first.shape))
    qps = [_unpack_side_info(e.side_info, e.bits) for e in encs]
    return codes, qps


def empirical_entropy_bits(codes: np.ndarray, bits: int) -> float:
    """Order-0 empirical entropy of the code stream, in total bits.

    Codec-independent floor used in benchmarks to separate "what the
    quantizer achieved" from "what the entropy coder realized".
    """
    flat = np.asarray(codes).ravel()
    if flat.size == 0:
        return 0.0
    counts = np.bincount(flat.astype(np.int64), minlength=1 << bits)
    p = counts[counts > 0] / flat.size
    return float(-np.sum(p * np.log2(p)) * flat.size)
