"""Channel tiling — paper §3.2.

The C quantized channels (each H x W) are rearranged into one rectangular tiled
image with ``cols = 2^ceil(log2(C)/2)`` channels across and
``rows = 2^floor(log2(C)/2)`` down (C is always a power of two, so the tiling
has no empty area). The tiled image is what the lossless image codec sees; the
spatial adjacency of correlated channels is what makes it compress.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def tile_grid(c: int) -> tuple[int, int]:
    """(rows, cols) of the tiling for C channels (C must be a power of 2)."""
    if c < 1 or (c & (c - 1)) != 0:
        raise ValueError(f"C must be a power of two, got {c}")
    lg = int(math.log2(c))
    cols = 1 << ((lg + 1) // 2)   # ceil(lg/2)
    rows = 1 << (lg // 2)          # floor(lg/2)
    assert rows * cols == c
    return rows, cols


def tile_channels(x: jax.Array) -> jax.Array:
    """(H, W, C) -> (rows*H, cols*W) tiled image (single example)."""
    h, w, c = x.shape
    rows, cols = tile_grid(c)
    # channel k goes to tile (k // cols, k % cols), scanning row-major
    y = jnp.transpose(x, (2, 0, 1))            # (C, H, W)
    y = y.reshape(rows, cols, h, w)
    y = jnp.transpose(y, (0, 2, 1, 3))         # (rows, H, cols, W)
    return y.reshape(rows * h, cols * w)


def untile_channels(img: jax.Array, c: int) -> jax.Array:
    """Inverse of :func:`tile_channels`: (rows*H, cols*W) -> (H, W, C)."""
    rows, cols = tile_grid(c)
    th, tw = img.shape
    h, w = th // rows, tw // cols
    y = img.reshape(rows, h, cols, w)
    y = jnp.transpose(y, (0, 2, 1, 3))         # (rows, cols, H, W)
    y = y.reshape(c, h, w)
    return jnp.transpose(y, (1, 2, 0))


def tile_batch(x: jax.Array) -> jax.Array:
    """(B, H, W, C) -> (B, rows*H, cols*W)."""
    return jax.vmap(tile_channels)(x)


def untile_batch(img: jax.Array, c: int) -> jax.Array:
    return jax.vmap(lambda im: untile_channels(im, c))(img)
