"""Training losses for the BaF predictor — paper eq. (7)."""
from __future__ import annotations

import jax.numpy as jnp


def charbonnier(pred: jnp.ndarray, target: jnp.ndarray, eps: float = 1e-3,
                mean: bool = True) -> jnp.ndarray:
    """Charbonnier penalty sum sqrt((pred-target)^2 + eps^2) — eq. (7).

    The paper sums over all elements; we expose ``mean`` because at framework
    scale the mean keeps loss magnitudes comparable across shapes (the
    optimizer-facing gradient differs only by a constant factor).
    """
    d = (pred.astype(jnp.float32) - target.astype(jnp.float32))
    v = jnp.sqrt(jnp.square(d) + eps * eps)
    return jnp.mean(v) if mean else jnp.sum(v)
