"""The paper's contribution: BaF tensor compression as composable JAX modules."""
from repro.core.quant import (QuantParams, compute_quant_params, quantize,
                              dequantize, bin_bounds, quantization_mse)
from repro.core.selection import (SelectionResult, correlation_matrix_conv,
                                  correlation_matrix_stream, select_channels,
                                  select_channels_greedy, accumulate_correlation)
from repro.core.tiling import tile_grid, tile_channels, untile_channels, tile_batch, untile_batch
from repro.core.losses import charbonnier
from repro.core.baf import (BaFConvConfig, BaFStreamConfig, init_baf_conv,
                            init_baf_stream, baf_conv_predict, baf_stream_predict,
                            baf_conv_backward, baf_conv_forward,
                            baf_stream_backward, consolidate, scatter_consolidated,
                            gather_bn)
from repro.core.split import SplitInferenceEngine, SplitStats
from repro.core import codec
