"""Channel selection — paper §3.1, eqs. (2)-(3).

Offline analysis: given samples of the split layer's input tensor X (Q channels,
at 2x the spatial resolution of Z when the split conv has stride 2) and the BN
output tensor Z (P channels), rank the Z channels by their mean absolute
correlation with *all* X channels, and keep the top C.

Because the eq. (3) score of a channel does not change as others are removed,
the paper's iterative re-selection over "remaining channels" reduces to a single
descending sort of the per-channel totals; we implement it that way and test the
equivalence explicitly (tests/test_selection.py).

Works for conv tensors (B, H, W, C) and transformer streams (B, S, D): for the
latter there is no stride, so a single "downsampled version" (s=0) is used and
X/Z have equal spatial size.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class SelectionResult(NamedTuple):
    order: np.ndarray      # (P,) channel indices of Z, best-first
    scores: np.ndarray     # (P,) eq. (3) totals, same order as `order`
    rho: np.ndarray        # (P, Q) mean absolute correlation matrix


def _flatten_leading(x: jax.Array) -> jax.Array:
    """(B, *spatial, C) -> (B*prod(spatial), C)."""
    return x.reshape(-1, x.shape[-1])


def stride2_offsets(x: jax.Array) -> list[jax.Array]:
    """Four stride-2 downsampled versions of an NHWC tensor (paper: s=0..3)."""
    return [x[:, i::2, j::2, :] for i in range(2) for j in range(2)]


def _abs_corr(z_flat: jax.Array, x_flat: jax.Array) -> jax.Array:
    """|Pearson rho| between every column of z_flat (P) and x_flat (Q) -> (P, Q)."""
    z = z_flat.astype(jnp.float32)
    x = x_flat.astype(jnp.float32)
    z = z - jnp.mean(z, axis=0, keepdims=True)
    x = x - jnp.mean(x, axis=0, keepdims=True)
    zn = jnp.linalg.norm(z, axis=0)        # (P,)
    xn = jnp.linalg.norm(x, axis=0)        # (Q,)
    dots = z.T @ x                          # (P, Q)
    denom = jnp.maximum(zn[:, None] * xn[None, :], 1e-12)
    return jnp.abs(dots / denom)


@jax.jit
def correlation_matrix_conv(z: jax.Array, x: jax.Array) -> jax.Array:
    """Eq. (2) for a stride-2 conv split: mean |rho| over the 4 offsets.

    z: (B, H, W, P) BN output; x: (B, 2H, 2W, Q) layer input.
    """
    rhos = [_abs_corr(_flatten_leading(z), _flatten_leading(xs))
            for xs in stride2_offsets(x)]
    return sum(rhos) / 4.0


@jax.jit
def correlation_matrix_stream(z: jax.Array, x: jax.Array) -> jax.Array:
    """Eq. (2) degenerate (stride-1) case for (B, S, D) transformer streams."""
    return _abs_corr(_flatten_leading(z), _flatten_leading(x))


def select_channels(rho: jax.Array) -> SelectionResult:
    """Eq. (3): order Z channels by total correlation with all X channels."""
    rho = np.asarray(rho)
    totals = rho.sum(axis=1)
    order = np.argsort(-totals, kind="stable")
    return SelectionResult(order=order, scores=totals[order], rho=rho)


def select_channels_greedy(rho: jax.Array, c: int) -> np.ndarray:
    """Literal paper procedure: repeatedly take the argmax among remaining.

    Kept as the reference for the sort-equivalence property test.
    """
    rho = np.asarray(rho)
    totals = rho.sum(axis=1).copy()
    chosen: list[int] = []
    remaining = set(range(rho.shape[0]))
    for _ in range(c):
        p_star = max(remaining, key=lambda p: (totals[p], -p))
        chosen.append(p_star)
        remaining.remove(p_star)
    return np.asarray(chosen)


def accumulate_correlation(batches_zx: Sequence[tuple[jax.Array, jax.Array]],
                           conv: bool = True) -> SelectionResult:
    """Streaming eq. (2) over a dataset: average the per-batch rho matrices.

    The paper computes rho over 1k COCO images; at scale the tensors do not fit
    in memory at once, so we average per-batch correlation matrices (an
    approximation of the pooled correlation that preserves the ranking in
    practice; exactness is not required — the order is offline side info).
    """
    fn = correlation_matrix_conv if conv else correlation_matrix_stream
    acc = None
    n = 0
    for z, x in batches_zx:
        r = fn(z, x)
        acc = r if acc is None else acc + r
        n += 1
    assert acc is not None, "no batches supplied"
    return select_channels(acc / n)
