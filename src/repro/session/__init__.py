"""Streaming sessions: temporal BaF delta coding over the plan/serve stack.

BaF prediction exploits redundancy *within* one tensor; a camera feeding the
split network at 10-30 fps also carries redundancy *between* consecutive
frames' feature tensors. This package adds the stateful layer that captures
it:

  * :mod:`repro.session.codec` — per-session reference state and the
    SessionFrame wire format: I-frames are today's ``CompressionPlan.encode``
    containers unchanged; P-frames code the temporal delta of quantized codes
    through the same entropy backends, wrapped in a versioned, CRC-hardened
    frame header (session id, frame seq, reference seq, I/P flag).
  * :mod:`repro.session.recovery` — the desync/NACK/intra-refresh state
    machine: a lost or corrupt frame can never be silently restored; the
    decoder desyncs, NACKs on the simulated downlink, and the encoder
    answers with a forced I-frame, bounding recovery time.
  * :mod:`repro.session.manager` — hundreds of concurrent camera sessions on
    the virtual clock through ``MultiTenantGateway``'s executor/batcher
    machinery, with per-session QoS: under overload a session steps down the
    quality ladder (coarser OperatingPoint, sparser cadence) *before*
    admission sheds it, metered as a distinct telemetry outcome.

See docs/STREAMING.md for the wire format and the recovery bounds.
"""
from repro.session.codec import (SESSION_MAGIC, FrameMeta, SessionConfig,
                                 SessionDecoder, SessionDesync,
                                 SessionEncoder, SessionError, SessionFrame)
from repro.session.manager import (QosLevel, SessionManager, SessionSpec,
                                   StreamReport)
from repro.session.recovery import (RecoveryConfig, RecoveryTracker,
                                    recovery_bound_s)

__all__ = [
    "SESSION_MAGIC", "FrameMeta", "SessionConfig", "SessionDecoder",
    "SessionDesync", "SessionEncoder", "SessionError", "SessionFrame",
    "QosLevel", "SessionManager", "SessionSpec", "StreamReport",
    "RecoveryConfig", "RecoveryTracker", "recovery_bound_s",
]
