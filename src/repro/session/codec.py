"""Stateful session codec: temporal delta coding of quantized BaF codes.

Wire format (all little-endian), mirroring the RTC1 container's CRC
discipline (repro/codec/container.py)::

    header  "SSF1" | u8 version | u8 frame_type (0=I, 1=P) | u8 level |
            u8 reserved | u32 session_id | u32 frame_seq | u32 ref_seq |
            u32 payload_len | u32 crc32(header fields above)
    payload <payload_len bytes>   # a BaF2 container (core/codec.py)
    footer  u32 crc32(payload)

An **I-frame**'s payload is exactly today's ``CompressionPlan.encode``
container — a session of keyframes only is byte-compatible with stateless
serving. A **P-frame**'s payload is the same container format over the
*temporal delta* of quantized codes::

    delta = (codes_t - codes_ref) mod 2^bits

entropy-coded by the plan's backend (rANS static tables adapt to the
delta's near-zero concentration, which is where the P-frame bit savings
come from). Reconstruction inverts the delta exactly, so a P-frame decodes
to bit-identical codes as the I-frame it chains from — temporal prediction
is lossless on top of quantization, and restore quality never drifts with
chain length.

The payload CRC means corruption anywhere in the frame is *detected* —
header flips fail the header CRC, payload flips fail the payload CRC —
before any codes are reconstructed. A corrupt or missing frame therefore
never silently restores; the decoder raises (:class:`CorruptStream` /
:class:`SessionDesync`) and the recovery layer (repro/session/recovery.py)
NACKs for an intra refresh.

``level`` names the operating point out of the session's agreed QoS ladder
(:class:`SessionConfig.levels`), so both ends resolve coding parameters
from one byte instead of re-negotiating per frame; a level change forces an
I-frame (a delta across operating points is meaningless).
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.codec.rans import CorruptStream
from repro.pipeline import (SESSION_WIRE_VERSION, Capabilities, DecodedBatch,
                            OperatingPoint, negotiate_session)

SESSION_MAGIC = b"SSF1"

FRAME_I = 0
FRAME_P = 1

_HEADER = struct.Struct("<4sBBBBIIII")
_CRC = struct.Struct("<I")
HEADER_BYTES = _HEADER.size + _CRC.size      # through the header CRC
FRAME_OVERHEAD_BYTES = HEADER_BYTES + _CRC.size


class SessionError(Exception):
    """Base for session-layer failures that are not byte corruption."""


class SessionDesync(SessionError):
    """A P-frame arrived whose reference the decoder does not hold.

    The session is out of sync (a frame was lost, corrupted, or reordered
    past its successor); nothing can be restored until an I-frame arrives.
    The recovery layer turns this into a NACK on the downlink.
    """


@dataclass(frozen=True)
class SessionFrame:
    """One parsed session frame (header fields + verified payload)."""
    session_id: int
    seq: int
    ref_seq: int                 # seq of the reference frame (I: == seq)
    intra: bool
    level: int                   # index into the session's QoS ladder
    payload: bytes               # a BaF2 container (verified by CRC)

    def pack(self) -> bytes:
        hdr = _HEADER.pack(SESSION_MAGIC, SESSION_WIRE_VERSION,
                           FRAME_I if self.intra else FRAME_P,
                           self.level, 0, self.session_id, self.seq,
                           self.ref_seq, len(self.payload))
        return b"".join([hdr, _CRC.pack(zlib.crc32(hdr)), self.payload,
                         _CRC.pack(zlib.crc32(self.payload))])

    @classmethod
    def parse(cls, blob: bytes) -> "SessionFrame":
        if len(blob) < HEADER_BYTES:
            raise CorruptStream(
                f"truncated session frame header: {len(blob)} bytes, "
                f"need {HEADER_BYTES}")
        (magic, version, frame_type, level, _reserved, session_id, seq,
         ref_seq, payload_len) = _HEADER.unpack_from(blob, 0)
        if magic != SESSION_MAGIC:
            raise CorruptStream(f"bad session frame magic {magic!r}")
        if version != SESSION_WIRE_VERSION:
            raise CorruptStream(
                f"unsupported session wire version {version}")
        (hdr_crc,) = _CRC.unpack_from(blob, _HEADER.size)
        if hdr_crc != zlib.crc32(blob[:_HEADER.size]):
            raise CorruptStream("session frame header CRC mismatch")
        if frame_type not in (FRAME_I, FRAME_P):
            raise CorruptStream(f"unknown session frame type {frame_type}")
        end = HEADER_BYTES + payload_len
        if end + _CRC.size > len(blob):
            raise CorruptStream(
                f"truncated session frame payload: header promises "
                f"{payload_len} bytes, {len(blob) - HEADER_BYTES - _CRC.size}"
                f" available")
        if end + _CRC.size < len(blob):
            raise CorruptStream(
                f"trailing garbage after session frame: "
                f"{len(blob) - end - _CRC.size} bytes")
        payload = blob[HEADER_BYTES:end]
        (payload_crc,) = _CRC.unpack_from(blob, end)
        if payload_crc != zlib.crc32(payload):
            raise CorruptStream("session frame payload CRC mismatch")
        return cls(session_id=session_id, seq=seq, ref_seq=ref_seq,
                   intra=frame_type == FRAME_I, level=level, payload=payload)


@dataclass(frozen=True)
class SessionConfig:
    """Session establishment state both ends agree on before frame 1.

    levels : the QoS ladder, best first — the frame header's ``level`` byte
             indexes this tuple, so encoder and decoder resolve coding
             parameters without per-frame negotiation
    keyframe_interval : force an I-frame every N frames (0 = none; P-frames
             flow until a NACK or level change forces intra refresh).
             Per-level overrides live on the QoS ladder (manager).
    """
    session_id: int
    levels: tuple[OperatingPoint, ...]
    keyframe_interval: int = 0

    def __post_init__(self):
        if not self.levels:
            raise ValueError("session needs at least one operating point")
        if len(self.levels) > 256:
            raise ValueError("level is a u8: at most 256 ladder steps")
        if self.keyframe_interval < 0:
            raise ValueError("keyframe_interval must be >= 0")


@dataclass(frozen=True)
class FrameMeta:
    """Encode-side accounting for one emitted frame."""
    seq: int
    intra: bool
    level: int
    op: OperatingPoint
    wire_bits: int               # full frame: header + payload + CRCs
    payload_bits: int


def _delta_mod(a: np.ndarray, b: np.ndarray, bits: int) -> np.ndarray:
    # codes live in [0, 2^bits) inside a uint dtype whose width is a
    # multiple of bits' power-of-two range, so wrap-around subtraction
    # followed by the mask IS subtraction mod 2^bits
    mask = np.array((1 << bits) - 1, dtype=a.dtype)
    return ((a - b) & mask).astype(a.dtype)


class SessionEncoder:
    """Edge-side session state: holds the previous frame's quantized codes.

    ``plan_for`` maps an operating point to its (cached) CompressionPlan —
    pass the gateway's ``plan_for`` so sessions share plan/jit caches with
    stateless serving. ``capabilities`` is the *decode* side's; when it does
    not speak the session profile (and may downgrade), the encoder emits
    I-frames only.
    """

    def __init__(self, cfg: SessionConfig, plan_for: Callable, *,
                 capabilities: Capabilities | None = None):
        self.cfg = cfg
        self.plan_for = plan_for
        self.temporal = negotiate_session(capabilities)
        self.seq = 0
        self._ref_codes: np.ndarray | None = None
        self._ref_seq = -1
        self._ref_level = -1
        self._last_intra_seq = -1
        self._force_intra = False

    @property
    def force_intra_pending(self) -> bool:
        return self._force_intra

    def nack(self) -> None:
        """A downlink NACK arrived: the next frame must be an I-frame."""
        self._force_intra = True

    def _wants_intra(self, level: int, keyframe_interval: int) -> bool:
        if (not self.temporal or self._ref_codes is None
                or self._force_intra or level != self._ref_level):
            return True
        return (keyframe_interval > 0
                and self.seq - self._last_intra_seq >= keyframe_interval)

    def encode(self, z, *, level: int = 0,
               keyframe_interval: int | None = None
               ) -> tuple[bytes, FrameMeta]:
        """Code one frame's split activation ``z`` (1, H, W, P) -> wire bytes.

        Emits an I-frame when the session state demands one (first frame,
        pending NACK, level change, keyframe cadence, or a decoder that
        never negotiated temporal frames), else a P-frame against the
        previous frame's codes. The reference advances to *this* frame
        either way — P-frames always chain to their immediate predecessor.
        """
        if not 0 <= level < len(self.cfg.levels):
            raise ValueError(f"level {level} outside the session ladder "
                             f"(0..{len(self.cfg.levels) - 1})")
        interval = (self.cfg.keyframe_interval if keyframe_interval is None
                    else keyframe_interval)
        op = self.cfg.levels[level]
        plan = self.plan_for(op)
        codes, qp = plan._quantize(z)
        intra = self._wants_intra(level, interval)
        if intra:
            blob = plan.encode_codes(codes, qp,
                                     raw_bits=int(np.prod(z.shape)) * 32)
            ref_seq = self.seq
            self._last_intra_seq = self.seq
            self._force_intra = False
        else:
            delta = _delta_mod(codes, self._ref_codes, plan.op.bits)
            blob = plan.encode_codes(delta, qp,
                                     raw_bits=int(np.prod(z.shape)) * 32)
            ref_seq = self._ref_seq
        frame = SessionFrame(session_id=self.cfg.session_id, seq=self.seq,
                             ref_seq=ref_seq, intra=intra, level=level,
                             payload=blob.data).pack()
        meta = FrameMeta(seq=self.seq, intra=intra, level=level, op=plan.op,
                         wire_bits=8 * len(frame),
                         payload_bits=8 * len(blob.data))
        self._ref_codes = codes
        self._ref_seq = self.seq
        self._ref_level = level
        self.seq += 1
        return frame, meta


class SessionDecoder:
    """Cloud-side session state: mirrors the encoder's reference chain.

    ``decode`` either returns exactly the codes the encoder quantized —
    bit-identical whether they traveled as an I-frame or a P-chain — or
    raises. :class:`CorruptStream` = the bytes are damaged (CRC/framing);
    :class:`SessionDesync` = the bytes are fine but reference state this
    decoder does not hold. Neither mutates the reference, so one bad frame
    cannot poison later recovery; both should be answered with a NACK.
    """

    def __init__(self, cfg: SessionConfig, plan_for: Callable):
        self.cfg = cfg
        self.plan_for = plan_for
        self.synced = False
        self._ref_codes: np.ndarray | None = None
        self._ref_seq = -1
        self._ref_level = -1
        self.last_decoded_seq = -1

    def decode(self, blob: bytes) -> tuple[DecodedBatch, SessionFrame]:
        frame = SessionFrame.parse(blob)
        if frame.session_id != self.cfg.session_id:
            raise CorruptStream(
                f"frame for session {frame.session_id} arrived at session "
                f"{self.cfg.session_id}")
        if frame.level >= len(self.cfg.levels):
            raise CorruptStream(
                f"frame level {frame.level} outside the agreed ladder "
                f"({len(self.cfg.levels)} levels)")
        op = self.cfg.levels[frame.level]
        plan = self.plan_for(op)
        from repro.core.codec import EncodedTensor
        from repro.pipeline import blob_from_tensor
        try:
            enc = EncodedTensor.from_bytes(frame.payload)
            decoded = plan.decode(blob_from_tensor(enc, plan.op, 1))
        except (ValueError, CorruptStream) as e:
            # the payload CRC passed, so this is a malformed-but-intact
            # container (encoder bug or a forged CRC); surface it as
            # corruption, never as decoded codes
            raise CorruptStream(f"session frame payload rejected: {e}") \
                from e
        if frame.intra:
            codes = decoded.codes
        else:
            if (not self.synced or frame.ref_seq != self._ref_seq
                    or frame.level != self._ref_level):
                raise SessionDesync(
                    f"P-frame {frame.seq} references frame {frame.ref_seq} "
                    f"level {frame.level}; decoder holds "
                    f"{self._ref_seq if self.synced else 'nothing'} level "
                    f"{self._ref_level}")
            ref = self._ref_codes
            mask = np.array((1 << plan.op.bits) - 1, dtype=ref.dtype)
            codes = ((decoded.codes.astype(ref.dtype) + ref) & mask)
        self._ref_codes = codes
        self._ref_seq = frame.seq
        self._ref_level = frame.level
        self.synced = True
        self.last_decoded_seq = frame.seq
        out = DecodedBatch(codes=codes, mins=decoded.mins, maxs=decoded.maxs)
        return out, frame

    def desync(self) -> None:
        """Drop reference state (e.g. the transport reported a lost frame
        before any successor arrived)."""
        self.synced = False
