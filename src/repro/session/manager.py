"""Streaming session manager: many camera sessions on one gateway.

Drives N concurrent 10-30 fps sessions on the virtual clock through a
:class:`~repro.serve.gateway.MultiTenantGateway`'s machinery — its plan
cache, cloud executor, admission policy, telemetry/tracing sinks — with the
stateful session layer on top:

    frame tick -> QoS ladder decision -> edge forward -> SessionEncoder
    (I/P) -> lossy SimulatedChannel.transmit_frame -> SessionDecoder
    (resync state machine, NACK on failure) -> micro-batch decoded codes ->
    executor restore + cloud forward -> per-frame telemetry

Per-session QoS — degrade before shed
-------------------------------------
Each session walks a shared quality ladder (:class:`QosLevel` tuple, best
first). When the gateway's admission policy rejects a frame, the session
first steps *down* the ladder — a coarser operating point, sparser keyframes
and, at the floor, a frame stride that halves offered load — and the frame
is served degraded rather than dropped; only a session already at the floor
sheds. Every step-down is metered as a :class:`~repro.serve.telemetry.
DegradeRecord` (a third outcome series, distinct from served and shed).
After ``upgrade_hold`` consecutive clean admissions a session steps back up
one rung, so quality recovers when pressure clears.

Loss recovery
-------------
The manager owns one impaired channel per session (loss/corruption/reorder
per packet, seeded). A frame that arrives damaged raises in the decoder;
the manager schedules a NACK on the simulated downlink and the encoder's
next frame is a forced I-frame. A frame lost outright surfaces as a desync
when its successor fails to chain. Recovery episodes are measured by
:class:`~repro.session.recovery.RecoveryTracker` per session and every run
ends with a bounded settle phase that repairs any still-desynced session —
``run`` asserts every session ends in sync.

Everything runs on the virtual clock; with a deterministic executor cost
model (``LinearCostModel``) a re-run over the same inputs is bit-identical
(:meth:`StreamReport.signature`).
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.pipeline import DecodedBatch, OperatingPoint
from repro.serve.batcher import DecodedRequest, MicroBatch, MicroBatcher
from repro.serve.channel import ChannelConfig, SimulatedChannel
from repro.serve.telemetry import (DegradeRecord, RequestRecord, ShedRecord,
                                   Telemetry)
from repro.session.codec import (SessionConfig, SessionDecoder, SessionEncoder,
                                 SessionError)
from repro.codec.rans import CorruptStream
from repro.session.recovery import RecoveryConfig, RecoveryTracker

SETTLE_ROUNDS_MAX = 64       # repair attempts before declaring a run broken


@dataclass(frozen=True)
class QosLevel:
    """One rung of the quality ladder (index 0 = best quality).

    keyframe_interval : periodic I-frame cadence at this rung (0 = none —
        P-frames until a NACK forces refresh)
    frame_stride : send every Nth frame only; >1 makes sense at the floor
        rung, where it genuinely halves/quarters offered executor load
        instead of just shaving wire bits
    """
    op: OperatingPoint
    keyframe_interval: int = 0
    frame_stride: int = 1

    def __post_init__(self):
        if self.keyframe_interval < 0:
            raise ValueError("keyframe_interval must be >= 0")
        if self.frame_stride < 1:
            raise ValueError("frame_stride must be >= 1")


@dataclass(frozen=True)
class SessionSpec:
    """One camera session. ``name`` must be a tenant of the gateway — the
    session inherits that tenant's priority (executor scheduling) and
    admission identity."""
    name: str
    fps: float = 15.0
    start_s: float = 0.0

    def __post_init__(self):
        if self.fps <= 0:
            raise ValueError("fps must be > 0")
        if self.start_s < 0:
            raise ValueError("start_s must be >= 0")


@dataclass(frozen=True)
class FrameLog:
    """One frame's outcome on the virtual clock."""
    seq: int                     # encoder sequence (== -1 for skipped/shed:
                                 # those frames never reached the encoder)
    t: float                     # frame tick time
    outcome: str                 # served | lost | corrupt | desync |
                                 # skipped | shed
    intra: bool = False
    level: int = 0
    wire_bits: int = 0


@dataclass
class _SessionState:
    spec: SessionSpec
    encoder: SessionEncoder
    decoder: SessionDecoder
    tracker: RecoveryTracker
    channel: SimulatedChannel
    priority: int
    level: int = 0               # current QoS rung
    healthy: int = 0             # consecutive clean admissions
    nack_inflight: bool = False
    frames: list = field(default_factory=list)        # FrameLog, tick order
    last_z: object = None        # latest split activation (settle repairs)
    frame_idx: int = 0


@dataclass
class StreamReport:
    """Everything a streaming run produced, keyed by session name."""
    frames: dict                 # name -> [FrameLog]
    telemetry: Telemetry
    recovery: dict               # name -> RecoveryTracker
    nacks: dict                  # name -> NACKs delivered
    final_levels: dict           # name -> QoS rung at end of run
    settle_frames: int           # repair I-frames spent ending in sync

    def counts(self, name: str) -> dict:
        out: dict[str, int] = {}
        for f in self.frames[name]:
            out[f.outcome] = out.get(f.outcome, 0) + 1
        return out

    def wire_bits(self, name: str) -> int:
        return sum(f.wire_bits for f in self.frames[name])

    def signature(self) -> tuple:
        """Virtual-clock quantities only — two runs of the same seeded
        workload under a deterministic cost model compare equal."""
        per_session = []
        for name in sorted(self.frames):
            logs = self.frames[name]
            tr = self.recovery[name]
            per_session.append((
                name,
                tuple((f.seq, round(f.t, 9), f.outcome, f.intra, f.level,
                       f.wire_bits) for f in logs),
                self.nacks.get(name, 0),
                tr.episodes,
                tuple(round(x, 9) for x in tr.recovery_times),
                self.final_levels[name],
            ))
        return (tuple(per_session), self.settle_frames,
                len(self.telemetry), len(self.telemetry.shed),
                len(self.telemetry.degraded))


class SessionManager:
    """Runs streaming sessions against a multi-tenant gateway.

    Parameters
    ----------
    gateway : MultiTenantGateway — supplies plans, model params, executor,
        admission policy, tenant specs (priority), tracer/metrics sinks
    sessions : SessionSpec list; every name must be a gateway tenant
    ladder : QosLevel tuple, best rung first; shared by all sessions
    channel_cfg : per-session impaired channel template (seeded per session
        from ``seed``); must be unmetered — budgets belong to the uplink
        scheduler, not here
    channels : pre-built {name: SimulatedChannel} (overrides channel_cfg)
    recovery : RecoveryConfig — NACK latency etc.
    upgrade_hold : clean admissions before stepping back up one rung
    batch_window_s : micro-batch window on the decoded-request path
    rd_table : RD table whose points carry the measured ``p_over_i`` ratio
        (serve.rate_control.RDPoint) — enables P-frame-aware pricing of the
        ladder rungs; None (default) keeps the legacy behaviour
    frame_budget_bits : per-frame wire-bit budget sessions should start
        within. With ``rd_table``, every session's *initial* rung is the
        best (first) rung whose expected per-frame session cost —
        ``session_bits_per_frame`` over the rung's keyframe interval and
        stride — fits this budget (floor rung if none fits). RD tables
        price I-frames only; without the P/I ratio a temporal rung's wire
        cost is overestimated and ladders start lower than they need to.
        None (default) starts at rung 0, the legacy behaviour.
    """

    def __init__(self, gateway, sessions, *, ladder,
                 channel_cfg: ChannelConfig | None = None,
                 channels: dict | None = None,
                 recovery: RecoveryConfig | None = None,
                 upgrade_hold: int = 16, batch_window_s: float | None = 0.02,
                 seed: int = 0, rd_table=None,
                 frame_budget_bits: float | None = None):
        ladder = tuple(ladder)
        if not ladder:
            raise ValueError("need at least one QoS rung")
        sessions = list(sessions)
        if not sessions:
            raise ValueError("need at least one session")
        names = [s.name for s in sessions]
        if len(set(names)) != len(names):
            raise ValueError("duplicate session names")
        missing = [n for n in names if n not in gateway.specs]
        if missing:
            raise ValueError(f"sessions {missing} are not gateway tenants")
        self.gateway = gateway
        self.sessions = sessions
        self.ladder = ladder
        self.recovery = recovery if recovery is not None else RecoveryConfig()
        self.upgrade_hold = upgrade_hold
        self.batch_window_s = batch_window_s
        self.seed = seed
        if channels is None:
            cfg = channel_cfg if channel_cfg is not None else ChannelConfig()
            channels = {s.name: SimulatedChannel(cfg, seed=seed + i)
                        for i, s in enumerate(sessions)}
        metered = [n for n, ch in channels.items()
                   if ch.cfg.budget_bits_per_tick is not None]
        if metered:
            raise ValueError(f"session channels must be unmetered: "
                             f"{sorted(metered)}")
        missing_ch = set(names) - set(channels)
        if missing_ch:
            raise ValueError(f"no channel for sessions {sorted(missing_ch)}")
        self.channels = channels
        # every session shares the gateway's negotiated capabilities: a
        # gateway that never negotiated the session profile streams I-only
        self._levels = tuple(gateway._fit_op(l.op) for l in ladder)
        self._initial_level = 0
        if rd_table is not None and frame_budget_bits is not None:
            self._initial_level = self._priced_initial_level(
                rd_table, float(frame_budget_bits))

    def _priced_initial_level(self, rd_table, frame_budget_bits: float) -> int:
        """Best (first) rung whose expected per-frame session wire cost fits
        the budget; the floor rung when none does.

        Each rung is priced through its *negotiated* operating point's RD
        entry via :func:`repro.serve.rate_control.session_bits_per_frame`,
        so P-frame savings (the point's measured ``p_over_i``) count —
        I-only pricing would overshoot temporal rungs and start sessions
        lower than the budget warrants. A rung with no table entry is
        skipped (never guessed at).
        """
        from repro.serve.rate_control import session_bits_per_frame
        by_op = {p.op.resolve(): p for p in rd_table}
        for i, rung in enumerate(self.ladder):
            point = by_op.get(self._levels[i].resolve())
            if point is None:
                continue
            cost = session_bits_per_frame(
                point, keyframe_interval=rung.keyframe_interval,
                frame_stride=rung.frame_stride)
            if cost <= frame_budget_bits:
                return i
        return len(self.ladder) - 1

    # -- executor run_fn (decoded-request currency) -------------------------
    def _make_run_fn(self, op: OperatingPoint):
        gw = self.gateway
        plan = gw.plan_for(op)

        def run(batch: MicroBatch):
            # repro: allow[RA01] -- warm-timing helper: real compute wall
            # for measured-cost telemetry, never virtual-clock state
            t0 = time.perf_counter()
            decoded = DecodedBatch(codes=batch.codes, mins=batch.mins,
                                   maxs=batch.maxs)
            z_tilde = plan.restore(decoded)
            logits = gw._cloud_fn(gw.params, z_tilde)
            logits = np.asarray(jax.block_until_ready(logits))
            # repro: allow[RA01] -- warm-timing helper (see t0 above)
            return logits, time.perf_counter() - t0
        return run

    # -- the run ------------------------------------------------------------
    def run(self, frames: dict) -> tuple[dict, StreamReport]:
        """Stream ``frames`` (name -> (N, H, W, 3) array) through the stack.

        Returns (responses, report): ``responses[name]`` maps served frame
        seq -> logits row; the report carries per-frame outcome logs,
        recovery stats, and merged telemetry. Every session is guaranteed
        in sync when this returns (bounded settle phase; raises if a
        pathological channel defeats SETTLE_ROUNDS_MAX repairs).
        """
        gw = self.gateway
        for name in frames:
            if name not in {s.name for s in self.sessions}:
                raise KeyError(f"frames for unknown session {name!r}")
        # fresh per-run state: replays are bit-identical
        gw.executor.reset()
        if gw.admission is not None:
            gw.admission.reset()
        for ch in self.channels.values():
            ch.reset()
        states: dict[str, _SessionState] = {}
        for i, spec in enumerate(self.sessions):
            cfg = SessionConfig(session_id=i, levels=self._levels)
            states[spec.name] = _SessionState(
                spec=spec,
                encoder=SessionEncoder(cfg, gw.plan_for,
                                       capabilities=gw.capabilities),
                decoder=SessionDecoder(cfg, gw.plan_for),
                tracker=RecoveryTracker(),
                channel=self.channels[spec.name],
                priority=gw.specs[spec.name].priority,
                level=self._initial_level)
        telemetry = Telemetry(registry=gw.metrics)
        batcher = MicroBatcher(max_batch=gw.max_batch,
                               window_s=self.batch_window_s)
        key_ops: dict = {}            # bucket key -> restore operating point
        responses: dict[str, dict] = {s.name: {} for s in self.sessions}
        nacks: dict[str, int] = {s.name: 0 for s in self.sessions}
        settle_frames = 0
        settle_rounds = 0
        tracer = gw.tracer

        events: list = []
        eseq = itertools.count()

        def push(t: float, kind: str, payload) -> None:
            heapq.heappush(events, (float(t), next(eseq), kind, payload))

        def meter(metric: str, **labels) -> None:
            if gw.metrics is not None:
                gw.metrics.counter(metric, **labels).inc()

        def send_frame(st: _SessionState, z, t: float, *,
                       settle: bool = False) -> None:
            """Encode at the session's current rung and push the delivery."""
            rung = self.ladder[st.level]
            blob, meta = st.encoder.encode(
                z, level=st.level, keyframe_interval=rung.keyframe_interval)
            delivery = st.channel.transmit_frame(blob, t)
            meter("session_frames_total",
                  kind="I" if meta.intra else "P", tenant=st.spec.name)
            if delivery.lost:
                st.frames.append(FrameLog(
                    seq=meta.seq, t=t, outcome="lost", intra=meta.intra,
                    level=meta.level, wire_bits=meta.wire_bits))
                meter("session_frames_lost_total", tenant=st.spec.name)
                if tracer is not None:
                    tracer.instant("session.frame_lost", t,
                                   track=f"tenant:{st.spec.name}",
                                   seq=meta.seq, intra=meta.intra)
                # an I-frame lost in flight leaves nothing for the decoder
                # to chain from — without feedback yet, the encoder keeps
                # the new reference and the NEXT frame's failure triggers
                # the NACK path
                return
            st.frames.append(FrameLog(
                seq=meta.seq, t=t, outcome="pending", intra=meta.intra,
                level=meta.level, wire_bits=meta.wire_bits))
            push(delivery.tx.t_arrive, "arrive",
                 (st.spec.name, delivery, meta, len(st.frames) - 1, settle))

        def resolve(st: _SessionState, log_idx: int, outcome: str) -> None:
            f = st.frames[log_idx]
            st.frames[log_idx] = FrameLog(seq=f.seq, t=f.t, outcome=outcome,
                                          intra=f.intra, level=f.level,
                                          wire_bits=f.wire_bits)

        def schedule_nack(st: _SessionState, t: float) -> None:
            if not self.recovery.nack or st.nack_inflight:
                return
            st.nack_inflight = True
            push(t + self.recovery.nack_latency_s, "nack", st.spec.name)

        def flush_deadline(key) -> None:
            deadline = batcher.deadline(key)
            if deadline is not None:
                due, gen = deadline
                push(due, "flush", (key, gen))

        def dispatch(batch: MicroBatch, t_ready: float) -> None:
            op = key_ops[batch.key]
            ticket = gw.executor.submit(batch, t_ready,
                                        run_fn=self._make_run_fn(op))
            push(ticket.t_start, "exec_start", ticket)
            push(ticket.t_done, "exec_done", ticket)

        for spec in self.sessions:
            n = len(frames.get(spec.name, ()))
            for idx in range(n):
                push(spec.start_s + idx / spec.fps, "frame",
                     (spec.name, idx))

        while events:
            t, _, kind, payload = heapq.heappop(events)

            if kind == "frame":
                name, idx = payload
                st = states[name]
                st.frame_idx = idx
                img = np.asarray(frames[name][idx])[None]
                rung = self.ladder[st.level]
                if rung.frame_stride > 1 and idx % rung.frame_stride != 0:
                    st.frames.append(FrameLog(seq=-1, t=t, outcome="skipped",
                                              level=st.level))
                    meter("session_frames_skipped_total", tenant=name)
                    continue
                if gw.admission is not None:
                    decision = gw.admission.admit(
                        tenant=name, priority=st.priority, t=t,
                        executor=gw.executor)
                    if not decision.admitted:
                        if st.level < len(self.ladder) - 1:
                            # degrade BEFORE shed: step one rung down and
                            # serve the frame anyway at reduced quality
                            telemetry.record_degrade(DegradeRecord(
                                tenant=name, t=t, frame_seq=idx,
                                from_level=st.level, to_level=st.level + 1,
                                reason=decision.reason))
                            st.level += 1
                            st.healthy = 0
                            if tracer is not None:
                                tracer.instant(
                                    "session.degrade", t,
                                    track=f"tenant:{name}",
                                    to_level=st.level,
                                    reason=decision.reason)
                        else:
                            st.frames.append(FrameLog(
                                seq=-1, t=t, outcome="shed",
                                level=st.level))
                            telemetry.record_shed(ShedRecord(
                                req_id=idx, tenant=name, t_submit=t,
                                reason=decision.reason,
                                priority=st.priority))
                            st.healthy = 0
                            continue
                    else:
                        st.healthy += 1
                        if (st.healthy >= self.upgrade_hold
                                and st.level > 0):
                            st.level -= 1       # pressure cleared: step up
                            st.healthy = 0
                z = gw._edge_fn(gw.params, img)
                st.last_z = z
                send_frame(st, z, t)

            elif kind == "arrive":
                name, delivery, meta, log_idx, settle = payload
                st = states[name]
                try:
                    decoded, frame = st.decoder.decode(delivery.data)
                except (CorruptStream, SessionError) as e:
                    outcome = ("corrupt" if isinstance(e, CorruptStream)
                               else "desync")
                    resolve(st, log_idx, outcome)
                    meter("session_frames_%s_total" % outcome, tenant=name)
                    if st.tracker.on_desync(t) and tracer is not None:
                        tracer.instant("session.desync", t,
                                       track=f"tenant:{name}",
                                       seq=meta.seq, reason=str(e))
                    schedule_nack(st, t)
                    continue
                if frame.intra:
                    st.tracker.on_resync(t)
                resolve(st, log_idx, "served")
                op = meta.op
                req = DecodedRequest(
                    req_id=meta.seq, codes=decoded.codes, mins=decoded.mins,
                    maxs=decoded.maxs, c=op.c, bits=op.bits, t_arrive=t,
                    meta=(op, meta, delivery.tx), tenant=name,
                    priority=st.priority)
                key_ops.setdefault(req.key, op)
                fulls = batcher.add(req, now=t)
                for full in fulls:
                    dispatch(full, t)
                if not fulls:
                    flush_deadline(req.key)

            elif kind == "nack":
                name = payload
                st = states[name]
                st.nack_inflight = False
                nacks[name] += 1
                st.encoder.nack()
                meter("session_nacks_total", tenant=name)
                if tracer is not None:
                    tracer.instant("session.nack", t, track=f"tenant:{name}")

            elif kind == "flush":
                key, gen = payload
                batch = batcher.take(key, gen)
                if batch is not None:
                    dispatch(batch, t)

            elif kind == "exec_start":
                gw.executor.on_start(payload)

            elif kind == "exec_done":
                ticket = payload
                batch = ticket.batch
                for row, req in enumerate(batch.requests):
                    op, meta, tx = req.meta
                    responses[req.tenant][req.req_id] = ticket.logits[row]
                    telemetry.record(RequestRecord(
                        req_id=req.req_id, c=op.c, bits=op.bits,
                        bits_on_wire=meta.wire_bits,
                        wire_latency_s=tx.t_arrive - tx.t_submit,
                        queue_wait_s=ticket.t_start - req.t_arrive,
                        compute_s=ticket.service_s,
                        batch_size=len(batch.requests),
                        padded_size=batch.padded_size,
                        tenant=req.tenant,
                        exec_queue=ticket.queue))
                    if tracer is not None:
                        track = f"tenant:{req.tenant}"
                        root = tracer.span(
                            "session.frame", tx.t_submit, ticket.t_done,
                            track=track, tenant=req.tenant, seq=req.req_id,
                            intra=meta.intra, level=meta.level,
                            wire_bits=meta.wire_bits)
                        tracer.span("channel.transmit", tx.t_submit,
                                    tx.t_arrive, track=track, parent=root,
                                    wire_bits=meta.wire_bits)
                        tracer.span("exec.queue", req.t_arrive,
                                    ticket.t_start, track=track, parent=root,
                                    exec_queue=ticket.queue)
                        tracer.span("cloud.compute", ticket.t_start,
                                    ticket.t_done, track=track, parent=root,
                                    exec_queue=ticket.queue,
                                    batch_size=len(batch.requests))
                gw.executor.complete(ticket)

            if not events:
                # ticks exhausted: first sweep leftover buckets, then run
                # the settle phase — repair I-frames until every session is
                # back in sync (a run must never end desynced). One round
                # per drain, so each repair's arrival is processed before
                # the next round decides who is still broken; only a repair
                # frame lost outright retries inside the inner loop.
                for rest in batcher.flush():
                    dispatch(rest, max(r.t_arrive for r in rest.requests))
                t_settle = t
                while not events and settle_rounds < SETTLE_ROUNDS_MAX:
                    broken = [st for st in states.values()
                              if (st.tracker.in_desync
                                  or not st.decoder.synced)
                              and st.last_z is not None]
                    if not broken:
                        break
                    settle_rounds += 1
                    for st in broken:
                        t_settle += 1.0 / st.spec.fps
                        st.encoder.nack()          # force intra refresh
                        send_frame(st, st.last_z, t_settle, settle=True)
                        settle_frames += 1

        still_broken = [n for n, st in states.items()
                        if (st.tracker.in_desync or not st.decoder.synced)
                        and st.last_z is not None]
        if still_broken:
            raise RuntimeError(
                f"sessions failed to resync after {SETTLE_ROUNDS_MAX} "
                f"repair rounds: {sorted(still_broken)}")

        report = StreamReport(
            frames={n: st.frames for n, st in states.items()},
            telemetry=telemetry,
            recovery={n: st.tracker for n, st in states.items()},
            nacks=nacks,
            final_levels={n: st.level for n, st in states.items()},
            settle_frames=settle_frames)
        if gw.metrics is not None:
            gw.executor.export_metrics(gw.metrics)
        return responses, report
