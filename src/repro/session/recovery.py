"""Loss recovery for streaming sessions: desync detection and NACK timing.

The session codec (repro/session/codec.py) guarantees *detection*: a lost or
corrupt frame makes the decoder raise instead of restoring wrong codes. This
module owns what happens next — the desync/NACK/intra-refresh state machine
and its timing bound:

  1. the decoder hits :class:`~repro.session.codec.SessionDesync` (or
     :class:`~repro.codec.rans.CorruptStream`) and the tracker enters desync,
  2. a NACK travels the simulated downlink (``nack_latency_s``),
  3. the encoder's next frame after the NACK lands is a forced I-frame,
  4. that I-frame crosses the lossy uplink; when it decodes, the tracker
     records first-desync -> resync as one recovery interval.

If the I-frame itself is lost the cycle repeats, so the *expected* recovery
time under loss probability ``p`` scales the single-cycle bound by
``1 / (1 - p)``. A periodic ``keyframe_interval`` bounds recovery even with
NACKs disabled (broadcast-style downlinks): the decoder waits at most one
keyframe period.

Everything here runs on the virtual clock — no wall time, fully
deterministic under seeded channels.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class RecoveryConfig:
    """How a session recovers from desync.

    nack : decoder NACKs on the downlink; encoder answers with intra refresh
    nack_latency_s : one-way downlink latency of the NACK signal
    keyframe_interval : periodic forced I-frame every N frames (0 = none);
        the no-feedback recovery path, also useful as a belt alongside NACKs
        on very lossy links
    """
    nack: bool = True
    nack_latency_s: float = 0.02
    keyframe_interval: int = 0

    def __post_init__(self):
        if self.nack_latency_s < 0:
            raise ValueError("nack_latency_s must be >= 0")
        if self.keyframe_interval < 0:
            raise ValueError("keyframe_interval must be >= 0")
        if not self.nack and self.keyframe_interval == 0:
            raise ValueError(
                "unrecoverable session: NACKs disabled and no periodic "
                "keyframes — a single lost frame would desync forever")


@dataclass
class RecoveryTracker:
    """Measures desync episodes on the virtual clock.

    One *episode* spans from the first desync event (later desyncs while
    already down do not restart the clock — the session is simply still
    down) to the resync that ends it. ``max_recovery_s`` is the quantity the
    tests bound against :func:`recovery_bound_s`.
    """
    in_desync: bool = False
    desync_since: float = 0.0
    episodes: int = 0
    desync_events: int = 0
    recovery_times: list = field(default_factory=list)

    def on_desync(self, t: float) -> bool:
        """Register a desync at virtual time ``t``; True when this event
        *opened* an episode (i.e. a NACK should be scheduled)."""
        self.desync_events += 1
        if self.in_desync:
            return False
        self.in_desync = True
        self.desync_since = t
        self.episodes += 1
        return True

    def on_resync(self, t: float) -> None:
        """An I-frame decoded at ``t``: close the episode if one is open."""
        if not self.in_desync:
            return
        self.in_desync = False
        self.recovery_times.append(t - self.desync_since)

    @property
    def max_recovery_s(self) -> float:
        return max(self.recovery_times, default=0.0)

    @property
    def mean_recovery_s(self) -> float:
        if not self.recovery_times:
            return 0.0
        return sum(self.recovery_times) / len(self.recovery_times)


def recovery_bound_s(*, fps: float, uplink_latency_s: float,
                     nack_latency_s: float, margin_frames: int = 2) -> float:
    """Analytic single-cycle recovery bound for the NACK path.

    Worst case, measured from the desync *detection* instant (a successor
    frame arriving and failing to chain):

      * the NACK crosses the downlink        -> ``nack_latency_s``
      * the encoder waits for its next frame -> up to ``1 / fps``
      * the forced I-frame crosses the uplink-> ``uplink_latency_s``

    plus ``margin_frames`` frame intervals of slack for queueing on a busy
    uplink (frames already in flight ahead of the refresh) and the
    half-open event ordering of the simulator. Callers dealing with loss
    probability ``p`` should scale by ``1 / (1 - p)`` cycles on average.
    """
    if fps <= 0:
        raise ValueError("fps must be > 0")
    frame_s = 1.0 / fps
    return nack_latency_s + frame_s + uplink_latency_s \
        + margin_frames * frame_s
