"""Production mesh: one v5e pod = (data=16, model=16) = 256 chips;
multi-pod adds a leading DCN 'pod' axis (2 pods = 512 chips).

Functions, not module constants — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(n_devices: int | None = None, *, prefer: str = "model"):
    """Small mesh over whatever devices exist (tests / examples).

    prefer="model" (default, train/dry-run): give the model axis the largest
    factor of n in (4, 2, 1) — a 4-device host becomes (data=1, model=4).
    prefer="data" (serving): all devices on the batch axis, (data=n, model=1)
    — the shape the batch-parallel cloud tier (serve.mesh_executor) wants.
    """
    n = n_devices or len(jax.devices())
    if prefer == "data":
        return jax.make_mesh((n, 1), ("data", "model"))
    if prefer != "model":
        raise ValueError(f"prefer must be 'data' or 'model', got {prefer!r}")
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


# v5e hardware constants (roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
CHIPS_PER_POD = 256
