"""Trip-count-aware cost model over compiled (SPMD-partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count, so for scanned-layer models it undercounts FLOPs/bytes by the
(layers × microbatches) factor — and the same goes for collective bytes of
per-layer all-gathers. This module parses ``compiled.as_text()`` into a
computation call graph, multiplies ``while`` bodies by their
``known_trip_count`` backend config, and accumulates:

  flops             2·M·N·K for every dot (the ≥99% term in LM cells;
                    convolutions are counted via window×features)
  bytes             written-buffer model: every materializing op moves
                    2 x its result bytes (one write + one downstream read);
                    layout-only ops (reshape/transpose/bitcast/broadcast/
                    convert) and bookkeeping are free, dynamic-update-slice
                    counts the update slice only (in-place semantics)
  collective_bytes  result bytes of all-gather / all-reduce / reduce-scatter /
                    all-to-all / collective-permute, trip-scaled

These are per-device quantities (the partitioned module is what one chip
executes). The model intentionally over-approximates bytes relative to a
perfect reuse analysis — it is for ranking bottlenecks and measuring deltas
between implementations, not absolute wall-time prediction.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
                "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s*"
                     r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)(?:\.clone)?\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls=|to_apply=)(%[\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"(%[\w.\-]+)")

_SKIP_BYTES = {"parameter", "constant", "iota", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "custom-call", "reshape", "transpose", "convert", "broadcast",
               "while", "conditional", "call", "get-dimension-size"}

# Standalone elementwise ops in CPU-backend HLO that the TPU backend would
# fuse into neighbours — counted as free so the bytes model approximates the
# TPU memory system rather than the unfused CPU lowering (DESIGN.md §4 note).
_ELEMENTWISE_FREE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "select",
    "compare", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "negate", "abs", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "power", "sqrt", "rsqrt", "cbrt", "logistic",
    "and", "or", "not", "xor", "clamp", "is-finite", "atan2", "erf",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "stochastic-convert", "real", "imag", "expm1", "log1p", "clz",
    "popcnt", "rem", "map", "pad", "reverse", "concatenate", "slice",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start", "reduce-scatter-start",
                "all-to-all-start"}


def _shapes(text: str):
    """All (dtype, dims) array shapes in a type string (tuples give several)."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d] or [1]
        out.append((dt, dims))
    return out


def _nbytes(text: str) -> float:
    total = 0.0
    for dt, dims in _shapes(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CompCost:
    flops: float = 0.0
    bytes_by: dict = field(default_factory=dict)   # op kind -> bytes
    coll: dict = field(default_factory=dict)
    # control-flow sub-calls: list of (callee, trip multiplier)
    calls: list = field(default_factory=list)
    # fused-kernel calls: (callee, boundary result bytes) — internals are one
    # kernel: only FLOPs recurse, bytes are counted at the boundary
    fusions: list = field(default_factory=list)
    # if this computation's ROOT is a dynamic-update-slice, the update bytes
    # (a fusion with such a root is an in-place update: scan ys stacking)
    root_dus_bytes: float | None = None

    @property
    def bytes(self) -> float:
        return sum(self.bytes_by.values())


def _parse(txt: str) -> dict[str, CompCost]:
    comps: dict[str, CompCost] = {}
    shapes: dict[str, str] = {}     # op name -> its result type string
    cur: CompCost | None = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line)
        if mc and line.endswith("{"):
            cur = CompCost()
            comps[mc.group(1)] = cur
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(line)
        if not md:
            continue
        name, result_type, op = md.groups()
        shapes[name] = result_type
        after = line[md.end():]

        if op == "while":
            m = _TRIP_RE.search(line)
            trips = int(m.group(1)) if m else 1
            body = _BODY_RE.search(line)
            cond = _COND_RE.search(line)
            if body:
                cur.calls.append((body.group(1), trips))
            if cond:
                cur.calls.append((cond.group(1), trips))
            continue
        if op in ("call", "conditional"):
            for m in _CALLS_RE.finditer(line):
                cur.calls.append((m.group(1), 1))
        elif op in ("fusion", "map", "reduce", "reduce-window", "scatter",
                    "sort"):
            for m in _CALLS_RE.finditer(line):
                cur.fusions.append((m.group(1), 2 * _nbytes(result_type)))
        if line.lstrip().startswith("ROOT") and op == "dynamic-update-slice":
            ops_part = after.split("), ", 1)[0]
            operands = _OPERAND_RE.findall(ops_part)
            if len(operands) >= 2:
                cur.root_dus_bytes = 2 * _nbytes(shapes.get(operands[1], ""))

        # ---- flops: dot / convolution --------------------------------------
        if op == "dot":
            out_elems = 1
            for dt, dims in _shapes(result_type):
                for d in dims:
                    out_elems *= d
            ops_part = after.split(")", 1)[0]
            first = _OPERAND_RE.search(ops_part)
            k = 1
            mcd = _LHS_CDIMS_RE.search(line)
            if first and mcd:
                lhs_type = shapes.get(first.group(1), "")
                sh = _shapes(lhs_type)
                if sh:
                    dims = sh[0][1]
                    for i in (int(x) for x in mcd.group(1).split(",") if x):
                        if i < len(dims):
                            k *= dims[i]
            cur.flops += 2.0 * out_elems * k
        elif op == "convolution":
            out_elems = 1
            for dt, dims in _shapes(result_type):
                for d in dims:
                    out_elems *= d
            mwin = re.search(r"window=\{size=([\dx]+)", line)
            kelems = 1
            if mwin:
                for d in mwin.group(1).split("x"):
                    kelems *= int(d)
            # input features from rhs shape via dim_labels ...io->...
            ops_part = after.split(")", 1)[0]
            operands = _OPERAND_RE.findall(ops_part)
            in_feat = 1
            mdl = re.search(r"dim_labels=\w+_(\w+)->", line)
            if len(operands) >= 2 and mdl:
                rhs_sh = _shapes(shapes.get(operands[1], ""))
                if rhs_sh:
                    i_pos = mdl.group(1).find("i")
                    dims = rhs_sh[0][1]
                    if 0 <= i_pos < len(dims):
                        in_feat = dims[i_pos]
            cur.flops += 2.0 * out_elems * kelems * in_feat

        # ---- bytes (written-buffer model; fusions at boundary in _total) ---
        if op not in _SKIP_BYTES and op not in _ELEMENTWISE_FREE \
                and op not in ("fusion", "map"):
            ops_part = after.split("), ", 1)[0]
            operands = _OPERAND_RE.findall(ops_part)
            if op == "dynamic-update-slice" and len(operands) >= 2:
                upd = shapes.get(operands[1], "")
                nb = 2 * _nbytes(upd)                  # read update + write
            else:
                nb = 2 * _nbytes(result_type)          # write + one read
            cur.bytes_by[op] = cur.bytes_by.get(op, 0.0) + nb

        # ---- collectives -----------------------------------------------------
        base = op[:-6] if op.endswith("-start") else op
        if base in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute") and not op.endswith("-done"):
            cur.coll[base] = cur.coll.get(base, 0.0) + _nbytes(result_type)
    return comps


def _fusion_flops(comps, name, memo) -> float:
    """FLOPs inside a fused computation (dots can live inside fusions)."""
    if name in memo:
        return memo[name]
    memo[name] = 0.0
    c = comps.get(name)
    if c is None:
        return 0.0
    fl = c.flops
    for callee, _ in c.fusions:
        fl += _fusion_flops(comps, callee, memo)
    memo[name] = fl
    return fl


def _total(comps: dict[str, CompCost], name: str, memo: dict,
           fmemo: dict) -> tuple:
    if name in memo:
        return memo[name]
    memo[name] = (0.0, {}, {})       # cycle guard
    c = comps.get(name)
    if c is None:
        return memo[name]
    fl, by, co = c.flops, dict(c.bytes_by), dict(c.coll)
    for callee, boundary_bytes in c.fusions:
        fl += _fusion_flops(comps, callee, fmemo)
        callee_c = comps.get(callee)
        if callee_c is not None and callee_c.root_dus_bytes is not None:
            nb = callee_c.root_dus_bytes       # in-place update (scan ys)
        else:
            nb = boundary_bytes
        by["fusion"] = by.get("fusion", 0.0) + nb
    for callee, mult in c.calls:
        f2, b2, c2 = _total(comps, callee, memo, fmemo)
        fl += mult * f2
        for k, v in b2.items():
            by[k] = by.get(k, 0.0) + mult * v
        for k, v in c2.items():
            co[k] = co.get(k, 0.0) + mult * v
    memo[name] = (fl, by, co)
    return memo[name]


def analyze_hlo_text(txt: str) -> dict:
    """Returns {'flops', 'bytes', 'collective_bytes': {kind: bytes}} for the
    ENTRY computation with while bodies scaled by known_trip_count."""
    comps = _parse(txt)
    entry = None
    for raw in txt.splitlines():
        if raw.startswith("ENTRY "):
            m = _COMP_RE.match(raw)
            if m:
                entry = m.group(1)
            break
    if entry is None:                 # fall back: last computation
        entry = list(comps)[-1] if comps else None
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "bytes_by_op": {},
                "collective_bytes": {}}
    fl, by, co = _total(comps, entry, {}, {})
    return {"flops": fl, "bytes": sum(by.values()), "bytes_by_op": by,
            "collective_bytes": co}


def analyze_compiled(compiled) -> dict:
    return analyze_hlo_text(compiled.as_text())
