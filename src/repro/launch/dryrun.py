import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production meshes and report memory/cost analysis.

  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod-only --json out.json

A cell "passes" when jit(...).lower(...).compile() succeeds under the mesh —
i.e. every collective the sharding implies is supported and the per-device
memory analysis is available. Output feeds EXPERIMENTS.md §Dry-run and the
roofline benchmarks (benchmarks/roofline.py re-uses lower_cell)."""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.compat import set_mesh

from repro.configs import ARCH_IDS, canonical
from repro.configs.base import SHAPES


def lower_cell(arch: str, shape: str, *, multi_pod: bool, smoke: bool = False,
               tcfg_overrides=None, overrides=None):
    """Returns (lowered, compiled, meta dict)."""
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch, shape, mesh, multi_pod=multi_pod, smoke=smoke,
                      tcfg_overrides=tcfg_overrides, overrides=overrides)
    with set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    return lowered, compiled, {"kind": cell.kind, "mesh": mesh.shape}


_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<shape>[^=]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\(")


def collective_bytes(compiled) -> dict:
    """Sum result bytes of every collective in the compiled (SPMD-partitioned)
    HLO, by op kind. Async pairs (-start/-done) are counted once (the -start).
    Parses compiled.as_text()."""
    out: dict[str, float] = {}
    for line in compiled.as_text().splitlines():
        m = _OP_RE.match(line)
        if not m or m.group("suffix") == "-done":
            continue
        nbytes = _shape_bytes(m.group("shape"))
        if nbytes:
            op = m.group("op")
            out[op] = out.get(op, 0.0) + nbytes
    return out


_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8,
                "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2)\[([\d,]*)\]")


def _shape_bytes(lhs: str) -> float:
    """Bytes of all array shapes on the lhs of an HLO instruction."""
    total = 0.0
    for m in _SHAPE_RE.finditer(lhs):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def run_cell(arch: str, shape: str, *, multi_pod: bool, verbose=True,
             tcfg_overrides=None, overrides=None) -> dict:
    t0 = time.time()
    rec = {"arch": arch, "shape": shape,
           "mesh": "pod2x16x16" if multi_pod else "16x16", "status": "ok"}
    if overrides:
        rec["overrides"] = overrides
    try:
        lowered, compiled, meta = lower_cell(arch, shape, multi_pod=multi_pod,
                                             tcfg_overrides=tcfg_overrides,
                                             overrides=overrides)
        rec["kind"] = meta["kind"]
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                rec[k] = getattr(ma, k, None)
        ca_list = compiled.cost_analysis()
        ca = ca_list[0] if isinstance(ca_list, (list, tuple)) else ca_list
        if ca:
            rec["flops"] = ca.get("flops")
            rec["bytes_accessed"] = ca.get("bytes accessed",
                                           ca.get("bytes_accessed"))
        rec["collective_bytes"] = collective_bytes(compiled)
        # trip-count-aware accounting (XLA counts while bodies once; the
        # scanned-layer models need body x trips — repro.launch.hlo_cost)
        from repro.launch.hlo_cost import analyze_compiled
        scaled = analyze_compiled(compiled)
        rec["flops_scaled"] = scaled["flops"]
        rec["bytes_scaled"] = scaled["bytes"]
        rec["collective_bytes_scaled"] = scaled["collective_bytes"]
        rec["compile_s"] = round(time.time() - t0, 1)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=8)
        rec["compile_s"] = round(time.time() - t0, 1)
    if verbose:
        flops = rec.get("flops")
        print(f"[{rec['mesh']}] {arch:15s} {shape:12s} {rec['status']:4s} "
              f"flops={flops:.3e}" if flops else
              f"[{rec['mesh']}] {arch:15s} {shape:12s} {rec['status']}"
              + (f"  ({rec.get('error','')[:120]})" if rec["status"] != "ok" else ""),
              flush=True)
    return rec


def iter_cells():
    from repro.configs import get_config
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            yield arch, shape, shape in cfg.supported_shapes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--grad-compress-bits", type=int, default=None)
    ap.add_argument("--override", action="append", default=[],
                    help="perf levers, key=value (seq_parallel=0, "
                         "remat_policy=dots, microbatches=4, flash_decode=1)")
    args = ap.parse_args(argv)
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = (int(v) if v.lstrip("-").isdigit() else
                        {"true": True, "false": False}.get(v.lower(), v))
    for bkey in ("seq_parallel", "decode_seq_shard", "flash_decode"):
        if bkey in overrides:
            overrides[bkey] = bool(overrides[bkey])
    overrides = overrides or None

    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)
    over = ({"grad_compress_bits": args.grad_compress_bits}
            if args.grad_compress_bits else None)

    records = []
    n_fail = 0
    for arch, shape, supported in iter_cells():
        if args.arch and canonical(args.arch) != arch:
            continue
        if args.shape and args.shape != shape:
            continue
        if not supported:
            records.append({"arch": arch, "shape": shape, "status": "skip",
                            "reason": "full attention is O(S^2) at 500k; "
                                      "see DESIGN.md §5"})
            print(f"[ ---- ] {arch:15s} {shape:12s} SKIP (quadratic attn)",
                  flush=True)
            continue
        for mp in meshes:
            rec = run_cell(arch, shape, multi_pod=mp, tcfg_overrides=over,
                           overrides=overrides)
            records.append(rec)
            n_fail += rec["status"] == "FAIL"

    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.json}")
    print(f"\n{sum(r['status']=='ok' for r in records)} ok, "
          f"{n_fail} failed, "
          f"{sum(r['status']=='skip' for r in records)} skipped")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
