"""Production training launcher: auto-resume, atomic checkpoints, preemption
handling, watchdog straggler escape — runnable at smoke scale on this host and
structured for the multi-host cluster (DESIGN.md §7).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --steps 50 \
      --ckpt-dir /tmp/ck --ckpt-every 20          # kill -TERM mid-run, rerun:
                                                  # resumes from the last step

On a real cluster the same file runs under `jax.distributed.initialize()`
(flag --multihost) with the production mesh from launch/mesh.py; here the dev
mesh covers whatever devices exist.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import canonical, get_smoke_config
from repro.data.synthetic import TokenDatasetConfig, token_batch_iterator
from repro.models.encdec import init_encdec
from repro.models.lm import init_lm
from repro.train import checkpoint as ckpt
from repro.train.fault import PreemptionFlag, StepDeadlineExceeded, Watchdog
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


def build(arch: str, *, seq_len: int, batch: int, microbatches: int,
          steps: int, lr: float, grad_compress_bits=None):
    cfg = get_smoke_config(arch)
    if cfg.family == "audio":
        raise SystemExit("use --arch of an LM family for the token driver")
    tcfg = TrainConfig(num_microbatches=microbatches, peak_lr=lr,
                       warmup_steps=max(steps // 20, 5), total_steps=steps,
                       grad_compress_bits=grad_compress_bits)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    data = TokenDatasetConfig(vocab_size=cfg.vocab, seq_len=seq_len,
                              batch_size=batch)
    return cfg, tcfg, state, step_fn, data


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--watchdog", action="store_true",
                    help="per-step deadline straggler escape")
    args = ap.parse_args(argv)

    arch = canonical(args.arch)
    cfg, tcfg, state, step_fn, data = build(
        arch, seq_len=args.seq_len, batch=args.batch,
        microbatches=args.microbatches, steps=args.steps, lr=args.lr)

    start = 0
    if args.ckpt_dir:
        restored, at = ckpt.restore(args.ckpt_dir, like=state)
        if restored is not None:
            state, start = restored, at
            print(f"[resume] restored step {at} from {args.ckpt_dir}")

    flag = PreemptionFlag().install()
    wd = Watchdog() if args.watchdog else None
    it = token_batch_iterator(data, seed=args.seed, start_step=start)
    t0 = time.time()
    for s in range(start, args.steps):
        batch = next(it)
        try:
            if wd is not None:
                state, metrics = wd.guard(step_fn, state, batch)
            else:
                state, metrics = step_fn(state, batch)
        except StepDeadlineExceeded as e:
            print(f"[watchdog] {e}; checkpointing and exiting for reschedule")
            if args.ckpt_dir:
                ckpt.save(args.ckpt_dir, s, state)
            return 75                      # EX_TEMPFAIL-style requeue code
        if s % args.log_every == 0 or s == args.steps - 1:
            print(f"step {s:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics.get('grad_norm', 0)):.2f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"{(time.time()-t0)/max(s-start+1,1):.2f}s/step", flush=True)
        if args.ckpt_dir and ((s + 1) % args.ckpt_every == 0 or flag.triggered
                              or s == args.steps - 1):
            path = ckpt.save(args.ckpt_dir, s + 1, state)
            ckpt.retain_last(args.ckpt_dir, keep=args.keep)
            if flag.triggered:
                print(f"[preempt] SIGTERM received; saved {path}; exiting 0")
                return 0
    print(f"done: {args.steps} steps in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
