"""Serving launcher: batched prefill + decode with the engine's step functions
(smoke scale on this host; the dry-run lowers the same steps on the production
mesh).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --batch 4 \
      --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --long 256 \
      --block 64      # chunked long-context ingestion then decode
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import canonical, get_smoke_config
from repro.models.lm import init_decode_cache, init_lm, lm_decode_step
from repro.serve.engine import make_long_ingest, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--long", type=int, default=0,
                    help="long-context ingest length (ssm/hybrid only)")
    ap.add_argument("--block", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = canonical(args.arch)
    cfg = get_smoke_config(arch)
    if cfg.family == "audio":
        raise SystemExit("serve driver covers LM families; whisper decode is "
                         "exercised in tests/test_models_smoke.py")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(args.seed)
    b = args.batch

    if args.long:
        assert cfg.family in ("ssm", "hybrid"), "--long needs sub-quadratic arch"
        if cfg.family == "hybrid":
            import dataclasses
            cfg = cfg.with_(hybrid=dataclasses.replace(
                cfg.hybrid, attn_window_long=args.block))
        tokens = jax.random.randint(key, (b, args.long), 0, cfg.vocab)
        ingest = jax.jit(make_long_ingest(cfg, block=args.block))
        t0 = time.time()
        logits, state = ingest(params, tokens)
        logits.block_until_ready()
        print(f"[long] ingested {args.long} tokens x{b} in blocks of "
              f"{args.block}: {time.time()-t0:.2f}s; "
              f"last-token logits {logits.shape}")
        return 0

    tokens = jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab)
    prefill = jax.jit(make_prefill_step(cfg))
    t0 = time.time()
    logits = prefill(params, {"tokens": tokens}) if cfg.embed_inputs else \
        prefill(params, {"embeds": jax.random.normal(
            key, (b, args.prompt_len, cfg.d_model), cfg.dtype)})
    logits.block_until_ready()
    print(f"[prefill] {args.prompt_len} tokens x{b}: {time.time()-t0:.2f}s")

    # decode loop with the KV/recurrent cache (cache prefilled token-by-token
    # here for simplicity; prefill-into-cache is the production path)
    cache = init_decode_cache(cfg, b, max_len=args.prompt_len + args.gen)
    step = jax.jit(lambda p, c, t: lm_decode_step(p, cfg, c, t))
    for t in range(args.prompt_len):
        _, cache = step(params, cache, tokens[:, t])
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen):
        logits_t, cache = step(params, cache, tok)
        tok = jnp.argmax(logits_t, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    dt = time.time() - t0
    print(f"[decode] {args.gen} tokens x{b}: {dt:.2f}s "
          f"({b*args.gen/dt:.1f} tok/s); sample row: "
          f"{[int(x[0]) for x in out[:8]]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
