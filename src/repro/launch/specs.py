"""Abstract input/step construction for every (arch x shape x mesh) cell.

Everything here is ShapeDtypeStruct-based: no parameter or activation is ever
allocated (the 480B arctic config lowers on a laptop). The dry-run, roofline
benchmarks, and the real train/serve launchers all build their jit'd steps
through this module so the sharding story exists in exactly one place.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.configs.base import SHAPES, ArchConfig
from repro.distributed import api as dist_api
from repro.distributed.sharding import (batch_pspec, cache_pspecs,
                                        params_pspecs)
from repro.models.encdec import init_encdec, init_encdec_cache, encode
from repro.models.lm import init_decode_cache, init_lm
from repro.optim.adamw import AdamWState
from repro.serve.engine import (init_long_state, make_decode_step,
                                make_long_ingest, make_prefill_step)
from repro.train.trainer import TrainConfig, TrainState, init_train_state, make_train_step

LONG_BLOCK = 8192

# per-arch microbatch counts for train_4k (sized so per-chip transients fit
# 16 GB on the (16,16) mesh; revisited in EXPERIMENTS.md §Perf)
TRAIN_MICROBATCHES = {
    "qwen2_72b": 16, "arctic_480b": 16, "starcoder2_15b": 8,
    "nemotron4_15b": 8, "pixtral_12b": 8, "qwen2_7b": 4,
    "olmoe_1b_7b": 2, "rwkv6_3b": 2, "zamba2_1p2b": 8, "whisper_tiny": 1,
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def make_batch_specs(cfg: ArchConfig, shape_name: str, *, arch: str = ""):
    """Abstract input batch for a given shape cell."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    if cfg.family == "audio":
        if kind in ("train", "prefill"):
            return {
                "audio_embeds": _sds((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": _sds((b, s), jnp.int32),
                "labels": _sds((b, s), jnp.int32),
            }
        return {"token": _sds((b,), jnp.int32)}
    if not cfg.embed_inputs:   # pixtral: precomputed patch/text embeddings
        if kind in ("train", "prefill"):
            return {
                "embeds": _sds((b, s, cfg.d_model), jnp.bfloat16),
                "labels": _sds((b, s), jnp.int32),
            }
        return {"token": _sds((b,), jnp.int32)}
    if kind in ("train", "prefill"):
        return {"tokens": _sds((b, s), jnp.int32),
                "labels": _sds((b, s), jnp.int32)}
    if kind == "long":
        return {"tokens": _sds((b, s), jnp.int32)}
    return {"token": _sds((b,), jnp.int32)}


def batch_shardings(specs, mesh: Mesh, *, multi_pod: bool):
    def shard_one(sds):
        bp = batch_pspec(sds.shape[0], mesh, multi_pod=multi_pod)
        return NamedSharding(mesh, P(bp, *([None] * (len(sds.shape) - 1))))
    return jax.tree.map(shard_one, specs)


def abstract_params(cfg: ArchConfig, init_fn) -> Any:
    return jax.eval_shape(lambda k: init_fn(k, cfg), jax.random.PRNGKey(0))


@dataclass
class CellProgram:
    """Everything needed to lower one (arch x shape x mesh) cell."""
    fn: Callable
    args: tuple              # abstract args (ShapeDtypeStructs)
    in_shardings: tuple
    out_shardings: Any
    kind: str
    donate: tuple = ()


def _named(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs)


def _vocab_axis(cfg: ArchConfig, mesh: Mesh, rules):
    """Model-axis factor for the logits vocab dim — None when indivisible
    (whisper's 51865 stays replicated at the boundary; internal shardings are
    still free)."""
    ax = rules.rules.get("vocab")
    if ax is None:
        return None
    size = (mesh.shape[ax] if isinstance(ax, str)
            else int(np.prod([mesh.shape[a] for a in ax])))
    return ax if cfg.vocab % size == 0 else None


def build_cell(arch: str, shape_name: str, mesh: Mesh, *, multi_pod: bool,
               smoke: bool = False,
               tcfg_overrides: Optional[dict] = None,
               overrides: Optional[dict] = None) -> CellProgram:
    """``overrides`` — perf hillclimb levers (EXPERIMENTS.md §Perf):
      seq_parallel:       bool (default True)  act_hidden sharding on/off
      decode_seq_shard:   bool (default True)  KV-cache seq-dim fallback
      remat_policy:       'full' | 'dots' | 'dots_no_batch'
      microbatches:       int
    """
    ov = overrides or {}
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if ov.get("bf16_norm_grad"):
        cfg = cfg.with_(norm_grad="bf16")
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    if shape_name not in cfg.supported_shapes:
        raise ValueError(f"{arch} does not support {shape_name} "
                         f"(full attention is quadratic; see DESIGN.md §5)")

    init_fn = init_encdec if cfg.family == "audio" else init_lm
    a_params = abstract_params(cfg, init_fn)
    # ZeRO across pods too: on the multi-pod mesh the fsdp factor spans
    # (pod, data) so optimizer state halves per added pod — arctic-480b's
    # fp32 master+moments (477B x 12 B) need all 512 chips to fit 16 GB HBM.
    # EXCEPTION: the compressed cross-pod gradient exchange needs
    # pod-REPLICATED params (per-pod ZeRO) — the int8 ring exchange replaces
    # the cross-pod reduce entirely (Tier C).
    compress = bool((tcfg_overrides or {}).get("grad_compress_bits"))
    fsdp_axis = (("pod", "data") if (multi_pod and not compress) else "data")
    if compress:
        # pod-replicated, TP-only weights: the (data x model)-sharded embed
        # gather inside the manual-pod shard_map trips an XLA partitioner
        # CHECK (spmd_partitioner_util.cc:504); TP-only avoids it and is the
        # natural pairing for compressed pod-DP (<=15B models).
        fsdp_axis = None
    p_specs = params_pspecs(a_params, mesh, data_axis=fsdp_axis)
    if compress:
        # ... and the vocab-sharded gather inside the manual region trips the
        # same CHECK: keep the embedding tables replicated in this config.
        def _fix(path, spec):
            names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
            return P() if any(n in ("embed", "lm_head") for n in names) else spec
        p_specs = jax.tree_util.tree_map_with_path(
            _fix, p_specs, is_leaf=lambda x: isinstance(x, P))
    batch_specs = make_batch_specs(cfg, shape_name)
    b_shard = batch_shardings(batch_specs, mesh, multi_pod=multi_pod)
    seq_par = ov.get("seq_parallel", True)
    rules = (dist_api.train_rules(multi_pod, seq_parallel=seq_par)
             if kind == "train"
             else dist_api.serve_rules(
                 multi_pod, weight_mode=cfg.serve_weight_sharding,
                 seq_parallel=seq_par))
    bp = batch_pspec(sh["global_batch"], mesh, multi_pod=multi_pod)

    if kind == "train":
        kw = dict(num_microbatches=ov.get(
            "microbatches", TRAIN_MICROBATCHES.get(arch, 4)))
        if "remat_policy" in ov:
            kw["remat_policy"] = ov["remat_policy"]
        kw.update(tcfg_overrides or {})
        tcfg = TrainConfig(**kw)
        a_state = jax.eval_shape(
            lambda p: init_train_state(p, tcfg),
            jax.tree.map(lambda x: _sds(x.shape, jnp.float32), a_params))
        state_specs = TrainState(
            params=p_specs,
            opt=AdamWState(count=P(), mu=p_specs, nu=p_specs),
            step=P(),
            ef=(p_specs if a_state.ef is not None else None))
        step = make_train_step(cfg, tcfg, mesh=mesh, multi_pod=multi_pod)

        def fn(state, batch):
            with dist_api.axis_ctx(rules):
                return step(state, batch)

        out_shardings = (_named(mesh, state_specs),
                         jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                      {"loss": 0, "grad_norm": 0, "lr": 0}))
        return CellProgram(
            fn=fn, args=(a_state, batch_specs),
            in_shardings=(_named(mesh, state_specs), b_shard),
            out_shardings=out_shardings, kind=kind, donate=(0,))

    # serving-side weight sharding (optionally TP-only: fsdp axis unbound)
    # + bf16 resident weights: serving never needs the fp32 master, and the
    # per-layer f32->bf16 convert traffic is pure waste (§Perf HC2 it2)
    if ov.get("serve_bf16_params", True):
        cfg = cfg.with_(param_dtype=cfg.dtype)
        a_params = abstract_params(cfg, init_fn)
    data_axis = "data" if cfg.serve_weight_sharding == "2d" else None
    p_specs = params_pspecs(a_params, mesh, data_axis=data_axis)

    if kind == "prefill":
        pre = make_prefill_step(cfg)

        def fn(params, batch):
            with dist_api.axis_ctx(rules):
                return pre(params, batch)

        logits_spec = NamedSharding(mesh, P(bp, None, _vocab_axis(cfg, mesh, rules)))
        return CellProgram(
            fn=fn, args=(a_params, batch_specs),
            in_shardings=(_named(mesh, p_specs), b_shard),
            out_shardings=logits_spec, kind=kind)

    if kind == "decode":
        dec = make_decode_step(cfg)
        b = sh["global_batch"]
        if cfg.family == "audio":
            enc_len = cfg.encdec.enc_len_decode
            a_cache = jax.eval_shape(
                lambda p, e: init_encdec_cache(p, cfg, e, sh["seq_len"]),
                a_params, _sds((b, enc_len, cfg.d_model), cfg.dtype))
        else:
            a_cache = jax.eval_shape(
                lambda: init_decode_cache(cfg, b, sh["seq_len"]))
        c_specs = cache_pspecs(a_cache, mesh, bp,
                               seq_fallback=ov.get("decode_seq_shard", True))
        tok = _sds((b,), jnp.int32)
        flash = ov.get("flash_decode", False)

        def fn(params, cache, token):
            with dist_api.axis_ctx(rules):
                if flash:
                    with dist_api.flash_decode_ctx(mesh, batch_spec=bp):
                        return dec(params, cache, token)
                return dec(params, cache, token)

        logits_spec = NamedSharding(mesh, P(bp, _vocab_axis(cfg, mesh, rules)))
        return CellProgram(
            fn=fn, args=(a_params, a_cache, tok),
            in_shardings=(_named(mesh, p_specs), _named(mesh, c_specs),
                          NamedSharding(mesh, P(bp))),
            out_shardings=(logits_spec, _named(mesh, c_specs)),
            kind=kind, donate=(1,))

    # long-context ingestion (ssm / hybrid only)
    block = min(LONG_BLOCK, sh["seq_len"])
    if cfg.family == "hybrid":
        block = cfg.hybrid.attn_window_long
    ingest = make_long_ingest(cfg, block=block)

    def fn(params, tokens):
        with dist_api.axis_ctx(rules):
            return ingest(params, tokens)

    a_state = jax.eval_shape(
        lambda: init_long_state(cfg, sh["global_batch"], block))
    ls_specs = cache_pspecs(a_state, mesh, bp)
    logits_spec = NamedSharding(mesh, P(bp, _vocab_axis(cfg, mesh, rules)))
    return CellProgram(
        fn=fn, args=(a_params, batch_specs["tokens"]),
        in_shardings=(_named(mesh, p_specs),
                      NamedSharding(mesh, P(bp, None))),
        out_shardings=(logits_spec, _named(mesh, ls_specs)), kind=kind)
