"""jax API compatibility layer (non-Pallas; kernels use kernels/compat.py).

The repo targets the current jax surface; the container may bake an older
release. Three renames matter here:

  * ``jax.shard_map`` (new) vs ``jax.experimental.shard_map.shard_map`` (old).
    The new call spells "manual axes" as ``axis_names={...}`` and replication
    checking as ``check_vma=``; the old one spells them ``auto=`` (the
    complement set) and ``check_rep=``. :func:`shard_map` here accepts the
    NEW spelling and translates down when needed.
  * ``jax.set_mesh(mesh)`` (new context manager) vs entering the ``Mesh``
    object itself (old). :func:`set_mesh` returns whichever works.
  * ``Compiled.cost_analysis()`` returns a dict on new jax but a one-element
    list of dicts on old jax. :func:`cost_analysis_dict` normalizes.

Everything resolves at import time against the installed jax; call sites
read as if the new API were present.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "set_mesh", "cost_analysis_dict"]


if hasattr(jax, "shard_map"):
    _new_shard_map = jax.shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma: bool = True):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return _new_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma, **kw)
else:
    from jax.experimental.shard_map import shard_map as _old_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma: bool = True):
        # Old jax's partial-manual mode (``auto=`` complement of axis_names)
        # trips a fatal XLA partitioner check on 0.4.x
        # (spmd_partitioner.cc "IsManualSubgroup" assert), so run fully
        # manual instead: results are identical, the region is just
        # replicated rather than auto-sharded over the unnamed axes.
        del axis_names
        return _old_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:
    def set_mesh(mesh):
        """Old jax: ``Mesh`` is itself the context manager."""
        return mesh


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})
