"""MeshExecutor — the sharded cloud tier.

Every executor so far modeled the cloud as virtual queues in front of one
device; this one puts the real compute on a **device mesh**. The bound
``run_fn`` (the gateway's ``_run_batch_mesh``) still does the batched host
decode, but restore + cloud forward run under ``shard_map`` with batch-axis
data parallelism: a padded micro-batch of N rows is split into
``N / mesh.shape['data']`` rows per device, each device runs the *same*
restore→forward program on its shard, and the logits come back sharded on
the batch axis. Model and BaF weights are replicated via
``distributed.sharding.params_pspecs`` (serve mode: ``data_axis=None``, the
"weights stay resident" layout — on the serving mesh the model axis is 1, so
every rule resolves to a full copy per device).

Bit-identity contract: per-row restore+forward is independent of its
batch-mates, so sharding the batch axis changes only the *shape* each device
computes at. The regression tests pin that a full bucket served by this
executor is bit-identical to :class:`~repro.serve.executor.SerialExecutor`
serving the same rows (XLA is free to pick different instruction schedules
at different batch shapes; the tests are the fence that it has not).

Virtual-clock planning: the per-batch service duration is the cost model
evaluated at the **per-shard** row count (``ceil(padded / n_data)``) — a
mesh that splits a 64-row bucket 8 ways charges the time of an 8-row batch.
With a frozen :class:`~repro.serve.executor.CalibratedCostModel` (fit on the
serial tier's measured samples, then ``freeze()``-d) the clock is a pure
function of the workload, so federated runs replay bit-for-bit. An unfrozen
calibrating model is refused at construction: it would record per-shard
sizes against whole-batch wall times and poison its own fit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.split import restore_codes, restore_codes_fused
from repro.distributed.sharding import params_pspecs
from repro.launch.hlo_cost import analyze_compiled
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16, make_dev_mesh
from repro.models.cnn import cnn_cloud
from repro.serve.executor import (CalibratedCostModel, CloudExecutor,
                                  CostModel, _Queue)


@dataclass(frozen=True)
class _ShardCost:
    """One batch as a single mesh device sees it — what the cost model is
    evaluated at (``padded_size`` = rows per shard, not rows per batch)."""
    padded_size: int
    key: Any = None


class MeshExecutor(CloudExecutor):
    """Cloud tier serving batched restore+forward from a device mesh.

    Parameters
    ----------
    mesh : jax Mesh with a batch-parallel axis (default:
        ``launch.mesh.make_dev_mesh(prefer="data")`` — all local devices on
        the data axis, the serving shape)
    cost : CostModel for virtual service times, evaluated per shard. Pass a
        **frozen** :class:`CalibratedCostModel` for bit-identical replay;
        an unfrozen one is rejected.
    data_axis : mesh axis name the batch is sharded over
    overhead_s : fixed per-batch virtual overhead added on top of the
        per-shard cost (dispatch / collective headroom); 0 by default
    """

    def __init__(self, mesh=None, *, cost: CostModel | None = None,
                 data_axis: str = "data", overhead_s: float = 0.0):
        if isinstance(cost, CalibratedCostModel) and not cost.frozen:
            raise ValueError(
                "MeshExecutor needs a frozen CalibratedCostModel: calibrate "
                "on the serial tier, freeze(), then hand it over — a "
                "calibrating model would record per-shard sizes against "
                "whole-batch wall times and poison its own fit")
        super().__init__(queues=[_Queue(rate=1.0)], cost=cost)
        self.mesh = mesh if mesh is not None else make_dev_mesh(prefer="data")
        if data_axis not in self.mesh.shape:
            raise ValueError(f"mesh has no {data_axis!r} axis: "
                             f"{dict(self.mesh.shape)}")
        self.data_axis = data_axis
        self.n_data = int(self.mesh.shape[data_axis])
        self.overhead_s = float(overhead_s)
        # (id(plan), codes shape) -> (plan, jitted shard_map program). The
        # plan ref is kept so id() stays valid for the cache's lifetime.
        self._fns: dict = {}
        self._pspecs: dict = {}      # id(params tree) -> (tree, specs)

    # -- virtual clock -------------------------------------------------------
    def shard_rows(self, padded_size: int) -> int:
        """Rows each device computes for a batch of ``padded_size``."""
        return -(-int(padded_size) // self.n_data)

    def _plan_duration(self, batch, wall_s: float) -> float:
        view = _ShardCost(padded_size=self.shard_rows(batch.padded_size),
                          key=getattr(batch, "key", None))
        return self.overhead_s + self.cost.duration_s(view, wall_s)

    # -- sharded compute -----------------------------------------------------
    def _params_specs(self, tree):
        hit = self._pspecs.get(id(tree))
        if hit is None:
            # serve layout: no data-axis (ZeRO) factor — inside a manual
            # shard_map region a data-sharded weight would arrive as a slice
            # with nothing to all-gather it; the model axis is size 1 on the
            # serving mesh, so every rule degenerates to a full per-device copy
            hit = (tree, params_pspecs(tree, self.mesh, data_axis=None))
            self._pspecs[id(tree)] = hit
        return hit[1]

    def _sharded_fn(self, plan, shape: tuple):
        key = (id(plan), tuple(shape))
        hit = self._fns.get(key)
        if hit is not None:
            return hit[1]
        bits = plan.op.bits
        sel = plan._sel
        fused = plan.fused
        consolidation = plan.consolidation

        def body(bafp, params, codes, mins, maxs):
            split = params["split"]
            if fused:
                z = restore_codes_fused(bafp, split, sel, codes, mins, maxs,
                                        bits=bits)
            else:
                z = restore_codes(bafp, split, sel, codes, mins, maxs,
                                  bits=bits, consolidation=consolidation)
            return cnn_cloud(params, z)

        d = self.data_axis
        fn = jax.jit(shard_map(
            body, mesh=self.mesh,
            in_specs=(self._params_specs(plan.spec.baf_params),
                      self._params_specs(plan.spec.params),
                      P(d), P(d), P(d)),
            out_specs=P(d), axis_names={d}, check_vma=False))
        self._fns[key] = (plan, fn)
        return fn

    def run_sharded(self, plan, decoded, target: int) -> np.ndarray:
        """Restore + cloud forward ``decoded`` across the mesh.

        Rows are padded (repeat-last, same as the serial path's bucket
        padding) to a multiple of the data-axis size so every device gets an
        equal shard; returns host logits for the first ``target`` rows.
        One jitted shard_map program per (plan, padded codes shape).
        """
        if plan.spec.params is None or plan.spec.baf_params is None:
            raise ValueError("plan was compiled without model weights; "
                             "MeshExecutor cannot restore")
        dec = decoded.pad_to(self.shard_rows(target) * self.n_data)
        fn = self._sharded_fn(plan, dec.codes.shape)
        out = fn(plan.spec.baf_params, plan.spec.params,
                 dec.codes, dec.mins, dec.maxs)
        return np.asarray(jax.block_until_ready(out))[:target]


def seed_cost_from_hlo(plan, sample_shape: tuple, *,
                       flops_per_s: float = PEAK_FLOPS_BF16,
                       bytes_per_s: float = HBM_BW) -> CalibratedCostModel:
    """Roofline-seeded :class:`CalibratedCostModel` for a plan's cloud body.

    Compiles the (serial) restore+forward program for one ``(N, H, W, C)``
    codes shape, runs the trip-count-aware ``launch/hlo_cost`` analysis over
    the compiled HLO, and seeds ``per_item_s`` with the roofline time
    ``max(flops/flops_per_s, bytes/bytes_per_s) / N``. Measured calibration
    samples override the seed at ``fit()``; the seed carries fits that would
    otherwise be degenerate (a single batch size in the samples).
    """
    n = int(sample_shape[0])
    c = int(sample_shape[-1])
    bits, sel = plan.op.bits, plan._sel
    fused, consolidation = plan.fused, plan.consolidation

    def body(bafp, params, codes, mins, maxs):
        split = params["split"]
        if fused:
            z = restore_codes_fused(bafp, split, sel, codes, mins, maxs,
                                    bits=bits)
        else:
            z = restore_codes(bafp, split, sel, codes, mins, maxs,
                              bits=bits, consolidation=consolidation)
        return cnn_cloud(params, z)

    code_dtype = np.uint8 if bits <= 8 else np.uint16
    codes = np.zeros(sample_shape, code_dtype)
    mins = np.zeros((n, 1, 1, c), np.float16)
    maxs = np.ones((n, 1, 1, c), np.float16)
    compiled = jax.jit(body).lower(plan.spec.baf_params, plan.spec.params,
                                   codes, mins, maxs).compile()
    est = analyze_compiled(compiled)
    roof_s = max(est["flops"] / flops_per_s, est["bytes"] / bytes_per_s)
    return CalibratedCostModel(seed_per_item_s=roof_s / n)
