"""Serving steps: prefill, single-token decode, and chunked long-context
ingestion (the long_500k path for SSM/hybrid archs).

All entry points are pure jit-able functions — launch/dryrun.py lowers them
with ShapeDtypeStruct inputs, and examples/serve_lm.py runs them for real at
smoke scale.

Long-context ingestion processes the sequence in blocks (outer lax.scan) so
peak activation memory is O(block), not O(S): per block, embed -> scan layers
carrying recurrent state (RWKV6State / Mamba2State stacked over layers) ->
for zamba2, the shared attention block runs windowed attention against the
previous block's K/V (window == block size). Returns final states + last
logits — ready to start decoding at position S.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ArchConfig
from repro.distributed import shard_hidden
from repro.models.attention import apply_rope, rope_freqs
from repro.models.encdec import (encdec_decode_step, encode, decode_train,
                                 init_encdec_cache)
from repro.models.lm import (DecodeCache, _norm, _segment_bounds,
                             init_decode_cache, lm_decode_step, lm_forward,
                             lm_logits)
from repro.models.mamba2 import init_mamba2_state, mamba2_block_chunk
from repro.models.rwkv6 import init_rwkv6_state, rwkv6_block_chunk


# ---------------------------------------------------------------------------
# Prefill / decode (all families)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig):
    if cfg.family == "audio":
        def prefill(params, batch):
            enc_out = encode(params, cfg, batch["audio_embeds"])
            return decode_train(params, cfg, batch["tokens"], enc_out)
        return prefill

    def prefill(params, batch):
        logits, _ = lm_forward(params, cfg, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"), remat=False)
        return logits
    return prefill


def make_decode_step(cfg: ArchConfig):
    if cfg.family == "audio":
        def step(params, cache, token):
            return encdec_decode_step(params, cfg, cache, token)
        return step

    def step(params, cache, token):
        return lm_decode_step(params, cfg, cache, token)
    return step


# ---------------------------------------------------------------------------
# Long-context chunked ingestion (ssm / hybrid)
# ---------------------------------------------------------------------------

class LongState(NamedTuple):
    layer_states: Any        # stacked (L, ...) RWKV6State / Mamba2State
    shared_k: Any = None     # (n_seg, B, W, K, hd) zamba2 windowed-attn carry
    shared_v: Any = None
    block_idx: jax.Array = None


def init_long_state(cfg: ArchConfig, batch: int, block: int) -> LongState:
    if cfg.family == "ssm":
        st = init_rwkv6_state(batch, cfg.d_model, cfg.ssm.head_dim, cfg.dtype)
        stacked = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), st)
        return LongState(layer_states=stacked,
                         block_idx=jnp.zeros((), jnp.int32))
    st = init_mamba2_state(batch, cfg.d_model, state_dim=cfg.ssm.state_dim,
                           head_dim=cfg.ssm.head_dim, expand=cfg.ssm.expand,
                           conv_width=cfg.ssm.conv_width, dtype=cfg.dtype)
    stacked = jax.tree.map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), st)
    nseg = len(_segment_bounds(cfg))
    kvshape = (nseg, batch, block, cfg.n_kv_heads, cfg.hd)
    return LongState(layer_states=stacked,
                     shared_k=jnp.zeros(kvshape, cfg.dtype),
                     shared_v=jnp.zeros(kvshape, cfg.dtype),
                     block_idx=jnp.zeros((), jnp.int32))


def _shared_attn_windowed(lp, cfg: ArchConfig, x, prev_k, prev_v, positions,
                          first_block):
    """Shared zamba2 block over one sequence block with carry-in window KV."""
    dtype = cfg.dtype
    b, w, d = x.shape
    xn = _norm(cfg, lp["ln1"], x)
    q = (xn @ lp["attn"]["wq"].astype(dtype)).reshape(b, w, cfg.n_heads, cfg.hd)
    k = (xn @ lp["attn"]["wk"].astype(dtype)).reshape(b, w, cfg.n_kv_heads, cfg.hd)
    v = (xn @ lp["attn"]["wv"].astype(dtype)).reshape(b, w, cfg.n_kv_heads, cfg.hd)
    cos, sin = rope_freqs(cfg.hd, cfg.rope_theta, positions)
    q = apply_rope(q, cos[None], sin[None])
    k = apply_rope(k, cos[None], sin[None])
    k2 = jnp.concatenate([prev_k, k], axis=1)           # (B, 2W, K, hd)
    v2 = jnp.concatenate([prev_v, v], axis=1)
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, w, cfg.n_kv_heads, g, cfg.hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k2.astype(jnp.float32)) / jnp.sqrt(cfg.hd)
    qpos = jnp.arange(w)[:, None] + w
    kpos = jnp.arange(2 * w)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - w)
    mask = jnp.where(first_block, mask & (kpos >= w), mask)
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v2.astype(jnp.float32))
    out = out.reshape(b, w, cfg.n_heads * cfg.hd).astype(dtype)
    x = x + out @ lp["attn"]["wo"].astype(dtype)
    from repro.models.ffn import ffn_apply
    x = x + ffn_apply(lp["ffn"], _norm(cfg, lp["ln2"], x), cfg.act, dtype=dtype)
    return x, k, v


def make_long_ingest(cfg: ArchConfig, *, block: int = 8192):
    """Returns ingest(params, tokens (B, S)) -> (last_logits (B, V), LongState).

    S must be a multiple of ``block``; for zamba2, block must equal the
    long-context attention window so the carry covers exactly one window.
    """
    assert cfg.family in ("ssm", "hybrid"), "long ingestion is sub-quadratic only"

    def ingest(params, tokens):
        b, s = tokens.shape
        nblocks = s // block
        state0 = init_long_state(cfg, b, block)
        tok_blocks = tokens.reshape(b, nblocks, block).transpose(1, 0, 2)

        def outer(carry, tok_blk):
            st: LongState = carry
            x = params["embed"][tok_blk].astype(cfg.dtype)
            x = shard_hidden(x, "batch", None, "act_hidden")

            if cfg.family == "ssm":
                def layer_body(xc, lp_state):
                    lp, lst = lp_state
                    y, new_lst = rwkv6_block_chunk(
                        lp, xc, lst, head_dim=cfg.ssm.head_dim,
                        chunk=cfg.ssm.chunk, dtype=cfg.dtype)
                    return y, new_lst
                layer_body = jax.checkpoint(
                    layer_body, policy=jax.checkpoint_policies.nothing_saveable)
                x, new_states = jax.lax.scan(
                    layer_body, x, (params["layers"], st.layer_states))
                new_st = LongState(layer_states=new_states,
                                   block_idx=st.block_idx + 1)
            else:
                new_seg_states, new_ks, new_vs = [], [], []
                first = st.block_idx == 0
                positions = st.block_idx * block + jnp.arange(block)
                for seg_i, (lo, hi) in enumerate(_segment_bounds(cfg)):
                    lp_seg = jax.tree.map(lambda a: a[lo:hi], params["layers"])
                    st_seg = jax.tree.map(lambda a: a[lo:hi], st.layer_states)

                    def layer_body(xc, lp_state):
                        lp, lst = lp_state
                        y, new_lst = mamba2_block_chunk(
                            lp, xc, lst, state_dim=cfg.ssm.state_dim,
                            head_dim=cfg.ssm.head_dim, expand=cfg.ssm.expand,
                            chunk=cfg.ssm.chunk, dtype=cfg.dtype)
                        return y, new_lst
                    layer_body = jax.checkpoint(
                        layer_body,
                        policy=jax.checkpoint_policies.nothing_saveable)
                    x, new_st_seg = jax.lax.scan(layer_body, x, (lp_seg, st_seg))
                    new_seg_states.append(new_st_seg)
                    x, nk, nv = _shared_attn_windowed(
                        params["shared"], cfg, x, st.shared_k[seg_i],
                        st.shared_v[seg_i], positions, first)
                    new_ks.append(nk)
                    new_vs.append(nv)
                new_st = LongState(
                    layer_states=jax.tree.map(
                        lambda *xs: jnp.concatenate(xs, 0), *new_seg_states),
                    shared_k=jnp.stack(new_ks, 0), shared_v=jnp.stack(new_vs, 0),
                    block_idx=st.block_idx + 1)
            last_hidden = _norm(cfg, params["final_norm"], x[:, -1:, :])
            logits = lm_logits(params, cfg, last_hidden)[:, 0]
            return new_st, logits

        final_state, logits_all = jax.lax.scan(outer, state0, tok_blocks)
        return logits_all[-1], final_state

    return ingest
