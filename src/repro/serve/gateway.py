"""Collaborative-intelligence serving gateway — multi-client split inference.

Turns the single-shot :class:`repro.core.split.SplitInferenceEngine` into a
service loop over many concurrent requests (paper Fig. 1 at serving scale):

    edge forward -> rate control picks an OperatingPoint -> negotiate against
    gateway capabilities -> plan.encode -> simulated channel -> micro-batch
    (wire blobs) -> plan.decode_batch (vectorized host decode) -> jitted BaF
    restore (+ fused Pallas consolidation) -> cloud forward -> respond, with
    per-request telemetry.

All coding state flows through :mod:`repro.pipeline`: the rate controller
hands back an :class:`OperatingPoint`, the gateway compiles (cached) one
:class:`CompressionPlan` per point against its per-C model specs, and every
stage reads configuration from the plan — no loose ``(C, bits, backend)``
tuples.

Design points:
  * the rate controller (serve/rate_control.py) consults the channel's
    remaining bit budget per request, so operating points adapt to congestion;
  * ``capabilities`` (repro.pipeline.Capabilities) lets a gateway refuse — or
    downgrade — operating points whose wire profile or backend it does not
    speak, *before* any bytes are encoded;
  * each C has its own BaF predictor (its input width is C) — the gateway
    holds a bank ``{c: (baf_params, sel_idx)}`` compiled into per-C
    ``ModelSpec``s;
  * the micro-batcher (serve/batcher.py) buckets *encoded* requests by
    ``(operating point, H, W)``; decode runs once per micro-batch through
    ``plan.decode_batch`` — the per-channel host numpy loops coalesce across
    the whole bucket — and the restore + cloud forward jit-compile once per
    bucket, never per request;
  * the cloud's service capacity is a pluggable
    :class:`repro.serve.executor.CloudExecutor`: flushed buckets are
    ``submit``-ted and come back as tickets with virtual start/done times
    (``SerialExecutor`` = the single serial cloud, the default;
    ``MultiQueueExecutor`` = N parallel replicas), and an optional
    ``AdmissionPolicy`` sheds excess load explicitly before any edge
    compute is spent;
  * transport and cloud-service timing run on a deterministic virtual
    clock; the real compute's wall time is measured separately (and is the
    virtual duration under the default ``MeasuredCost`` model).
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass

import jax
import numpy as np

from repro import pipeline
from repro.core.split import SplitStats, _jitted_cnn_fns, activation_stats
from repro.pipeline import Capabilities, ModelSpec, OperatingPoint, negotiate
from repro.serve.batcher import EncodedRequest, MicroBatch, MicroBatcher
from repro.serve.channel import ChannelConfig, SimulatedChannel, Transmission
from repro.serve.executor import (AdmissionPolicy, CloudExecutor, ExecTicket,
                                  RequestShed, SerialExecutor)
from repro.serve.rate_control import ContentKeyedController, RateController
from repro.serve.scheduler import (DeficitRoundRobinScheduler, TenantSpec,
                                   UplinkJob)
from repro.serve.telemetry import (RequestRecord, ShedRecord, Telemetry)


@dataclass
class GatewayResponse:
    req_id: int
    logits: np.ndarray            # (num_classes,)
    op: OperatingPoint
    stats: SplitStats             # wire accounting for this request

    @property
    def shed(self) -> bool:       # duck-type discriminator vs RequestShed
        return False


class ServingGateway:
    """Orchestrates decode -> batch -> restore -> cloud for many clients.

    Parameters
    ----------
    params : CNN params (models/cnn.py)
    baf_bank : {c: (baf_params, sel_idx)} — BaF predictor + channel order per C
    channel : SimulatedChannel or None (None = ideal wire, zero latency)
    controller : RateController or None (None = fixed ``default_op``)
    default_op : operating point used when no controller is given
    backend : legacy override — when set, every selected operating point is
              re-based onto this entropy backend (None = respect the point's
              own backend, the plan-API default)
    capabilities : what this gateway speaks; selected operating points are
              negotiated against it (refuse or downgrade) before encoding
    max_batch : micro-batch cap (1 = naive one-at-a-time serving)
    fused : use the Pallas fused-consolidation restore path
    executor : CloudExecutor modeling the cloud's service capacity on the
              virtual clock (None = SerialExecutor(), the single serial
              cloud of previous releases)
    tracer : repro.obs.Tracer collecting virtual-clock span trees (None =
              tracing off, zero per-request overhead beyond an is-None
              check); reassignable between serve runs
    metrics : repro.obs.MetricsRegistry shared across telemetry, executor
              gauges, scheduler and channel counters (None = each serve
              run's Telemetry keeps a private registry)
    """

    def __init__(self, params, baf_bank: dict, *,
                 channel: SimulatedChannel | None = None,
                 controller: RateController | None = None,
                 default_op: OperatingPoint | None = None,
                 backend: str | None = None, max_batch: int = 8,
                 fused: bool = True,
                 capabilities: Capabilities | None = None,
                 executor: CloudExecutor | None = None,
                 shared_executor: bool = False,
                 tracer=None, metrics=None):
        if not baf_bank:
            raise ValueError("empty BaF bank")
        self.params = params
        self.baf_bank = {int(c): (p, np.asarray(s))
                         for c, (p, s) in baf_bank.items()}
        self._specs = {c: ModelSpec(sel_idx=s, params=params, baf_params=p)
                       for c, (p, s) in self.baf_bank.items()}
        self.channel = channel
        self.controller = controller
        self.backend = backend
        self.capabilities = capabilities
        if default_op is None:
            c = max(self.baf_bank)
            default_op = OperatingPoint(c=c, bits=8)
        self.default_op = self._fit_op(default_op)
        self.max_batch = max_batch
        self.fused = fused
        self.tracer = tracer
        self.metrics = metrics
        self.executor = executor if executor is not None else SerialExecutor()
        if shared_executor and executor is None:
            raise ValueError("shared_executor=True needs the shared executor "
                             "passed explicitly")
        self.shared_executor = shared_executor
        if metrics is not None:
            if not shared_executor:
                self.executor.metrics = metrics
            if channel is not None:
                channel.bind_metrics(metrics, tenant="")
        # a mesh-capable executor (duck-typed on run_sharded) takes restore +
        # cloud forward through its shard_map runner; plain executors run the
        # whole batch inline here
        self._run_fn = (self._run_batch_mesh
                        if callable(getattr(self.executor, "run_sharded",
                                            None))
                        else self._run_batch)
        if not shared_executor:
            if self.executor.run_fn is not None:
                # an exclusively-owned executor binds one gateway's batched
                # decode+restore+forward; a second binder would silently run
                # the first gateway's plans against its own blobs (and each
                # serve() resets the other's queues mid-use). Federations
                # pass shared_executor=True and supply run_fn per submit.
                raise ValueError("executor is already bound to another "
                                 "gateway; construct one executor per "
                                 "gateway (or build every gateway with "
                                 "shared_executor=True to federate)")
            self.executor.run_fn = self._run_fn
        # process-wide jitted CNN halves (core.split caches them): gateways
        # share one trace cache, so spinning up per-tenant/solo gateways in
        # benchmarks and tests does not recompile per instance
        self._edge_fn, self._cloud_fn = _jitted_cnn_fns()

    # -- plans --------------------------------------------------------------
    def _fit_op(self, op: OperatingPoint) -> OperatingPoint:
        """Re-base onto the legacy backend override, negotiate against the
        gateway's capabilities, and check the BaF bank covers the C."""
        if self.backend is not None and op.backend != self.backend:
            op = op.with_backend(self.backend)
        op = negotiate(op, self.capabilities)
        if op.c not in self.baf_bank:
            raise ValueError(f"operating point picked C={op.c} with no BaF "
                             f"predictor in the bank {sorted(self.baf_bank)}")
        return op

    def plan_for(self, op: OperatingPoint) -> pipeline.CompressionPlan:
        """The (cached) compression plan this gateway executes for ``op``."""
        return pipeline.compile(op, self._specs[op.c], fused=self.fused)

    # -- edge side ----------------------------------------------------------
    def _pick_op(self, t_submit: float) -> OperatingPoint:
        if self.controller is None:
            return self.default_op
        budget = (self.channel.budget_remaining(at=t_submit)
                  if self.channel is not None else None)
        return self._fit_op(self.controller.select(budget).op)

    def encode_request(self, img, t_submit: float = 0.0):
        """Edge-side work for one request: rate control + encode + transmit.

        img: (1, H, W, 3). Returns (op, WireBlob, SplitStats, Transmission).
        The blob is serialized here — the channel meters its true byte
        length (container header + side info + entropy-coded payload).
        """
        op = self._pick_op(t_submit)
        plan = self.plan_for(op)
        z = self._edge_fn(self.params, img)
        blob = plan.encode(z)
        if self.channel is not None:
            tx = self.channel.transmit_bytes(blob.data, t_submit)
        else:
            tx = Transmission(bits=8 * blob.nbytes, t_submit=t_submit,
                              t_start=t_submit, t_arrive=t_submit)
        return op, blob, blob.stats, tx

    # -- cloud side ---------------------------------------------------------
    def _run_batch(self, batch: MicroBatch) -> tuple[np.ndarray, float]:
        """Batched decode + restore + cloud forward; measured wall time.

        The host decode is part of the cloud side's measured compute now —
        it runs once per micro-batch (plan.decode_batch), not once per
        request on arrival.
        """
        plan = self.plan_for(batch.key.op)
        # repro: allow[RA01] -- warm-timing helper: measures real compute
        # wall for MeasuredCost/CalibratedCostModel; feeds telemetry, never
        # the virtual clock
        t0 = time.perf_counter()
        decoded = plan.decode_batch([r.blob for r in batch.requests])
        z_tilde = plan.restore(decoded.pad_to(batch.padded_size))
        logits = self._cloud_fn(self.params, z_tilde)
        logits = np.asarray(jax.block_until_ready(logits))
        # repro: allow[RA01] -- warm-timing helper (see t0 above)
        return logits, time.perf_counter() - t0

    def _run_batch_mesh(self, batch: MicroBatch) -> tuple[np.ndarray, float]:
        """Batched decode on the host, restore + cloud forward on the mesh.

        Same contract as :meth:`_run_batch` (logits rows align with
        ``batch.requests``, measured wall time), but the device half runs
        through the executor's ``run_sharded`` shard_map program."""
        plan = self.plan_for(batch.key.op)
        # repro: allow[RA01] -- warm-timing helper: measured wall seeds the
        # mesh executor's calibrated cost fit; never enters the virtual clock
        t0 = time.perf_counter()
        decoded = plan.decode_batch([r.blob for r in batch.requests])
        logits = self.executor.run_sharded(plan, decoded, batch.padded_size)
        # repro: allow[RA01] -- warm-timing helper (see t0 above)
        return logits, time.perf_counter() - t0

    def _response_for(self, req: EncodedRequest, ticket: ExecTicket,
                      row: int, op, stats):
        """Build one request's response from its executor ticket row.

        Subclass hook: a task-aware gateway returns a fan-out response
        carrying each of the tenant's declared head outputs (repro.tasks);
        the base gateway returns the single-consumer logits row."""
        return GatewayResponse(req_id=req.req_id, logits=ticket.logits[row],
                               op=op, stats=stats)

    def _exec_batch_spans(self, tracer, ticket: ExecTicket) -> None:
        """Emit batch-level spans for one executor ticket (tracer != None).

        Subclass hook: a task-aware gateway adds per-head ``head.<task>``
        child spans alongside the base ``exec.batch`` span."""
        batch = ticket.batch
        tracer.span("exec.batch", ticket.t_start, ticket.t_done,
                    track=f"exec-q{ticket.queue}", seq=ticket.seq,
                    n_requests=len(batch.requests),
                    padded_size=batch.padded_size)

    def _post_record(self, req: EncodedRequest, out,
                     telemetry: Telemetry) -> None:
        """Per-request hook after telemetry.record (base: no-op).

        A task-aware gateway meters per-task request counters here."""

    def _record_ticket(self, ticket: ExecTicket, responses,
                       telemetry: Telemetry) -> None:
        """Fan one finished executor ticket out to per-request results.

        When a tracer is attached, each served request also emits its span
        tree here — a ``request`` root whose children (sched.wait /
        channel.transmit / exec.queue / cloud.compute) are built from the
        *same* virtual-clock floats the RequestRecord holds, so per-request
        span durations sum to ``total_latency_s`` exactly, and a batch-level
        ``exec.batch`` span on the serving queue's track."""
        tracer = self.tracer
        batch = ticket.batch
        if tracer is not None:
            self._exec_batch_spans(tracer, ticket)
        for row, req in enumerate(batch.requests):      # padding rows ignored
            op, stats, tx = req.meta[:3]
            out = self._response_for(req, ticket, row, op, stats)
            # "" is the documented single-tenant sentinel (serve/batcher.py);
            # the multi-tenant arrive handler always sets a tenant name and
            # appends the UplinkJob as meta[3]
            multi_tenant = req.tenant != ""
            if multi_tenant:
                responses[req.tenant][req.req_id] = out
            else:
                responses[req.req_id] = out
            telemetry.record(RequestRecord(
                req_id=req.req_id, c=op.c, bits=op.bits,
                bits_on_wire=stats.wire_bits,
                wire_latency_s=tx.t_arrive - tx.t_submit,
                queue_wait_s=ticket.t_start - req.t_arrive,
                compute_s=ticket.service_s,
                batch_size=len(batch.requests),
                padded_size=batch.padded_size,
                tenant=req.tenant,
                sched_wait_s=(tx.t_submit - req.meta[3].t_enqueue
                              if multi_tenant else 0.0),
                exec_queue=ticket.queue))
            if tracer is not None:
                t0 = req.meta[3].t_enqueue if multi_tenant else tx.t_submit
                track = f"tenant:{req.tenant or 'default'}"
                root = tracer.span(
                    "request", t0, ticket.t_done, track=track,
                    tenant=req.tenant, req_id=req.req_id, op=str(op),
                    wire_bits=stats.wire_bits,
                    padded_size=batch.padded_size, exec_queue=ticket.queue)
                tracer.span("sched.wait", t0, tx.t_submit, track=track,
                            parent=root)
                tracer.span("channel.transmit", tx.t_submit, tx.t_arrive,
                            track=track, parent=root,
                            wire_bits=stats.wire_bits)
                tracer.span("exec.queue", req.t_arrive, ticket.t_start,
                            track=track, parent=root,
                            exec_queue=ticket.queue)
                tracer.span("cloud.compute", ticket.t_start, ticket.t_done,
                            track=track, parent=root,
                            exec_queue=ticket.queue,
                            batch_size=len(batch.requests))
            self._post_record(req, out, telemetry)

    # -- orchestration loop -------------------------------------------------
    def serve(self, imgs, *, submit_times=None) -> tuple[list[GatewayResponse],
                                                         Telemetry]:
        """Serve one request per row of ``imgs`` (N, H, W, 3).

        Responses come back in submission order regardless of channel
        reordering or batching; telemetry holds the per-request records.
        The cloud side runs through ``self.executor`` on the virtual clock,
        so queue_wait/latency telemetry includes waiting for busy cloud
        queues — the same accounting as the multi-tenant event loop
        (previous releases dispatched single-tenant batches the instant
        they filled, modeling no cloud occupancy at all).
        """
        imgs = np.asarray(imgs)
        n = imgs.shape[0]
        if submit_times is None:
            submit_times = [0.0] * n
        self.executor.reset()
        # 1. edge side: rate control, encode, transmit — in submit-time order
        # (the simulated link is FIFO by call, so out-of-order calls would
        # charge early requests for wire time the late ones occupied)
        inflight = []
        tracer = self.tracer
        for i in sorted(range(n), key=lambda k: float(submit_times[k])):
            t_submit = float(submit_times[i])
            op, blob, stats, tx = self.encode_request(imgs[i:i + 1], t_submit)
            if tracer is not None:
                tracer.instant("submit", t_submit, track="tenant:default",
                               req_id=i)
                tracer.instant("edge.encode", t_submit, track="tenant:default",
                               req_id=i, op=str(op),
                               wire_bits=8 * blob.nbytes)
            inflight.append((i, op, blob, stats, tx))
        # 2. cloud side: micro-batch encoded blobs in arrival order; decode
        # runs batched per bucket inside _run_batch, scheduled by the
        # executor (tickets carry the virtual start/done times)
        inflight.sort(key=lambda item: (item[4].t_arrive, item[0]))
        responses: list[GatewayResponse | None] = [None] * n
        telemetry = Telemetry(registry=self.metrics)
        batcher = MicroBatcher(max_batch=self.max_batch)

        def run(batch: MicroBatch) -> None:
            # submit plans the virtual times and runs the real compute;
            # results are consumed (and the batch/logits refs released)
            # immediately, so memory tracks one batch, not the workload
            ticket = self.executor.submit(
                batch, max(r.t_arrive for r in batch.requests),
                run_fn=self._run_fn)
            self.executor.on_start(ticket)
            self._record_ticket(ticket, responses, telemetry)
            self.executor.complete(ticket)

        for i, op, blob, stats, tx in inflight:
            req = EncodedRequest(req_id=i, blob=blob, t_arrive=tx.t_arrive,
                                 meta=(op, stats, tx))
            for full in batcher.add(req):
                run(full)
        for rest in batcher.flush():
            run(rest)
        assert all(r is not None for r in responses)
        if self.metrics is not None:
            self.executor.export_metrics(self.metrics)
        return responses, telemetry


# ---------------------------------------------------------------------------
# Multi-tenant, event-driven serving
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TenantRequest:
    """One request of the multi-tenant workload."""
    tenant: str
    img: object                  # (H, W, 3) or (1, H, W, 3)
    t_submit: float = 0.0


class MultiTenantGateway(ServingGateway):
    """Event-driven serving over N tenants sharing one uplink bit budget.

    Replaces :meth:`ServingGateway.serve`'s strict encode -> batch -> restore
    phases with a virtual-clock event loop where edge submits, uplink drain
    ticks, channel arrivals, batch-window flushes, and cloud-compute
    completions interleave:

        submit  : edge forward + content-keyed rate control + capability
                  negotiation + plan.encode; the encoded job queues at the
                  DRR scheduler
        drain   : the scheduler grants queued jobs against the shared
                  per-tick budget (weighted DRR, starvation-free); granted
                  jobs enter their tenant's own channel
        arrive  : the wire blob goes straight into the micro-batcher —
                  buckets are keyed (operating point, H, W) only, so tenants
                  share buckets and decode/restore compiles stay bounded
                  under heterogeneous traffic (decode itself is deferred to
                  dispatch and runs batched)
        flush   : a partially-filled bucket hits its batch window; with
                  ``adaptive_window=True`` the window follows the bucket's
                  arrival-rate EWMA (burst-aware: bursts flush near-full
                  buckets fast, sparse traffic stops waiting for stragglers
                  that are not coming)
        exec_start : the cloud executor's queue begins serving a dispatched
                  batch (``executor.submit`` planned its virtual start/done
                  when the bucket flushed; depth introspection follows these
                  events, so admission control sees the live backlog)
        exec_done : batched decode + restore + cloud forward finished on the
                  executor's virtual clock; responses + telemetry record

    The cloud is a pluggable :class:`repro.serve.executor.CloudExecutor`:
    the default ``SerialExecutor`` reproduces the single serial cloud of
    previous releases; ``MultiQueueExecutor`` models N parallel replicas
    with work-conserving queue selection. An optional ``admission`` policy
    (token buckets, queue-depth thresholds) runs at submit — before any
    edge compute or encoding — and every rejection becomes an explicit
    :class:`RequestShed` in the tenant's response list plus a ``shed``
    telemetry record; nothing is ever silently dropped.

    Per-tenant channels must be unmetered — the *shared* budget lives in the
    scheduler; a per-channel budget would meter the same bits twice.
    Channels, executor, and admission state are reset at the start of every
    ``serve_tenants`` call, so a repeat of the same workload replays
    bit-identically (exactly so when the executor uses a deterministic cost
    model such as ``LinearCostModel``).
    """

    def __init__(self, params, baf_bank: dict, *,
                 tenants: "list[TenantSpec] | tuple[TenantSpec, ...]",
                 channel_cfg: ChannelConfig | None = None,
                 channels: dict[str, SimulatedChannel] | None = None,
                 controller: RateController | None = None,
                 default_op: OperatingPoint | None = None,
                 backend: str | None = None, max_batch: int = 8,
                 fused: bool = True,
                 capabilities: Capabilities | None = None,
                 budget_bits_per_tick: int | None = None,
                 tick_s: float = 1.0, quantum_bits: int | None = None,
                 batch_window_s: float | None = 0.02,
                 adaptive_window: bool = False,
                 min_window_s: float = 0.0, seed: int = 0,
                 executor: CloudExecutor | None = None,
                 shared_executor: bool = False,
                 admission: AdmissionPolicy | None = None,
                 tracer=None, metrics=None):
        super().__init__(params, baf_bank, channel=None, controller=None,
                         default_op=default_op, backend=backend,
                         max_batch=max_batch, fused=fused,
                         capabilities=capabilities, executor=executor,
                         shared_executor=shared_executor,
                         tracer=tracer, metrics=metrics)
        self.admission = admission
        specs = list(tenants)
        if not specs:
            raise ValueError("need at least one tenant")
        self.specs = {t.name: t for t in specs}
        if channels is None:
            cfg = channel_cfg if channel_cfg is not None else ChannelConfig()
            if cfg.budget_bits_per_tick is not None:
                raise ValueError("per-tenant channels must be unmetered; "
                                 "set budget_bits_per_tick on the gateway "
                                 "(shared scheduler budget) instead")
            channels = {t.name: SimulatedChannel(cfg, seed=seed + i)
                        for i, t in enumerate(specs)}
        missing = set(self.specs) - set(channels)
        if missing:
            raise ValueError(f"no channel for tenants {sorted(missing)}")
        metered = [n for n, ch in channels.items()
                   if ch.cfg.budget_bits_per_tick is not None]
        if metered:
            raise ValueError(f"per-tenant channels must be unmetered (the "
                             f"scheduler owns the shared budget; a channel "
                             f"budget would meter the same bits twice): "
                             f"{sorted(metered)}")
        self.channels = channels
        if metrics is not None:
            for name, ch in channels.items():
                ch.bind_metrics(metrics, tenant=name)
        self.mt_controller = controller
        self._sched_args = dict(budget_bits_per_tick=budget_bits_per_tick,
                                tick_s=tick_s, quantum_bits=quantum_bits)
        self.batch_window_s = batch_window_s
        self.adaptive_window = adaptive_window
        self.min_window_s = min_window_s

    # -- edge side ----------------------------------------------------------
    def _pick_tenant_op(self, spec: TenantSpec, z, budget: float):
        ctrl = self.mt_controller
        if ctrl is None:
            return self.default_op
        if isinstance(ctrl, ContentKeyedController):
            z_np = np.asarray(z)        # one device->host copy, not one per C
            stats = {c: activation_stats(z_np, sel)
                     for c, (_, sel) in self.baf_bank.items()}
            rd = ctrl.select_for(budget, stats, spec.quality_floor_db)
        else:
            rd = ctrl.select(budget)
        return self._fit_op(rd.op)

    # -- orchestration ------------------------------------------------------
    def _begin_run(self, workload: "list[TenantRequest]") -> "_FederatedRun":
        """Reset this gateway's per-run state (channels, admission, a fresh
        scheduler/batcher/telemetry) and return it bundled for the event
        loop. The shared executor is NOT reset here — the federation driver
        resets it exactly once per run."""
        for w in workload:
            if w.tenant not in self.specs:
                raise KeyError(f"unknown tenant {w.tenant!r}")
        for ch in self.channels.values():
            ch.reset()
        if self.admission is not None:
            self.admission.reset()
        sched = DeficitRoundRobinScheduler(self.specs.values(),
                                           **self._sched_args)
        if self.metrics is not None:
            sched.bind_metrics(self.metrics)
        self.last_scheduler = sched          # post-run introspection (tests,
        return _FederatedRun(                # fairness/budget audits)
            gateway=self, sched=sched,
            telemetry=Telemetry(registry=self.metrics),
            batcher=MicroBatcher(max_batch=self.max_batch,
                                 window_s=self.batch_window_s,
                                 adaptive=self.adaptive_window,
                                 min_window_s=self.min_window_s),
            responses={n: {} for n in self.specs},
            counts={n: 0 for n in self.specs},
            n_requests=len(workload))

    def _finish_run(self, st: "_FederatedRun") -> tuple[dict[str, list],
                                                        Telemetry]:
        # no silent drops: every submission ended as exactly one response
        # or one explicit shed outcome
        out = {}
        for name, got in st.responses.items():
            assert len(got) == st.counts[name], (
                f"tenant {name}: {len(got)}/{st.counts[name]} outcomes")
            out[name] = [got[i] for i in range(st.counts[name])]
        assert len(st.telemetry) + len(st.telemetry.shed) == st.n_requests
        if self.metrics is not None:
            self.executor.export_metrics(self.metrics)
        return out, st.telemetry

    def serve_tenants(self, workload: "list[TenantRequest]") -> tuple[
            dict[str, list], Telemetry]:
        """Run the event loop over the whole workload; returns per-tenant
        outcomes (in per-tenant submission order — each entry is a
        :class:`GatewayResponse` or an explicit :class:`RequestShed`) and
        merged telemetry (served records + the separate ``shed`` series).

        A federation of one: the full loop lives in
        :func:`serve_federated`, which drives M gateways on a single
        virtual clock against one shared executor."""
        return serve_federated([(self, workload)])[0]


# ---------------------------------------------------------------------------
# Gateway federation: M gateways, one shared cloud executor
# ---------------------------------------------------------------------------

@dataclass
class _FederatedRun:
    """One gateway's per-run state inside a federated event loop."""
    gateway: MultiTenantGateway
    sched: DeficitRoundRobinScheduler
    telemetry: Telemetry
    batcher: MicroBatcher
    responses: dict                   # tenant -> {req_id: outcome}
    counts: dict                      # tenant -> submissions seen
    n_requests: int
    # dedupe only drains that have not run yet: a submit landing at a
    # timestamp whose drain already executed must get a fresh one, or its
    # job would strand in the scheduler queue
    drain_times: "set[float]" = None
    # generation -> earliest flush time scheduled so far. Adaptive windows
    # can move a group's deadline *earlier* as arrivals sharpen the rate
    # estimate; re-push then (stale later events no-op via gen)
    scheduled_flushes: "dict[int, float]" = None

    def __post_init__(self):
        self.drain_times = set()
        self.scheduled_flushes = {}


def serve_federated(runs: "list[tuple[MultiTenantGateway, list]]"
                    ) -> "list[tuple[dict[str, list], Telemetry]]":
    """Drive M gateways' event loops on ONE virtual clock against ONE shared
    cloud executor.

    ``runs`` is ``[(gateway, workload), ...]``. Every gateway keeps its own
    tenants, uplink scheduler, channels, admission policy, batcher, and
    telemetry; the cloud capacity — the mesh — is common. Events from all
    gateways interleave in global time order on a single heap, so a bucket
    flushed by gateway 0 occupies the shared executor exactly when gateway
    1's admission policy reads ``executor.depth()`` (shared-mesh depth
    introspection: one gateway's burst sheds another's overflow).

    Each submit passes the owning gateway's ``run_fn``, so one executor
    serves every gateway's plans without rebinding. Returns one
    ``(outcomes, telemetry)`` per run, aligned with ``runs``; replay is
    bit-identical under a deterministic cost model (``LinearCostModel`` or a
    frozen ``CalibratedCostModel``).
    """
    if not runs:
        raise ValueError("serve_federated needs at least one "
                         "(gateway, workload) pair")
    gateways = [gw for gw, _ in runs]
    if len(set(map(id, gateways))) != len(gateways):
        raise ValueError("each gateway may appear once per federation")
    executor = gateways[0].executor
    for gw in gateways[1:]:
        if gw.executor is not executor:
            raise ValueError("federated gateways must share one executor "
                             "(build them with shared_executor=True around "
                             "a single instance)")
    executor.reset()
    states = [gw._begin_run(workload) for gw, workload in runs]

    events: list = []
    seq = itertools.count()

    def push(t: float, gi: int, kind: str, payload) -> None:
        heapq.heappush(events, (float(t), next(seq), gi, kind, payload))

    def schedule_drain(t: float, gi: int) -> None:
        t = float(t)
        st = states[gi]
        if t not in st.drain_times:
            st.drain_times.add(t)
            push(t, gi, "drain", None)

    def dispatch(gi: int, batch: MicroBatch, t_ready: float) -> None:
        # the executor plans the batch onto a queue of its virtual clock;
        # the loop replays the planned times as events so depth
        # introspection (admission's signal) tracks the virtual clock
        ticket = executor.submit(batch, t_ready,
                                 run_fn=states[gi].gateway._run_fn)
        push(ticket.t_start, gi, "exec_start", ticket)
        push(ticket.t_done, gi, "exec_done", ticket)

    for gi, (gw, workload) in enumerate(runs):
        for w in workload:
            push(w.t_submit, gi, "submit", w)

    while events:
        t, _, gi, kind, payload = heapq.heappop(events)
        gw = gateways[gi]
        st = states[gi]
        tracer = gw.tracer

        if kind == "submit":
            w = payload
            spec = gw.specs[w.tenant]
            local_id = st.counts[w.tenant]
            st.counts[w.tenant] += 1
            if tracer is not None:
                tracer.instant("submit", t, track=f"tenant:{w.tenant}",
                               tenant=w.tenant, req_id=local_id)
            if gw.admission is not None:
                decision = gw.admission.admit(
                    tenant=w.tenant, priority=spec.priority, t=t,
                    executor=executor)
                if not decision.admitted:
                    # shed BEFORE any edge compute or encoding is spent;
                    # the outcome is explicit: it takes the response slot
                    # and lands in telemetry's separate shed series
                    outcome = RequestShed(
                        req_id=local_id, tenant=w.tenant, t_submit=t,
                        reason=decision.reason, priority=spec.priority)
                    st.responses[w.tenant][local_id] = outcome
                    st.telemetry.record_shed(ShedRecord(
                        req_id=local_id, tenant=w.tenant, t_submit=t,
                        reason=decision.reason, priority=spec.priority))
                    if tracer is not None:
                        tracer.instant(
                            "admission.shed", t,
                            track=f"tenant:{w.tenant}", tenant=w.tenant,
                            req_id=local_id, reason=decision.reason,
                            priority=spec.priority)
                    continue
            img = np.asarray(w.img)
            if img.ndim == 3:
                img = img[None]
            z = gw._edge_fn(gw.params, img)
            op = gw._pick_tenant_op(spec, z, st.sched.budget_remaining(t))
            blob = gw.plan_for(op).encode(z)
            if tracer is not None:
                tracer.instant("edge.encode", t,
                               track=f"tenant:{w.tenant}",
                               tenant=w.tenant, req_id=local_id,
                               op=str(op), wire_bits=8 * blob.nbytes)
            # the scheduler meters the job at its true container length,
            # so DRR shares reflect real bits on the wire
            st.sched.enqueue(UplinkJob(
                tenant=w.tenant, req_id=local_id, bits=8 * blob.nbytes,
                t_enqueue=t, payload=(op, blob, blob.stats)))
            schedule_drain(t, gi)

        elif kind == "drain":
            st.drain_times.discard(t)
            for job in st.sched.drain(t):
                blob = job.payload[1]
                tx = gw.channels[job.tenant].transmit_bytes(blob.data, t)
                push(tx.t_arrive, gi, "arrive", (job, tx))
            if st.sched.pending():
                schedule_drain(st.sched.next_tick_time(t), gi)

        elif kind == "arrive":
            job, tx = payload
            op, blob, stats = job.payload
            req = EncodedRequest(
                req_id=job.req_id, blob=blob, t_arrive=t,
                meta=(op, stats, tx, job), tenant=job.tenant,
                priority=gw.specs[job.tenant].priority)
            fulls = st.batcher.add(req, now=t)
            for full in fulls:
                dispatch(gi, full, t)
            if not fulls:
                deadline = st.batcher.deadline(req.key)
                if deadline is not None:
                    due, gen = deadline
                    if due < st.scheduled_flushes.get(gen, float("inf")):
                        st.scheduled_flushes[gen] = due
                        push(due, gi, "flush", (req.key, gen))

        elif kind == "flush":
            key, gen = payload
            current = st.batcher.deadline(key)
            if (current is not None and current[1] == gen
                    and current[0] > t + 1e-12):
                # the adaptive estimate drifted *later* (traffic
                # decelerated after this event was scheduled): chase the
                # new due time instead of flushing undersized. Each
                # re-push is strictly later and the deadline is capped
                # at t_first + window_s, so the chase terminates.
                st.scheduled_flushes[gen] = current[0]
                push(current[0], gi, "flush", (key, gen))
            else:
                batch = st.batcher.take(key, gen)
                if batch is not None:
                    st.scheduled_flushes.pop(gen, None)
                    dispatch(gi, batch, t)

        elif kind == "exec_start":
            executor.on_start(payload)

        elif kind == "exec_done":
            gw._record_ticket(payload, st.responses, st.telemetry)
            executor.complete(payload)   # releases batch/logits refs

        # events may drain while buckets still hold requests (no batch
        # window): sweep every gateway's leftovers through the same
        # dispatch path, in federation order (deterministic)
        if not events:
            for gj, sj in enumerate(states):
                for rest in sj.batcher.flush():
                    dispatch(gj, rest,
                             max(r.t_arrive for r in rest.requests))

    return [gw._finish_run(st) for gw, st in zip(gateways, states)]


class GatewayFederation:
    """M multi-tenant gateways sharing one cloud executor (the shared mesh).

    Construction validates the sharing contract — every gateway holds the
    same executor instance and (for M > 1) was built with
    ``shared_executor=True``. :meth:`serve` zips gateways with their
    workloads onto one virtual clock via :func:`serve_federated`; admission
    stays per-gateway while ``depth()`` exposes the shared-mesh backlog all
    of them key on.
    """

    def __init__(self, gateways: "list[MultiTenantGateway]"):
        gateways = list(gateways)
        if not gateways:
            raise ValueError("federation needs at least one gateway")
        executor = gateways[0].executor
        for gw in gateways:
            if gw.executor is not executor:
                raise ValueError("federated gateways must share one executor")
            if len(gateways) > 1 and not gw.shared_executor:
                raise ValueError("build federated gateways with "
                                 "shared_executor=True")
        self.gateways = gateways
        self.executor = executor

    def serve(self, workloads: "list[list[TenantRequest]]"
              ) -> "list[tuple[dict[str, list], Telemetry]]":
        if len(workloads) != len(self.gateways):
            raise ValueError(f"{len(workloads)} workloads for "
                             f"{len(self.gateways)} gateways")
        return serve_federated(list(zip(self.gateways, workloads)))

    def depth(self) -> int:
        """Shared-mesh backlog every member's admission policy reads."""
        return self.executor.depth()
