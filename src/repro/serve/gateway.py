"""Collaborative-intelligence serving gateway — multi-client split inference.

Turns the single-shot :class:`repro.core.split.SplitInferenceEngine` into a
service loop over many concurrent requests (paper Fig. 1 at serving scale):

    edge forward -> rate control picks (C, bits) -> encode -> simulated
    channel -> decode -> micro-batch -> jitted BaF restore (+ fused Pallas
    consolidation) -> cloud forward -> respond, with per-request telemetry.

Design points:
  * the rate controller (serve/rate_control.py) consults the channel's
    remaining bit budget per request, so operating points adapt to congestion;
  * each C has its own BaF predictor (its input width is C) — the gateway
    holds a bank ``{c: (baf_params, sel_idx)}``;
  * the micro-batcher (serve/batcher.py) pads groups with equal
    ``(C, bits, H, W)`` to power-of-two batch sizes so the restore + cloud
    forward jit-compile once per bucket, never per request;
  * transport timing is simulated (deterministic virtual clock), compute
    timing is measured — telemetry keeps the two separate.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as wire
from repro.core.split import (SplitStats, decode_stream, encode_activation,
                              restore_codes, restore_codes_fused)
from repro.serve.batcher import DecodedRequest, MicroBatch, MicroBatcher
from repro.serve.channel import SimulatedChannel, Transmission
from repro.serve.rate_control import OperatingPoint, RateController
from repro.serve.telemetry import RequestRecord, Telemetry


@dataclass
class GatewayResponse:
    req_id: int
    logits: np.ndarray            # (num_classes,)
    op: OperatingPoint
    stats: SplitStats             # wire accounting for this request


class ServingGateway:
    """Orchestrates decode -> batch -> restore -> cloud for many clients.

    Parameters
    ----------
    params : CNN params (models/cnn.py)
    baf_bank : {c: (baf_params, sel_idx)} — BaF predictor + channel order per C
    channel : SimulatedChannel or None (None = ideal wire, zero latency)
    controller : RateController or None (None = fixed ``default_op``)
    default_op : operating point used when no controller is given
    max_batch : micro-batch cap (1 = naive one-at-a-time serving)
    fused : use the Pallas fused-consolidation restore path
    """

    def __init__(self, params, baf_bank: dict, *,
                 channel: SimulatedChannel | None = None,
                 controller: RateController | None = None,
                 default_op: OperatingPoint | None = None,
                 backend: str = "zlib", max_batch: int = 8,
                 fused: bool = True):
        if not baf_bank:
            raise ValueError("empty BaF bank")
        from repro.models.cnn import cnn_cloud, cnn_edge  # local: avoid cycle
        self.params = params
        self.baf_bank = {int(c): (p, jnp.asarray(np.asarray(s), jnp.int32))
                         for c, (p, s) in baf_bank.items()}
        self.channel = channel
        self.controller = controller
        if default_op is None:
            c = max(self.baf_bank)
            default_op = OperatingPoint(c=c, bits=8)
        if default_op.c not in self.baf_bank:
            raise ValueError(f"no BaF predictor for C={default_op.c}")
        self.default_op = default_op
        self.backend = backend
        self.max_batch = max_batch
        self.fused = fused
        self._edge_fn = jax.jit(lambda p, img: cnn_edge(p, img)[1])
        self._cloud_fn = jax.jit(cnn_cloud)

    # -- edge side ----------------------------------------------------------
    def _pick_op(self, t_submit: float) -> OperatingPoint:
        if self.controller is None:
            return self.default_op
        budget = (self.channel.budget_remaining(at=t_submit)
                  if self.channel is not None else None)
        rd = self.controller.select(budget)
        if rd.op.c not in self.baf_bank:
            raise ValueError(f"RD table picked C={rd.op.c} with no BaF "
                             f"predictor in the bank {sorted(self.baf_bank)}")
        return rd.op

    def encode_request(self, img, t_submit: float = 0.0):
        """Edge-side work for one request: rate control + encode + transmit.

        img: (1, H, W, 3). Returns (op, EncodedTensor, SplitStats, Transmission).
        """
        op = self._pick_op(t_submit)
        _, sel_idx = self.baf_bank[op.c]
        z = self._edge_fn(self.params, img)
        enc, stats = encode_activation(z, sel_idx, op.bits,
                                       backend=self.backend)
        if self.channel is not None:
            tx = self.channel.transmit(stats.total_bits, t_submit)
        else:
            tx = Transmission(bits=stats.total_bits, t_submit=t_submit,
                              t_start=t_submit, t_arrive=t_submit)
        return op, enc, stats, tx

    # -- cloud side ---------------------------------------------------------
    def _restore(self, key, codes, mins, maxs):
        baf_params, sel_idx = self.baf_bank[key.c]
        if self.fused:
            return restore_codes_fused(baf_params, self.params["split"],
                                       sel_idx, codes, mins, maxs,
                                       bits=key.bits)
        return restore_codes(baf_params, self.params["split"], sel_idx,
                             codes, mins, maxs, bits=key.bits,
                             consolidation=True)

    def _process_batch(self, batch: MicroBatch, responses: list,
                       telemetry: Telemetry) -> None:
        t_dispatch = max(r.t_arrive for r in batch.requests)
        t0 = time.perf_counter()
        z_tilde = self._restore(batch.key, jnp.asarray(batch.codes),
                                jnp.asarray(batch.mins),
                                jnp.asarray(batch.maxs))
        logits = self._cloud_fn(self.params, z_tilde)
        logits = np.asarray(jax.block_until_ready(logits))
        compute_s = time.perf_counter() - t0
        for row, req in enumerate(batch.requests):      # padding rows ignored
            op, stats, tx = req.meta
            responses[req.req_id] = GatewayResponse(
                req_id=req.req_id, logits=logits[row], op=op, stats=stats)
            telemetry.record(RequestRecord(
                req_id=req.req_id, c=op.c, bits=op.bits,
                bits_on_wire=stats.total_bits,
                wire_latency_s=tx.latency_s,
                queue_wait_s=t_dispatch - req.t_arrive,
                compute_s=compute_s,
                batch_size=len(batch.requests),
                padded_size=batch.padded_size))

    # -- orchestration loop -------------------------------------------------
    def serve(self, imgs, *, submit_times=None) -> tuple[list[GatewayResponse],
                                                         Telemetry]:
        """Serve one request per row of ``imgs`` (N, H, W, 3).

        Responses come back in submission order regardless of channel
        reordering or batching; telemetry holds the per-request records.
        """
        imgs = np.asarray(imgs)
        n = imgs.shape[0]
        if submit_times is None:
            submit_times = [0.0] * n
        # 1. edge side: rate control, encode, transmit — in submit-time order
        # (the simulated link is FIFO by call, so out-of-order calls would
        # charge early requests for wire time the late ones occupied)
        inflight = []
        for i in sorted(range(n), key=lambda k: float(submit_times[k])):
            op, enc, stats, tx = self.encode_request(imgs[i:i + 1],
                                                     float(submit_times[i]))
            inflight.append((i, op, enc, stats, tx))
        # 2. cloud side: decode in arrival order, micro-batch, restore, respond
        inflight.sort(key=lambda item: (item[4].t_arrive, item[0]))
        responses: list[GatewayResponse | None] = [None] * n
        telemetry = Telemetry()
        batcher = MicroBatcher(max_batch=self.max_batch)
        for i, op, enc, stats, tx in inflight:
            blob = enc.to_bytes()                        # real wire round-trip
            codes, mins, maxs = decode_stream(
                wire.EncodedTensor.from_bytes(blob), batch=1, c=op.c)
            req = DecodedRequest(
                req_id=i, codes=np.asarray(codes), mins=np.asarray(mins),
                maxs=np.asarray(maxs), c=op.c, bits=op.bits,
                t_arrive=tx.t_arrive, meta=(op, stats, tx))
            for full in batcher.add(req):
                self._process_batch(full, responses, telemetry)
        for rest in batcher.flush():
            self._process_batch(rest, responses, telemetry)
        assert all(r is not None for r in responses)
        return responses, telemetry
