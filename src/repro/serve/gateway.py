"""Collaborative-intelligence serving gateway — multi-client split inference.

Turns the single-shot :class:`repro.core.split.SplitInferenceEngine` into a
service loop over many concurrent requests (paper Fig. 1 at serving scale):

    edge forward -> rate control picks an OperatingPoint -> negotiate against
    gateway capabilities -> plan.encode -> simulated channel -> micro-batch
    (wire blobs) -> plan.decode_batch (vectorized host decode) -> jitted BaF
    restore (+ fused Pallas consolidation) -> cloud forward -> respond, with
    per-request telemetry.

All coding state flows through :mod:`repro.pipeline`: the rate controller
hands back an :class:`OperatingPoint`, the gateway compiles (cached) one
:class:`CompressionPlan` per point against its per-C model specs, and every
stage reads configuration from the plan — no loose ``(C, bits, backend)``
tuples.

Design points:
  * the rate controller (serve/rate_control.py) consults the channel's
    remaining bit budget per request, so operating points adapt to congestion;
  * ``capabilities`` (repro.pipeline.Capabilities) lets a gateway refuse — or
    downgrade — operating points whose wire profile or backend it does not
    speak, *before* any bytes are encoded;
  * each C has its own BaF predictor (its input width is C) — the gateway
    holds a bank ``{c: (baf_params, sel_idx)}`` compiled into per-C
    ``ModelSpec``s;
  * the micro-batcher (serve/batcher.py) buckets *encoded* requests by
    ``(operating point, H, W)``; decode runs once per micro-batch through
    ``plan.decode_batch`` — the per-channel host numpy loops coalesce across
    the whole bucket — and the restore + cloud forward jit-compile once per
    bucket, never per request;
  * transport timing is simulated (deterministic virtual clock), compute
    timing is measured — telemetry keeps the two separate.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass

import jax
import numpy as np

from repro import pipeline
from repro.core.split import SplitStats, _jitted_cnn_fns, activation_stats
from repro.pipeline import Capabilities, ModelSpec, OperatingPoint, negotiate
from repro.serve.batcher import EncodedRequest, MicroBatch, MicroBatcher
from repro.serve.channel import ChannelConfig, SimulatedChannel, Transmission
from repro.serve.rate_control import ContentKeyedController, RateController
from repro.serve.scheduler import (DeficitRoundRobinScheduler, TenantSpec,
                                   UplinkJob)
from repro.serve.telemetry import RequestRecord, Telemetry


@dataclass
class GatewayResponse:
    req_id: int
    logits: np.ndarray            # (num_classes,)
    op: OperatingPoint
    stats: SplitStats             # wire accounting for this request


class ServingGateway:
    """Orchestrates decode -> batch -> restore -> cloud for many clients.

    Parameters
    ----------
    params : CNN params (models/cnn.py)
    baf_bank : {c: (baf_params, sel_idx)} — BaF predictor + channel order per C
    channel : SimulatedChannel or None (None = ideal wire, zero latency)
    controller : RateController or None (None = fixed ``default_op``)
    default_op : operating point used when no controller is given
    backend : legacy override — when set, every selected operating point is
              re-based onto this entropy backend (None = respect the point's
              own backend, the plan-API default)
    capabilities : what this gateway speaks; selected operating points are
              negotiated against it (refuse or downgrade) before encoding
    max_batch : micro-batch cap (1 = naive one-at-a-time serving)
    fused : use the Pallas fused-consolidation restore path
    """

    def __init__(self, params, baf_bank: dict, *,
                 channel: SimulatedChannel | None = None,
                 controller: RateController | None = None,
                 default_op: OperatingPoint | None = None,
                 backend: str | None = None, max_batch: int = 8,
                 fused: bool = True,
                 capabilities: Capabilities | None = None):
        if not baf_bank:
            raise ValueError("empty BaF bank")
        self.params = params
        self.baf_bank = {int(c): (p, np.asarray(s))
                         for c, (p, s) in baf_bank.items()}
        self._specs = {c: ModelSpec(sel_idx=s, params=params, baf_params=p)
                       for c, (p, s) in self.baf_bank.items()}
        self.channel = channel
        self.controller = controller
        self.backend = backend
        self.capabilities = capabilities
        if default_op is None:
            c = max(self.baf_bank)
            default_op = OperatingPoint(c=c, bits=8)
        self.default_op = self._fit_op(default_op)
        self.max_batch = max_batch
        self.fused = fused
        # process-wide jitted CNN halves (core.split caches them): gateways
        # share one trace cache, so spinning up per-tenant/solo gateways in
        # benchmarks and tests does not recompile per instance
        self._edge_fn, self._cloud_fn = _jitted_cnn_fns()

    # -- plans --------------------------------------------------------------
    def _fit_op(self, op: OperatingPoint) -> OperatingPoint:
        """Re-base onto the legacy backend override, negotiate against the
        gateway's capabilities, and check the BaF bank covers the C."""
        if self.backend is not None and op.backend != self.backend:
            op = op.with_backend(self.backend)
        op = negotiate(op, self.capabilities)
        if op.c not in self.baf_bank:
            raise ValueError(f"operating point picked C={op.c} with no BaF "
                             f"predictor in the bank {sorted(self.baf_bank)}")
        return op

    def plan_for(self, op: OperatingPoint) -> pipeline.CompressionPlan:
        """The (cached) compression plan this gateway executes for ``op``."""
        return pipeline.compile(op, self._specs[op.c], fused=self.fused)

    # -- edge side ----------------------------------------------------------
    def _pick_op(self, t_submit: float) -> OperatingPoint:
        if self.controller is None:
            return self.default_op
        budget = (self.channel.budget_remaining(at=t_submit)
                  if self.channel is not None else None)
        return self._fit_op(self.controller.select(budget).op)

    def encode_request(self, img, t_submit: float = 0.0):
        """Edge-side work for one request: rate control + encode + transmit.

        img: (1, H, W, 3). Returns (op, WireBlob, SplitStats, Transmission).
        The blob is serialized here — the channel meters its true byte
        length (container header + side info + entropy-coded payload).
        """
        op = self._pick_op(t_submit)
        plan = self.plan_for(op)
        z = self._edge_fn(self.params, img)
        blob = plan.encode(z)
        if self.channel is not None:
            tx = self.channel.transmit_bytes(blob.data, t_submit)
        else:
            tx = Transmission(bits=8 * blob.nbytes, t_submit=t_submit,
                              t_start=t_submit, t_arrive=t_submit)
        return op, blob, blob.stats, tx

    # -- cloud side ---------------------------------------------------------
    def _run_batch(self, batch: MicroBatch) -> tuple[np.ndarray, float]:
        """Batched decode + restore + cloud forward; measured wall time.

        The host decode is part of the cloud side's measured compute now —
        it runs once per micro-batch (plan.decode_batch), not once per
        request on arrival.
        """
        plan = self.plan_for(batch.key.op)
        t0 = time.perf_counter()
        decoded = plan.decode_batch([r.blob for r in batch.requests])
        z_tilde = plan.restore(decoded.pad_to(batch.padded_size))
        logits = self._cloud_fn(self.params, z_tilde)
        logits = np.asarray(jax.block_until_ready(logits))
        return logits, time.perf_counter() - t0

    def _process_batch(self, batch: MicroBatch, responses: list,
                       telemetry: Telemetry) -> None:
        t_dispatch = max(r.t_arrive for r in batch.requests)
        logits, compute_s = self._run_batch(batch)
        for row, req in enumerate(batch.requests):      # padding rows ignored
            op, stats, tx = req.meta
            responses[req.req_id] = GatewayResponse(
                req_id=req.req_id, logits=logits[row], op=op, stats=stats)
            telemetry.record(RequestRecord(
                req_id=req.req_id, c=op.c, bits=op.bits,
                bits_on_wire=stats.wire_bits,
                wire_latency_s=tx.latency_s,
                queue_wait_s=t_dispatch - req.t_arrive,
                compute_s=compute_s,
                batch_size=len(batch.requests),
                padded_size=batch.padded_size))

    # -- orchestration loop -------------------------------------------------
    def serve(self, imgs, *, submit_times=None) -> tuple[list[GatewayResponse],
                                                         Telemetry]:
        """Serve one request per row of ``imgs`` (N, H, W, 3).

        Responses come back in submission order regardless of channel
        reordering or batching; telemetry holds the per-request records.
        """
        imgs = np.asarray(imgs)
        n = imgs.shape[0]
        if submit_times is None:
            submit_times = [0.0] * n
        # 1. edge side: rate control, encode, transmit — in submit-time order
        # (the simulated link is FIFO by call, so out-of-order calls would
        # charge early requests for wire time the late ones occupied)
        inflight = []
        for i in sorted(range(n), key=lambda k: float(submit_times[k])):
            op, blob, stats, tx = self.encode_request(imgs[i:i + 1],
                                                      float(submit_times[i]))
            inflight.append((i, op, blob, stats, tx))
        # 2. cloud side: micro-batch encoded blobs in arrival order; decode
        # runs batched per bucket inside _run_batch
        inflight.sort(key=lambda item: (item[4].t_arrive, item[0]))
        responses: list[GatewayResponse | None] = [None] * n
        telemetry = Telemetry()
        batcher = MicroBatcher(max_batch=self.max_batch)
        for i, op, blob, stats, tx in inflight:
            req = EncodedRequest(req_id=i, blob=blob, t_arrive=tx.t_arrive,
                                 meta=(op, stats, tx))
            for full in batcher.add(req):
                self._process_batch(full, responses, telemetry)
        for rest in batcher.flush():
            self._process_batch(rest, responses, telemetry)
        assert all(r is not None for r in responses)
        return responses, telemetry


# ---------------------------------------------------------------------------
# Multi-tenant, event-driven serving
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TenantRequest:
    """One request of the multi-tenant workload."""
    tenant: str
    img: object                  # (H, W, 3) or (1, H, W, 3)
    t_submit: float = 0.0


class MultiTenantGateway(ServingGateway):
    """Event-driven serving over N tenants sharing one uplink bit budget.

    Replaces :meth:`ServingGateway.serve`'s strict encode -> batch -> restore
    phases with a virtual-clock event loop where edge submits, uplink drain
    ticks, channel arrivals, batch-window flushes, and cloud-compute
    completions interleave:

        submit  : edge forward + content-keyed rate control + capability
                  negotiation + plan.encode; the encoded job queues at the
                  DRR scheduler
        drain   : the scheduler grants queued jobs against the shared
                  per-tick budget (weighted DRR, starvation-free); granted
                  jobs enter their tenant's own channel
        arrive  : the wire blob goes straight into the micro-batcher —
                  buckets are keyed (operating point, H, W) only, so tenants
                  share buckets and decode/restore compiles stay bounded
                  under heterogeneous traffic (decode itself is deferred to
                  dispatch and runs batched)
        flush   : a partially-filled bucket hits its batch window; with
                  ``adaptive_window=True`` the window follows the bucket's
                  arrival-rate EWMA (burst-aware: bursts flush near-full
                  buckets fast, sparse traffic stops waiting for stragglers
                  that are not coming)
        done    : batched decode + restore + cloud forward finished (the
                  cloud is modeled as a serial executor on the virtual
                  clock; compute durations are measured wall time)

    Per-tenant channels must be unmetered — the *shared* budget lives in the
    scheduler; a per-channel budget would meter the same bits twice.
    Channels are reset at the start of every ``serve_tenants`` call, so a
    repeat of the same workload replays bit-identically.
    """

    def __init__(self, params, baf_bank: dict, *,
                 tenants: "list[TenantSpec] | tuple[TenantSpec, ...]",
                 channel_cfg: ChannelConfig | None = None,
                 channels: dict[str, SimulatedChannel] | None = None,
                 controller: RateController | None = None,
                 default_op: OperatingPoint | None = None,
                 backend: str | None = None, max_batch: int = 8,
                 fused: bool = True,
                 capabilities: Capabilities | None = None,
                 budget_bits_per_tick: int | None = None,
                 tick_s: float = 1.0, quantum_bits: int | None = None,
                 batch_window_s: float | None = 0.02,
                 adaptive_window: bool = False,
                 min_window_s: float = 0.0, seed: int = 0):
        super().__init__(params, baf_bank, channel=None, controller=None,
                         default_op=default_op, backend=backend,
                         max_batch=max_batch, fused=fused,
                         capabilities=capabilities)
        specs = list(tenants)
        if not specs:
            raise ValueError("need at least one tenant")
        self.specs = {t.name: t for t in specs}
        if channels is None:
            cfg = channel_cfg if channel_cfg is not None else ChannelConfig()
            if cfg.budget_bits_per_tick is not None:
                raise ValueError("per-tenant channels must be unmetered; "
                                 "set budget_bits_per_tick on the gateway "
                                 "(shared scheduler budget) instead")
            channels = {t.name: SimulatedChannel(cfg, seed=seed + i)
                        for i, t in enumerate(specs)}
        missing = set(self.specs) - set(channels)
        if missing:
            raise ValueError(f"no channel for tenants {sorted(missing)}")
        metered = [n for n, ch in channels.items()
                   if ch.cfg.budget_bits_per_tick is not None]
        if metered:
            raise ValueError(f"per-tenant channels must be unmetered (the "
                             f"scheduler owns the shared budget; a channel "
                             f"budget would meter the same bits twice): "
                             f"{sorted(metered)}")
        self.channels = channels
        self.mt_controller = controller
        self._sched_args = dict(budget_bits_per_tick=budget_bits_per_tick,
                                tick_s=tick_s, quantum_bits=quantum_bits)
        self.batch_window_s = batch_window_s
        self.adaptive_window = adaptive_window
        self.min_window_s = min_window_s

    # -- edge side ----------------------------------------------------------
    def _pick_tenant_op(self, spec: TenantSpec, z, budget: float):
        ctrl = self.mt_controller
        if ctrl is None:
            return self.default_op
        if isinstance(ctrl, ContentKeyedController):
            z_np = np.asarray(z)        # one device->host copy, not one per C
            stats = {c: activation_stats(z_np, sel)
                     for c, (_, sel) in self.baf_bank.items()}
            rd = ctrl.select_for(budget, stats, spec.quality_floor_db)
        else:
            rd = ctrl.select(budget)
        return self._fit_op(rd.op)

    # -- orchestration ------------------------------------------------------
    def serve_tenants(self, workload: "list[TenantRequest]") -> tuple[
            dict[str, list[GatewayResponse]], Telemetry]:
        """Run the event loop over the whole workload; returns per-tenant
        responses (in per-tenant submission order) and merged telemetry."""
        for w in workload:
            if w.tenant not in self.specs:
                raise KeyError(f"unknown tenant {w.tenant!r}")
        for ch in self.channels.values():
            ch.reset()
        sched = DeficitRoundRobinScheduler(self.specs.values(),
                                           **self._sched_args)
        self.last_scheduler = sched          # post-run introspection (tests,
        telemetry = Telemetry()              # fairness/budget audits)
        batcher = MicroBatcher(max_batch=self.max_batch,
                               window_s=self.batch_window_s,
                               adaptive=self.adaptive_window,
                               min_window_s=self.min_window_s)
        responses: dict[str, dict[int, GatewayResponse]] = {
            n: {} for n in self.specs}
        counts = {n: 0 for n in self.specs}

        events: list = []
        seq = itertools.count()

        def push(t: float, kind: str, payload) -> None:
            heapq.heappush(events, (float(t), next(seq), kind, payload))

        # dedupe only drains that have not run yet: a submit landing at a
        # timestamp whose drain already executed must get a fresh one, or
        # its job would strand in the scheduler queue
        drain_times: set[float] = set()

        def schedule_drain(t: float) -> None:
            t = float(t)
            if t not in drain_times:
                drain_times.add(t)
                push(t, "drain", None)

        # generation -> earliest flush time scheduled so far. Adaptive
        # windows can move a group's deadline *earlier* as arrivals sharpen
        # the rate estimate; re-push then (stale later events no-op via gen)
        scheduled_flushes: dict[int, float] = {}
        cloud_busy = 0.0

        def dispatch(batch: MicroBatch, t_ready: float) -> None:
            nonlocal cloud_busy
            start = max(t_ready, cloud_busy)
            logits, compute_s = self._run_batch(batch)
            cloud_busy = start + compute_s
            push(cloud_busy, "done", (batch, logits, start, compute_s))

        for w in workload:
            push(w.t_submit, "submit", w)

        while events:
            t, _, kind, payload = heapq.heappop(events)

            if kind == "submit":
                w = payload
                spec = self.specs[w.tenant]
                local_id = counts[w.tenant]
                counts[w.tenant] += 1
                img = np.asarray(w.img)
                if img.ndim == 3:
                    img = img[None]
                z = self._edge_fn(self.params, img)
                op = self._pick_tenant_op(spec, z, sched.budget_remaining(t))
                blob = self.plan_for(op).encode(z)
                # the scheduler meters the job at its true container length,
                # so DRR shares reflect real bits on the wire
                sched.enqueue(UplinkJob(
                    tenant=w.tenant, req_id=local_id, bits=8 * blob.nbytes,
                    t_enqueue=t, payload=(op, blob, blob.stats)))
                schedule_drain(t)

            elif kind == "drain":
                drain_times.discard(t)
                for job in sched.drain(t):
                    blob = job.payload[1]
                    tx = self.channels[job.tenant].transmit_bytes(blob.data, t)
                    push(tx.t_arrive, "arrive", (job, tx))
                if sched.pending():
                    schedule_drain(sched.next_tick_time(t))

            elif kind == "arrive":
                job, tx = payload
                op, blob, stats = job.payload
                req = EncodedRequest(
                    req_id=job.req_id, blob=blob, t_arrive=t,
                    meta=(op, stats, tx, job), tenant=job.tenant)
                fulls = batcher.add(req, now=t)
                for full in fulls:
                    dispatch(full, t)
                if not fulls:
                    deadline = batcher.deadline(req.key)
                    if deadline is not None:
                        due, gen = deadline
                        if due < scheduled_flushes.get(gen, float("inf")):
                            scheduled_flushes[gen] = due
                            push(due, "flush", (req.key, gen))

            elif kind == "flush":
                key, gen = payload
                current = batcher.deadline(key)
                if (current is not None and current[1] == gen
                        and current[0] > t + 1e-12):
                    # the adaptive estimate drifted *later* (traffic
                    # decelerated after this event was scheduled): chase the
                    # new due time instead of flushing undersized. Each
                    # re-push is strictly later and the deadline is capped
                    # at t_first + window_s, so the chase terminates.
                    scheduled_flushes[gen] = current[0]
                    push(current[0], "flush", (key, gen))
                else:
                    batch = batcher.take(key, gen)
                    if batch is not None:
                        scheduled_flushes.pop(gen, None)
                        dispatch(batch, t)

            elif kind == "done":
                batch, logits, start, compute_s = payload
                for row, req in enumerate(batch.requests):
                    op, stats, tx, job = req.meta
                    responses[req.tenant][req.req_id] = GatewayResponse(
                        req_id=req.req_id, logits=logits[row], op=op,
                        stats=stats)
                    telemetry.record(RequestRecord(
                        req_id=req.req_id, c=op.c, bits=op.bits,
                        bits_on_wire=stats.wire_bits,
                        wire_latency_s=tx.t_arrive - tx.t_submit,
                        queue_wait_s=start - req.t_arrive,
                        compute_s=compute_s,
                        batch_size=len(batch.requests),
                        padded_size=batch.padded_size,
                        tenant=req.tenant,
                        sched_wait_s=tx.t_submit - job.t_enqueue))

            # events may drain while buckets still hold requests (no batch
            # window): sweep the leftovers through the same dispatch path
            if not events:
                for rest in batcher.flush():
                    dispatch(rest, max(r.t_arrive for r in rest.requests))

        out = {}
        for name, got in responses.items():
            assert len(got) == counts[name], (
                f"tenant {name}: {len(got)}/{counts[name]} responses")
            out[name] = [got[i] for i in range(counts[name])]
        return out, telemetry
