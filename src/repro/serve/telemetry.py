"""Per-request and aggregate serving telemetry for the gateway.

Wire-side numbers (bits on wire, channel latency, queue wait) come from the
simulated channel's virtual clock; compute-side numbers (restore + cloud
forward) are measured wall clock. ``total_latency_s`` adds the two — the
simulated transport and the real compute — which is the quantity the
benchmark reports percentiles over.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class RequestRecord:
    req_id: int
    c: int
    bits: int
    bits_on_wire: int
    wire_latency_s: float       # submit -> arrival at the cloud (simulated)
    queue_wait_s: float         # arrival -> micro-batch dispatch (simulated)
    compute_s: float            # restore + cloud forward (measured, per batch)
    batch_size: int             # true (unpadded) size of the micro-batch
    padded_size: int
    tenant: str = ""            # owning tenant ("" = single-tenant serving)
    sched_wait_s: float = 0.0   # encode done -> uplink grant (simulated)

    @property
    def total_latency_s(self) -> float:
        return (self.sched_wait_s + self.wire_latency_s + self.queue_wait_s
                + self.compute_s)


def jain_fairness(values) -> float:
    """Jain's fairness index over per-tenant allocations: 1 = perfectly
    fair, 1/n = one tenant holds everything."""
    v = np.asarray(list(values), np.float64)
    if v.size == 0 or float(np.sum(v)) == 0.0:
        return 1.0
    return float(np.sum(v) ** 2 / (v.size * np.sum(v * v)))


class Telemetry:
    """Accumulates request records and reports aggregate percentiles."""

    def __init__(self):
        self.records: list[RequestRecord] = []

    def record(self, rec: RequestRecord) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    def percentile(self, field_name: str, p: float,
                   tenant: str | None = None) -> float:
        vals = [getattr(r, field_name) for r in self.records
                if tenant is None or r.tenant == tenant]
        if not vals:
            raise ValueError("no records")
        return float(np.percentile(np.asarray(vals, np.float64), p))

    def tenants(self) -> list[str]:
        return sorted({r.tenant for r in self.records})

    def per_tenant(self) -> dict[str, dict]:
        """{tenant: summary} over each tenant's own records."""
        out = {}
        for t in self.tenants():
            recs = [r for r in self.records if r.tenant == t]
            out[t] = {
                "count": len(recs),
                "bits_on_wire": int(sum(r.bits_on_wire for r in recs)),
                "p50_latency_s": float(np.percentile(
                    [r.total_latency_s for r in recs], 50)),
                "p99_latency_s": float(np.percentile(
                    [r.total_latency_s for r in recs], 99)),
                "mean_sched_wait_s": float(np.mean(
                    [r.sched_wait_s for r in recs])),
                "operating_points": sorted({(r.c, r.bits) for r in recs}),
            }
        return out

    def fairness(self, field_name: str = "bits_on_wire") -> float:
        """Jain's index over per-tenant sums of ``field_name`` (1 = fair)."""
        per = {}
        for r in self.records:
            per[r.tenant] = per.get(r.tenant, 0.0) + getattr(r, field_name)
        return jain_fairness(per.values())

    def summary(self, *, wall_s: float | None = None) -> dict:
        """Aggregate view; pass the measured wall time for requests/sec."""
        if not self.records:
            return {"count": 0}
        out = {
            "count": len(self.records),
            "mean_bits_on_wire": float(np.mean([r.bits_on_wire
                                                for r in self.records])),
            "mean_batch_size": float(np.mean([r.batch_size
                                              for r in self.records])),
            "p50_latency_s": self.percentile("total_latency_s", 50),
            "p99_latency_s": self.percentile("total_latency_s", 99),
            "p50_compute_s": self.percentile("compute_s", 50),
            "p99_compute_s": self.percentile("compute_s", 99),
            "operating_points": sorted({(r.c, r.bits) for r in self.records}),
        }
        if wall_s is not None and wall_s > 0:
            out["requests_per_s"] = len(self.records) / wall_s
        tenants = self.tenants()
        if len(tenants) > 1 or (tenants and tenants != [""]):
            out["tenants"] = tenants
            out["fairness_bits"] = self.fairness("bits_on_wire")
        return out

    def format_summary(self, *, wall_s: float | None = None) -> str:
        s = self.summary(wall_s=wall_s)
        if not s["count"]:
            return "no requests"
        lines = [f"requests           : {s['count']}"]
        if "requests_per_s" in s:
            lines.append(f"requests/sec       : {s['requests_per_s']:.1f}")
        lines += [
            f"mean bits on wire  : {s['mean_bits_on_wire']:.0f}",
            f"mean batch size    : {s['mean_batch_size']:.2f}",
            f"p50 / p99 latency  : {s['p50_latency_s']*1e3:.2f} / "
            f"{s['p99_latency_s']*1e3:.2f} ms",
            f"p50 / p99 compute  : {s['p50_compute_s']*1e3:.2f} / "
            f"{s['p99_compute_s']*1e3:.2f} ms",
            f"operating points   : {s['operating_points']}",
        ]
        if "fairness_bits" in s:
            lines.append(f"fairness (bits)    : {s['fairness_bits']:.3f}")
            for t, ts in self.per_tenant().items():
                lines.append(
                    f"  tenant {t or '<default>':<10}: n={ts['count']:<4} "
                    f"p50/p99 {ts['p50_latency_s']*1e3:.2f}/"
                    f"{ts['p99_latency_s']*1e3:.2f} ms  "
                    f"ops {ts['operating_points']}")
        return "\n".join(lines)
