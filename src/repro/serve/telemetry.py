"""Per-request and aggregate serving telemetry for the gateway.

Wire-side numbers (bits on wire, channel latency, queue wait) come from the
simulated channel's virtual clock; compute-side numbers (restore + cloud
forward) follow the executor's virtual-clock cost model (identical to the
measured wall clock under the default ``MeasuredCost``). ``total_latency_s``
adds the two, which is the quantity the benchmark reports percentiles over.

Shed requests live in their own series (:class:`ShedRecord`, recorded via
:meth:`Telemetry.record_shed`): admission rejections never appear among the
served records, so latency p50/p99 measure *served* requests only — an
overloaded gateway shedding half its traffic cannot fake a good p99 (or be
charged zero-latency phantoms). ``summary()`` reports the shed series
alongside, as counts and a shed rate.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class RequestRecord:
    req_id: int
    c: int
    bits: int
    bits_on_wire: int
    wire_latency_s: float       # submit -> arrival at the cloud (simulated)
    queue_wait_s: float         # arrival -> executor service start (virtual)
    compute_s: float            # restore + cloud forward (executor cost model)
    batch_size: int             # true (unpadded) size of the micro-batch
    padded_size: int
    tenant: str = ""            # owning tenant ("" = single-tenant serving)
    sched_wait_s: float = 0.0   # encode done -> uplink grant (simulated)
    exec_queue: int = 0         # executor queue that served the batch

    @property
    def total_latency_s(self) -> float:
        return (self.sched_wait_s + self.wire_latency_s + self.queue_wait_s
                + self.compute_s)


@dataclass(frozen=True)
class ShedRecord:
    """One admission rejection — its own series, never a latency record."""
    req_id: int                 # per-tenant sequence number
    tenant: str
    t_submit: float
    reason: str                 # admission policy's explicit justification
    priority: int = 0


def jain_fairness(values) -> float:
    """Jain's fairness index over per-tenant allocations: 1 = perfectly
    fair, 1/n = one tenant holds everything."""
    v = np.asarray(list(values), np.float64)
    if v.size == 0 or float(np.sum(v)) == 0.0:
        return 1.0
    return float(np.sum(v) ** 2 / (v.size * np.sum(v * v)))


class Telemetry:
    """Accumulates request records and reports aggregate percentiles.

    Served requests (``records``) and admission rejections (``shed``) are
    separate series; ``__len__``/``percentile`` cover served only."""

    def __init__(self):
        self.records: list[RequestRecord] = []
        self.shed: list[ShedRecord] = []

    def record(self, rec: RequestRecord) -> None:
        self.records.append(rec)

    def record_shed(self, rec: ShedRecord) -> None:
        self.shed.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    def shed_by_tenant(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for s in self.shed:
            out[s.tenant] = out.get(s.tenant, 0) + 1
        return out

    def shed_rate(self) -> float:
        """Fraction of all submissions that were shed (0.0 when none)."""
        total = len(self.records) + len(self.shed)
        return len(self.shed) / total if total else 0.0

    def percentile(self, field_name: str, p: float,
                   tenant: str | None = None) -> float:
        vals = [getattr(r, field_name) for r in self.records
                if tenant is None or r.tenant == tenant]
        if not vals:
            raise ValueError("no records")
        return float(np.percentile(np.asarray(vals, np.float64), p))

    def tenants(self) -> list[str]:
        return sorted({r.tenant for r in self.records})

    def per_tenant(self) -> dict[str, dict]:
        """{tenant: summary} over each tenant's own records.

        Tenants with served traffic report latency percentiles over their
        *served* requests only; their shed count rides alongside. A tenant
        whose every request was shed still appears — shedding must never
        erase a tenant from the report — with the same row schema (latency
        fields None, counts 0), so consumers never hit a KeyError; guard on
        ``count`` before using the latency numbers."""
        shed = self.shed_by_tenant()
        out = {}
        for t in sorted(set(self.tenants()) | set(shed)):
            recs = [r for r in self.records if r.tenant == t]
            lat = [r.total_latency_s for r in recs]
            out[t] = {
                "count": len(recs),
                "shed": shed.get(t, 0),
                "bits_on_wire": int(sum(r.bits_on_wire for r in recs)),
                "p50_latency_s": (float(np.percentile(lat, 50))
                                  if recs else None),
                "p99_latency_s": (float(np.percentile(lat, 99))
                                  if recs else None),
                "mean_sched_wait_s": (float(np.mean(
                    [r.sched_wait_s for r in recs])) if recs else None),
                "operating_points": sorted({(r.c, r.bits) for r in recs}),
            }
        return out

    def fairness(self, field_name: str = "bits_on_wire") -> float:
        """Jain's index over per-tenant sums of ``field_name`` (1 = fair)."""
        per = {}
        for r in self.records:
            per[r.tenant] = per.get(r.tenant, 0.0) + getattr(r, field_name)
        return jain_fairness(per.values())

    def summary(self, *, wall_s: float | None = None) -> dict:
        """Aggregate view; pass the measured wall time for requests/sec.

        Latency percentiles cover served requests only; the shed series is
        summarized separately (``shed``/``shed_rate``)."""
        if not self.records:
            out = {"count": 0}
            if self.shed:
                out.update({"shed": len(self.shed), "shed_rate": 1.0,
                            "shed_by_tenant": self.shed_by_tenant()})
            return out
        out = {
            "count": len(self.records),
            "mean_bits_on_wire": float(np.mean([r.bits_on_wire
                                                for r in self.records])),
            "mean_batch_size": float(np.mean([r.batch_size
                                              for r in self.records])),
            "p50_latency_s": self.percentile("total_latency_s", 50),
            "p99_latency_s": self.percentile("total_latency_s", 99),
            "p50_compute_s": self.percentile("compute_s", 50),
            "p99_compute_s": self.percentile("compute_s", 99),
            "operating_points": sorted({(r.c, r.bits) for r in self.records}),
        }
        if self.shed:
            out["shed"] = len(self.shed)
            out["shed_rate"] = self.shed_rate()
            out["shed_by_tenant"] = self.shed_by_tenant()
        if wall_s is not None and wall_s > 0:
            out["requests_per_s"] = len(self.records) / wall_s
        tenants = self.tenants()
        if len(tenants) > 1 or (tenants and tenants != [""]):
            out["tenants"] = tenants
            out["fairness_bits"] = self.fairness("bits_on_wire")
        return out

    def format_summary(self, *, wall_s: float | None = None) -> str:
        s = self.summary(wall_s=wall_s)
        if not s["count"]:
            return ("no requests" if not self.shed
                    else f"no requests served ({len(self.shed)} shed)")
        lines = [f"requests           : {s['count']}"]
        if "shed" in s:
            lines.append(f"shed (admission)   : {s['shed']} "
                         f"({100 * s['shed_rate']:.0f}% of offered)")
        if "requests_per_s" in s:
            lines.append(f"requests/sec       : {s['requests_per_s']:.1f}")
        lines += [
            f"mean bits on wire  : {s['mean_bits_on_wire']:.0f}",
            f"mean batch size    : {s['mean_batch_size']:.2f}",
            f"p50 / p99 latency  : {s['p50_latency_s']*1e3:.2f} / "
            f"{s['p99_latency_s']*1e3:.2f} ms",
            f"p50 / p99 compute  : {s['p50_compute_s']*1e3:.2f} / "
            f"{s['p99_compute_s']*1e3:.2f} ms",
            f"operating points   : {s['operating_points']}",
        ]
        if "fairness_bits" in s:
            lines.append(f"fairness (bits)    : {s['fairness_bits']:.3f}")
            for t, ts in self.per_tenant().items():
                shed = f" shed={ts['shed']}" if ts["shed"] else ""
                if ts["count"]:
                    lines.append(
                        f"  tenant {t or '<default>':<10}: "
                        f"n={ts['count']:<4} "
                        f"p50/p99 {ts['p50_latency_s']*1e3:.2f}/"
                        f"{ts['p99_latency_s']*1e3:.2f} ms  "
                        f"ops {ts['operating_points']}{shed}")
                else:
                    lines.append(f"  tenant {t or '<default>':<10}: "
                                 f"n=0   {shed}")
        return "\n".join(lines)
