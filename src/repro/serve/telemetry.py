"""Per-request and aggregate serving telemetry for the gateway.

Wire-side numbers (bits on wire, channel latency, queue wait) come from the
simulated channel's virtual clock; compute-side numbers (restore + cloud
forward) follow the executor's virtual-clock cost model (identical to the
measured wall clock under the default ``MeasuredCost``). ``total_latency_s``
adds the two, which is the quantity the benchmark reports percentiles over.

Shed requests live in their own series (:class:`ShedRecord`, recorded via
:meth:`Telemetry.record_shed`): admission rejections never appear among the
served records, so latency p50/p99 measure *served* requests only — an
overloaded gateway shedding half its traffic cannot fake a good p99 (or be
charged zero-latency phantoms). ``summary()`` reports the shed series
alongside, as counts and a shed rate.

:class:`Telemetry` is built on :class:`repro.obs.metrics.MetricsRegistry`:
every record also lands in counters and mergeable log-bucket histograms
(``gateway_requests_total``, ``gateway_request_latency_seconds{tenant=...}``,
...), dumpable as Prometheus text via ``telemetry.metrics``. By default the
full record list is kept and percentiles stay the exact numpy computation;
pass ``max_records=N`` to bound memory on long runs — the list caps at N
while counts/sums/percentiles keep covering *every* record through the
registry aggregates (percentiles then carry the histogram's bucket
tolerance, ~9% relative at the default growth; see repro.obs.metrics).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.obs.metrics import LogHistogram, MetricsRegistry


@dataclass(frozen=True)
class RequestRecord:
    req_id: int
    c: int
    bits: int
    bits_on_wire: int
    wire_latency_s: float       # submit -> arrival at the cloud (simulated)
    queue_wait_s: float         # arrival -> executor service start (virtual)
    compute_s: float            # restore + cloud forward (executor cost model)
    batch_size: int             # true (unpadded) size of the micro-batch
    padded_size: int
    tenant: str = ""            # owning tenant ("" = single-tenant serving)
    sched_wait_s: float = 0.0   # encode done -> uplink grant (simulated)
    exec_queue: int = 0         # executor queue that served the batch

    @property
    def total_latency_s(self) -> float:
        return (self.sched_wait_s + self.wire_latency_s + self.queue_wait_s
                + self.compute_s)


@dataclass(frozen=True)
class ShedRecord:
    """One admission rejection — its own series, never a latency record."""
    req_id: int                 # per-tenant sequence number
    tenant: str
    t_submit: float
    reason: str                 # admission policy's explicit justification
    priority: int = 0


@dataclass(frozen=True)
class DegradeRecord:
    """One QoS ladder step-down — degrade-before-shed's distinct outcome.

    A streaming session under pressure steps to a coarser operating point
    (or sparser cadence) *instead of* being shed; the request is still
    served, so it also appears among the latency records. This series
    meters how often and why quality was traded for admission, separate
    from both the served and the shed series.
    """
    tenant: str
    t: float
    frame_seq: int
    from_level: int             # QoS ladder index before the step (0 = best)
    to_level: int               # ladder index after
    reason: str                 # the admission rejection that triggered it


def jain_fairness(values) -> float:
    """Jain's fairness index over per-tenant allocations: 1 = perfectly
    fair, 1/n = one tenant holds everything."""
    v = np.asarray(list(values), np.float64)
    if v.size == 0 or float(np.sum(v)) == 0.0:
        return 1.0
    return float(np.sum(v) ** 2 / (v.size * np.sum(v * v)))


# histogram series a truncated Telemetry can still answer percentiles from
_HIST_FIELDS = {
    "total_latency_s": "gateway_request_latency_seconds",
    "compute_s": "gateway_compute_seconds",
    "queue_wait_s": "gateway_queue_wait_seconds",
}


class _TenantAgg:
    """Running per-tenant aggregates + cached registry series handles (one
    key construction per tenant, not per record)."""
    __slots__ = ("count", "bits", "sched_sum", "batch_sum", "ops",
                 "c_req", "c_bits", "hists")

    def __init__(self, metrics: MetricsRegistry, tenant: str):
        self.count = 0
        self.bits = 0
        self.sched_sum = 0.0
        self.batch_sum = 0.0
        self.ops: set[tuple[int, int]] = set()
        self.c_req = metrics.counter("gateway_requests_total", tenant=tenant)
        self.c_bits = metrics.counter("gateway_wire_bits_total",
                                      tenant=tenant)
        self.hists = {f: metrics.histogram(name, tenant=tenant)
                      for f, name in _HIST_FIELDS.items()}


class Telemetry:
    """Accumulates request records and reports aggregate percentiles.

    Served requests (``records``) and admission rejections (``shed``) are
    separate series; ``__len__``/``percentile`` cover served only.

    Parameters
    ----------
    registry : share an existing :class:`MetricsRegistry` (the gateway
        passes its own so request series land beside executor/scheduler
        gauges); None = a private registry
    max_records : cap on the stored record list (None = keep every record,
        the exact-percentile default). Aggregates always cover all records.
    """

    def __init__(self, *, registry: MetricsRegistry | None = None,
                 max_records: int | None = None):
        if max_records is not None and max_records < 1:
            raise ValueError(f"max_records must be >= 1, got {max_records}")
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.max_records = max_records
        self.records: list[RequestRecord] = []
        self.shed: list[ShedRecord] = []
        self.degraded: list[DegradeRecord] = []
        self._n = 0
        self._tenant: dict[str, _TenantAgg] = {}

    @property
    def truncated(self) -> bool:
        """True when the record list stopped growing at ``max_records``
        (aggregate counts/sums/histograms still cover everything)."""
        return self._n > len(self.records)

    def _agg(self, tenant: str) -> _TenantAgg:
        agg = self._tenant.get(tenant)
        if agg is None:
            agg = _TenantAgg(self.metrics, tenant)
            self._tenant[tenant] = agg
        return agg

    def record(self, rec: RequestRecord) -> None:
        if self.max_records is None or len(self.records) < self.max_records:
            self.records.append(rec)
        self._n += 1
        agg = self._agg(rec.tenant)
        agg.count += 1
        agg.bits += rec.bits_on_wire
        agg.sched_sum += rec.sched_wait_s
        agg.batch_sum += rec.batch_size
        agg.ops.add((rec.c, rec.bits))
        agg.c_req.inc()
        agg.c_bits.inc(rec.bits_on_wire)
        for field_name, hist in agg.hists.items():
            # virtual-clock records can carry tiny negative waits (a ticket
            # may start a hair before the packet's nominal arrival in the
            # adaptive-window path); latency histograms clamp at zero
            hist.observe(max(0.0, getattr(rec, field_name)))

    def record_shed(self, rec: ShedRecord) -> None:
        self.shed.append(rec)
        self.metrics.counter("gateway_shed_total", tenant=rec.tenant).inc()

    def record_degrade(self, rec: DegradeRecord) -> None:
        self.degraded.append(rec)
        self.metrics.counter("gateway_degrade_total", tenant=rec.tenant).inc()

    def degrade_by_tenant(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for d in self.degraded:
            out[d.tenant] = out.get(d.tenant, 0) + 1
        return out

    def __len__(self) -> int:
        return self._n            # true served count, even when truncated

    def shed_by_tenant(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for s in self.shed:
            out[s.tenant] = out.get(s.tenant, 0) + 1
        return out

    def shed_rate(self) -> float:
        """Fraction of all submissions that were shed (0.0 when none)."""
        total = self._n + len(self.shed)
        return len(self.shed) / total if total else 0.0

    # -- percentiles ---------------------------------------------------------
    def _hist_percentile(self, field_name: str, p: float,
                         tenant: str | None) -> float:
        name = _HIST_FIELDS.get(field_name)
        if name is None:
            raise ValueError(
                f"record history truncated at max_records="
                f"{self.max_records}; histogram percentiles cover only "
                f"{sorted(_HIST_FIELDS)}, not {field_name!r}")
        if tenant is None:
            hists = [a.hists[field_name] for a in self._tenant.values()]
            h = hists[0] if len(hists) == 1 else LogHistogram.merged(hists)
        else:
            agg = self._tenant.get(tenant)
            h = agg.hists[field_name] if agg is not None else None
        if h is None or h.count == 0:
            raise ValueError(self._no_records_msg(tenant))
        return h.percentile(p)

    def _no_records_msg(self, tenant: str | None) -> str:
        scope = f"tenant {tenant!r}" if tenant is not None else "telemetry"
        msg = f"no served records in {scope}"
        if self.shed:
            msg += (f" ({len(self.shed)} shed by admission — the shed "
                    f"series has no latency percentiles)")
        return msg

    def percentile(self, field_name: str, p: float,
                   tenant: str | None = None) -> float:
        """Percentile of ``field_name`` over served records.

        Exact (numpy, linear interpolation) while the full record list is
        retained; within histogram bucket tolerance once truncated. A single
        record reports itself at every percentile; no served records raises
        ValueError naming the shed count instead of returning NaN."""
        if self.truncated:
            return self._hist_percentile(field_name, p, tenant)
        vals = [getattr(r, field_name) for r in self.records
                if tenant is None or r.tenant == tenant]
        if not vals:
            raise ValueError(self._no_records_msg(tenant))
        if len(vals) == 1:
            return float(vals[0])
        return float(np.percentile(np.asarray(vals, np.float64), p))

    def tenants(self) -> list[str]:
        return sorted(self._tenant)

    def per_tenant(self) -> dict[str, dict]:
        """{tenant: summary} over each tenant's own records.

        Tenants with served traffic report latency percentiles over their
        *served* requests only; their shed count rides alongside. A tenant
        whose every request was shed still appears — shedding must never
        erase a tenant from the report — with the same row schema (latency
        fields None, counts 0), so consumers never hit a KeyError; guard on
        ``count`` before using the latency numbers."""
        shed = self.shed_by_tenant()
        out = {}
        for t in sorted(set(self.tenants()) | set(shed)):
            agg = self._tenant.get(t)
            count = agg.count if agg is not None else 0
            row = {
                "count": count,
                "shed": shed.get(t, 0),
                "bits_on_wire": int(agg.bits) if agg is not None else 0,
                "p50_latency_s": None,
                "p99_latency_s": None,
                "mean_sched_wait_s": None,
                "operating_points": sorted(agg.ops) if agg is not None
                else [],
            }
            if count:
                row["p50_latency_s"] = self.percentile(
                    "total_latency_s", 50, tenant=t)
                row["p99_latency_s"] = self.percentile(
                    "total_latency_s", 99, tenant=t)
                row["mean_sched_wait_s"] = agg.sched_sum / count
            out[t] = row
        return out

    def fairness(self, field_name: str = "bits_on_wire") -> float:
        """Jain's index over per-tenant sums of ``field_name`` (1 = fair)."""
        if field_name == "bits_on_wire":
            # aggregate path: exact regardless of record truncation
            return jain_fairness(a.bits for a in self._tenant.values())
        if self.truncated:
            raise ValueError(
                f"record history truncated at max_records="
                f"{self.max_records}; fairness over {field_name!r} needs "
                f"the full record list (bits_on_wire stays available)")
        per: dict[str, float] = {}
        for r in self.records:
            per[r.tenant] = per.get(r.tenant, 0.0) + getattr(r, field_name)
        return jain_fairness(per.values())

    # -- aggregate views -----------------------------------------------------
    def summary(self, *, wall_s: float | None = None) -> dict:
        """Aggregate view; pass the measured wall time for requests/sec.

        Latency percentiles cover served requests only; the shed series is
        summarized separately (``shed``/``shed_rate``). An empty served
        series with a non-empty shed series reports counts (not a crash and
        not phantom zero latencies)."""
        if self._n == 0:
            out = {"count": 0}
            if self.shed:
                out.update({"shed": len(self.shed), "shed_rate": 1.0,
                            "shed_by_tenant": self.shed_by_tenant()})
            if self.degraded:
                out.update({"degraded": len(self.degraded),
                            "degrade_by_tenant": self.degrade_by_tenant()})
            return out
        total_bits = sum(a.bits for a in self._tenant.values())
        total_batch = sum(a.batch_sum for a in self._tenant.values())
        ops = set()
        for a in self._tenant.values():
            ops |= a.ops
        out = {
            "count": self._n,
            "mean_bits_on_wire": total_bits / self._n,
            "mean_batch_size": total_batch / self._n,
            "p50_latency_s": self.percentile("total_latency_s", 50),
            "p99_latency_s": self.percentile("total_latency_s", 99),
            "p50_compute_s": self.percentile("compute_s", 50),
            "p99_compute_s": self.percentile("compute_s", 99),
            "operating_points": sorted(ops),
        }
        if self.shed:
            out["shed"] = len(self.shed)
            out["shed_rate"] = self.shed_rate()
            out["shed_by_tenant"] = self.shed_by_tenant()
        if self.degraded:
            out["degraded"] = len(self.degraded)
            out["degrade_by_tenant"] = self.degrade_by_tenant()
        if wall_s is not None and wall_s > 0:
            out["requests_per_s"] = self._n / wall_s
        tenants = self.tenants()
        if len(tenants) > 1 or (tenants and tenants != [""]):
            out["tenants"] = tenants
            out["fairness_bits"] = self.fairness("bits_on_wire")
        return out

    def format_summary(self, *, wall_s: float | None = None) -> str:
        s = self.summary(wall_s=wall_s)
        if not s["count"]:
            return ("no requests" if not self.shed
                    else f"no requests served ({len(self.shed)} shed)")
        lines = [f"requests           : {s['count']}"]
        if "shed" in s:
            lines.append(f"shed (admission)   : {s['shed']} "
                         f"({100 * s['shed_rate']:.0f}% of offered)")
        if "requests_per_s" in s:
            lines.append(f"requests/sec       : {s['requests_per_s']:.1f}")
        lines += [
            f"mean bits on wire  : {s['mean_bits_on_wire']:.0f}",
            f"mean batch size    : {s['mean_batch_size']:.2f}",
            f"p50 / p99 latency  : {s['p50_latency_s']*1e3:.2f} / "
            f"{s['p99_latency_s']*1e3:.2f} ms",
            f"p50 / p99 compute  : {s['p50_compute_s']*1e3:.2f} / "
            f"{s['p99_compute_s']*1e3:.2f} ms",
            f"operating points   : {s['operating_points']}",
        ]
        if "fairness_bits" in s:
            lines.append(f"fairness (bits)    : {s['fairness_bits']:.3f}")
            for t, ts in self.per_tenant().items():
                shed = f" shed={ts['shed']}" if ts["shed"] else ""
                if ts["count"]:
                    lines.append(
                        f"  tenant {t or '<default>':<10}: "
                        f"n={ts['count']:<4} "
                        f"p50/p99 {ts['p50_latency_s']*1e3:.2f}/"
                        f"{ts['p99_latency_s']*1e3:.2f} ms  "
                        f"ops {ts['operating_points']}{shed}")
                else:
                    lines.append(f"  tenant {t or '<default>':<10}: "
                                 f"n=0   {shed}")
        return "\n".join(lines)
