"""Per-request and aggregate serving telemetry for the gateway.

Wire-side numbers (bits on wire, channel latency, queue wait) come from the
simulated channel's virtual clock; compute-side numbers (restore + cloud
forward) are measured wall clock. ``total_latency_s`` adds the two — the
simulated transport and the real compute — which is the quantity the
benchmark reports percentiles over.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class RequestRecord:
    req_id: int
    c: int
    bits: int
    bits_on_wire: int
    wire_latency_s: float       # submit -> arrival at the cloud (simulated)
    queue_wait_s: float         # arrival -> micro-batch dispatch (simulated)
    compute_s: float            # restore + cloud forward (measured, per batch)
    batch_size: int             # true (unpadded) size of the micro-batch
    padded_size: int

    @property
    def total_latency_s(self) -> float:
        return self.wire_latency_s + self.queue_wait_s + self.compute_s


class Telemetry:
    """Accumulates request records and reports aggregate percentiles."""

    def __init__(self):
        self.records: list[RequestRecord] = []

    def record(self, rec: RequestRecord) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    def percentile(self, field_name: str, p: float) -> float:
        vals = [getattr(r, field_name) for r in self.records]
        if not vals:
            raise ValueError("no records")
        return float(np.percentile(np.asarray(vals, np.float64), p))

    def summary(self, *, wall_s: float | None = None) -> dict:
        """Aggregate view; pass the measured wall time for requests/sec."""
        if not self.records:
            return {"count": 0}
        out = {
            "count": len(self.records),
            "mean_bits_on_wire": float(np.mean([r.bits_on_wire
                                                for r in self.records])),
            "mean_batch_size": float(np.mean([r.batch_size
                                              for r in self.records])),
            "p50_latency_s": self.percentile("total_latency_s", 50),
            "p99_latency_s": self.percentile("total_latency_s", 99),
            "p50_compute_s": self.percentile("compute_s", 50),
            "p99_compute_s": self.percentile("compute_s", 99),
            "operating_points": sorted({(r.c, r.bits) for r in self.records}),
        }
        if wall_s is not None and wall_s > 0:
            out["requests_per_s"] = len(self.records) / wall_s
        return out

    def format_summary(self, *, wall_s: float | None = None) -> str:
        s = self.summary(wall_s=wall_s)
        if not s["count"]:
            return "no requests"
        lines = [f"requests           : {s['count']}"]
        if "requests_per_s" in s:
            lines.append(f"requests/sec       : {s['requests_per_s']:.1f}")
        lines += [
            f"mean bits on wire  : {s['mean_bits_on_wire']:.0f}",
            f"mean batch size    : {s['mean_batch_size']:.2f}",
            f"p50 / p99 latency  : {s['p50_latency_s']*1e3:.2f} / "
            f"{s['p99_latency_s']*1e3:.2f} ms",
            f"p50 / p99 compute  : {s['p50_compute_s']*1e3:.2f} / "
            f"{s['p99_compute_s']*1e3:.2f} ms",
            f"operating points   : {s['operating_points']}",
        ]
        return "\n".join(lines)
