"""Adaptive rate control for split inference — pick ``(C, bits)`` per request.

The paper sweeps C (transmitted channels) and n (quantizer bits) offline and
reports the accuracy/bits trade-off; deployment needs the inverse mapping:
given the channel's current bit budget and a quality floor, which operating
point do we run *this* request at?  Following the bit-allocation line of work
(Alvar & Bajić 2020; Choi & Bajić 2018) we build an offline rate–distortion
table by sweeping the existing fidelity metrics, then do a table lookup per
request:

  * ``cheapest_meeting_floor`` — the paper-style planner: minimum wire bits
    subject to PSNR >= floor (no channel in the loop),
  * ``select(bit_budget)``     — the channel-adaptive policy: among points
    that fit the budget, prefer those meeting the quality floor and take the
    **highest-PSNR** one (spend the rate the channel grants); if none meeting
    the floor fit, degrade to the best PSNR that fits; if nothing fits,
    send the globally cheapest point rather than dropping the request.

The table is plain data, so tests pin behaviour on a hand-written table and
production builds one with :func:`build_rd_table`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import numpy as np

# OperatingPoint is owned by the pipeline package now (it grew backend /
# tiling / context / wire-profile fields); re-exported here so serve-side
# callers keep importing it from repro.serve.
from repro.pipeline import OperatingPoint


@dataclass(frozen=True)
class RDPoint:
    op: OperatingPoint
    bits_per_example: float    # measured wire cost: true encoded container
                               # bytes * 8 (header + side info + payload),
                               # the same quantity the channel meters
    psnr_db: float             # restoration quality (higher is better)
    kl: float = math.nan       # KL(cloud || split) of downstream logits
    # calibration-time content statistics of the selected C channels —
    # anchor for the per-request PSNR shift (ContentKeyedController); NaN
    # means "no content keying for this point"
    calib_peak: float = math.nan
    calib_range: float = math.nan
    # expected P-frame/I-frame wire-bit ratio of the session codec at this
    # point (repro.session temporal delta coding); NaN means unmeasured —
    # session pricing then falls back to I-only cost, the legacy behaviour
    p_over_i: float = math.nan


class RateController:
    """Table-driven operating-point selection with a PSNR quality floor."""

    def __init__(self, table: list[RDPoint], *, quality_floor_db: float):
        if not table:
            raise ValueError("empty rate-distortion table")
        self.table = sorted(table, key=lambda p: (p.bits_per_example,
                                                  -p.psnr_db))
        self.quality_floor_db = quality_floor_db

    # -- offline planner ----------------------------------------------------
    def cheapest_meeting_floor(self) -> RDPoint:
        """Minimum-rate point with PSNR >= floor (paper-style operating point).

        Falls back to the highest-PSNR point when nothing meets the floor.
        """
        for p in self.table:                      # sorted by cost
            if p.psnr_db >= self.quality_floor_db:
                return p
        return max(self.table, key=lambda p: p.psnr_db)

    # -- per-request, channel-adaptive policy -------------------------------
    def select(self, bit_budget: float | None = None) -> RDPoint:
        """Pick the operating point for one request given the channel budget.

        ``bit_budget=None`` (or inf) means unmetered: equivalent to the full
        table. See module docstring for the 3-tier policy.
        """
        budget = math.inf if bit_budget is None else bit_budget
        fitting = [p for p in self.table if p.bits_per_example <= budget]
        if not fitting:
            return self.table[0]                  # cheapest overall
        meeting = [p for p in fitting if p.psnr_db >= self.quality_floor_db]
        pool = meeting if meeting else fitting
        # highest quality the budget allows; break PSNR ties toward fewer bits
        return max(pool, key=lambda p: (p.psnr_db, -p.bits_per_example))


class ContentKeyedController(RateController):
    """Per-request (C, bits) selection keyed on the request's own content.

    The calibration table's PSNRs are averages over the calibration batch;
    actual requests vary. Quantization noise power scales with the squared
    quantizer step, the step scales with the content's dynamic range, and
    the PSNR peak follows the content's peak — so with the per-C activation
    statistics of *this* request (core.split.activation_stats, O(HWC)) every
    table entry's PSNR shifts by

        20·log10(peak_req / peak_cal) + 20·log10(range_cal / range_req)

    interpolated from the entry's own calibration anchor. Selection then
    runs the same 3-tier budget/floor policy as the base class, but against
    the shifted per-request estimates (Choi & Bajić 2018's per-content
    operating points, as a table shift instead of an online sweep).
    """

    def estimate_psnr_db(self, p: RDPoint, stats=None) -> float:
        """Per-request PSNR estimate for one table entry.

        stats: ActivationStats for p's C (or a dict {c: ActivationStats}).
        Falls back to the calibration PSNR when anchors or stats are absent.
        """
        if isinstance(stats, dict):
            stats = stats.get(p.op.c)
        if stats is None or not (math.isfinite(p.calib_peak)
                                 and math.isfinite(p.calib_range)):
            return p.psnr_db
        eps = 1e-12
        shift = (20.0 * math.log10(max(stats.peak, eps)
                                   / max(p.calib_peak, eps))
                 + 20.0 * math.log10(max(p.calib_range, eps)
                                     / max(stats.dyn_range, eps)))
        return p.psnr_db + shift

    def select_for(self, bit_budget: float | None = None, stats=None,
                   floor_db: float | None = None) -> RDPoint:
        """3-tier policy over per-request PSNR estimates.

        stats    : per-request content statistics ({c: ActivationStats} or a
                   single ActivationStats applied to every C); None degrades
                   to the calibration-table policy
        floor_db : per-tenant floor override (None = controller default)
        """
        floor = self.quality_floor_db if floor_db is None else floor_db
        budget = math.inf if bit_budget is None else bit_budget
        est = {id(p): self.estimate_psnr_db(p, stats) for p in self.table}
        fitting = [p for p in self.table if p.bits_per_example <= budget]
        if not fitting:
            return self.table[0]
        meeting = [p for p in fitting if est[id(p)] >= floor]
        pool = meeting if meeting else fitting
        return max(pool, key=lambda p: (est[id(p)], -p.bits_per_example))


def session_bits_per_frame(point: RDPoint, *, keyframe_interval: int,
                           frame_stride: int = 1) -> float:
    """Expected wire bits per camera frame of a temporal session at this
    operating point.

    RD tables price I-frames (``bits_per_example`` is a standalone
    container); a streaming session interleaves cheap P-frames
    (repro.session), so pricing rungs off the I-only number overestimates
    their wire cost. With the point's measured ``p_over_i`` ratio:

        keyframe_interval k >= 1 : (1 + (k-1)·ratio) / k   of I-frame bits
        keyframe_interval 0      : ratio (steady state all-P after frame 0)

    divided by ``frame_stride`` (a rung serving every Nth camera frame
    offers 1/N of the per-frame load). A NaN ratio degrades to the legacy
    I-only price, so tables without the measurement keep old behaviour.
    """
    if keyframe_interval < 0:
        raise ValueError("keyframe_interval must be >= 0")
    if frame_stride < 1:
        raise ValueError("frame_stride must be >= 1")
    i_bits = point.bits_per_example
    ratio = point.p_over_i
    if not math.isfinite(ratio):
        per_frame = i_bits
    elif keyframe_interval == 0:
        per_frame = ratio * i_bits
    else:
        k = keyframe_interval
        per_frame = i_bits * (1.0 + (k - 1) * ratio) / k
    return per_frame / frame_stride


def rd_grid(baf_bank: dict, bits_sweep=(2, 4, 6, 8),
            backend: str = "zlib") -> list[OperatingPoint]:
    """The default calibration grid: every bank C crossed with the bit sweep
    on one backend. This list is also the RD cache's identity — see
    :func:`load_or_build_rd_table`."""
    return [OperatingPoint(c=c, bits=bits, backend=backend)
            for c in sorted(baf_bank) for bits in bits_sweep]


def build_rd_table(params, baf_bank: dict, imgs, *,
                   bits_sweep=(2, 4, 6, 8), backend: str = "zlib",
                   consolidation: bool = True,
                   ops: "list[OperatingPoint] | None" = None) -> list[RDPoint]:
    """Offline operating-point sweep with the repo's own fidelity metrics.

    params   : CNN params (models/cnn.py)
    baf_bank : {c: (baf_params, sel_idx)} — one trained BaF predictor per C
               (the BaF net's input width is C, so each C needs its own)
    imgs     : (B, H, W, 3) calibration batch the costs/metrics are measured on
    ops      : explicit grid of operating points; default
               ``rd_grid(baf_bank, bits_sweep, backend)``

    Each point's wire cost is measured by compiling its
    :class:`repro.pipeline.CompressionPlan` and encoding every calibration
    example through it — the same code path deployment runs.
    """
    from repro import pipeline
    from repro.core.split import activation_stats, fidelity_metrics
    from repro.models.cnn import cnn_edge

    if ops is None:
        ops = rd_grid(baf_bank, bits_sweep, backend)
    edge = jax.jit(lambda p, i: cnn_edge(p, i)[1])
    z = edge(params, imgs)
    specs, anchors = {}, {}
    for c, (baf_params, sel_idx) in sorted(baf_bank.items()):
        specs[c] = pipeline.ModelSpec(sel_idx=np.asarray(sel_idx),
                                      params=params, baf_params=baf_params)
        # per-example anchors, averaged: deployment sees single requests
        per_ex = [activation_stats(z[i:i + 1], sel_idx)
                  for i in range(imgs.shape[0])]
        anchors[c] = (float(np.mean([s.peak for s in per_ex])),
                      float(np.mean([s.dyn_range for s in per_ex])))
    table = []
    for op in ops:
        if op.c not in baf_bank:
            raise ValueError(f"operating point wants C={op.c} but the bank "
                             f"holds {sorted(baf_bank)}")
        plan = pipeline.compile(op, specs[op.c], consolidation=consolidation)
        baf_params, sel_idx = baf_bank[op.c]
        # cost at deployment granularity: the gateway transmits one image
        # per request, and a shared stream over the whole batch would
        # understate that — encode each example alone and average the
        # *actual* container lengths (not a bits*count estimate)
        per_req_bits = [plan.encode(z[i:i + 1]).stats.wire_bits
                        for i in range(imgs.shape[0])]
        psnr, kl = fidelity_metrics(params, baf_params, sel_idx, imgs,
                                    bits=op.bits, consolidation=consolidation,
                                    z=z)
        calib_peak, calib_range = anchors[op.c]
        table.append(RDPoint(
            op=op, bits_per_example=float(np.mean(per_req_bits)),
            psnr_db=float(psnr), kl=float(kl),
            calib_peak=calib_peak, calib_range=calib_range))
    return table


# ---------------------------------------------------------------------------
# RD-table disk cache (benchmark / CI time budget)
# ---------------------------------------------------------------------------

def op_to_json(op: OperatingPoint) -> dict:
    return {"c": op.c, "bits": op.bits, "backend": op.backend,
            "tiling": op.tiling, "context": op.context,
            "profile": op.profile}


def op_from_json(r: dict) -> OperatingPoint:
    from repro.pipeline import WIRE_PROFILE_VERSION
    return OperatingPoint(c=int(r["c"]), bits=int(r["bits"]),
                          backend=str(r.get("backend", "zlib")),
                          tiling=str(r.get("tiling", "auto")),
                          context=str(r.get("context", "auto")),
                          profile=int(r.get("profile",
                                            WIRE_PROFILE_VERSION)))


def rd_table_to_json(table: list[RDPoint]) -> list[dict]:
    return [{**op_to_json(p.op),
             "bits_per_example": p.bits_per_example, "psnr_db": p.psnr_db,
             "kl": p.kl, "calib_peak": p.calib_peak,
             "calib_range": p.calib_range, "p_over_i": p.p_over_i}
            for p in table]


def rd_table_from_json(rows: list[dict]) -> list[RDPoint]:
    return [RDPoint(op=op_from_json(r),
                    bits_per_example=float(r["bits_per_example"]),
                    psnr_db=float(r["psnr_db"]), kl=float(r["kl"]),
                    calib_peak=float(r.get("calib_peak", math.nan)),
                    calib_range=float(r.get("calib_range", math.nan)),
                    p_over_i=float(r.get("p_over_i", math.nan)))
            for r in rows]


def codec_revision() -> str:
    """Identity of the wire format the repo currently emits: container magic,
    rANS container version, and the pipeline wire profile. Any coder change
    that moves container bytes bumps one of these, so RD caches keyed on it
    can never serve stale costs."""
    from repro.codec.container import VERSION as rans_version
    from repro.core.codec import MAGIC as wire_magic
    from repro.pipeline import WIRE_PROFILE_VERSION
    return (f"{wire_magic.decode('ascii')}/rtc{rans_version}"
            f"/wp{WIRE_PROFILE_VERSION}")


def load_or_build_rd_table(cache_path, key: dict | None = None, build=None, *,
                           ops: "list[OperatingPoint] | None" = None,
                           tasks: dict | None = None) -> list[RDPoint]:
    """RD sweeps re-encode every calibration example at every operating
    point — too slow to redo per CI run now that the rANS backends are in
    the sweep. Cache the table to disk keyed by the sweep's identity.

    The effective cache key is ``key`` (caller-provided sweep inputs such as
    the calibration seed/shape) augmented with:

      * the full ``ops`` grid (every field of every operating point) when
        given — a sweep over different backends, bit depths, tilings, or
        wire profiles can never alias a cached table,
      * :func:`codec_revision` — container-format changes invalidate every
        cached table automatically (pre-plan caches keyed on backend+seed
        only are treated as stale and rebuilt in place), and
      * the ``tasks`` identity when given (head-set + task-weight vector,
        conventionally :func:`repro.tasks.task_set_key`) — a table swept
        for one task mix can never be served to a caller pricing a
        different head set or weighting; in particular a plain single-task
        cache (no ``tasks`` key on disk) is stale for any task-aware
        caller and rebuilds in place, and vice versa.

    cache_path : JSON file (conventionally ``benchmarks/rd_cache_*.json``)
    key        : JSON-serializable dict of extra sweep inputs (seed, calib …)
    build      : zero-arg callable returning the table on cache miss
    ops        : the operating-point grid the build sweeps
    tasks      : JSON-serializable head-set/weight identity of the sweep
    """
    import json
    import os

    if build is None:
        raise TypeError("load_or_build_rd_table needs a build callable "
                        "(the keyword-style signature makes it optional "
                        "syntactically, never semantically)")
    full_key = dict(key or {})
    if ops is not None:
        full_key["ops"] = [op_to_json(p) for p in ops]
    full_key["codec_rev"] = codec_revision()
    if tasks is not None:
        full_key["tasks"] = dict(tasks)

    cache_path = os.fspath(cache_path)
    try:
        with open(cache_path) as f:
            data = json.load(f)
        if data.get("key") == full_key:
            return rd_table_from_json(data["points"])
    except (OSError, ValueError, KeyError, AttributeError, TypeError):
        pass                         # any unusable cache file -> rebuild
    table = build()
    tmp = cache_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"key": full_key, "points": rd_table_to_json(table)}, f,
                  indent=1)
    os.replace(tmp, cache_path)
    return table
