"""Deterministic, seedable channel models for the collaborative-intelligence
gateway (repro.serve.gateway).

The paper's premise is a bandwidth-constrained uplink between an edge device
and the cloud. This module simulates that link with a virtual clock so the
gateway can be tested and benchmarked deterministically:

  * serialization delay — ``bits / bandwidth_bps``,
  * propagation delay   — ``base_latency_s`` plus optional uniform jitter
                          drawn from a seeded generator,
  * a single-transmission-at-a-time link: a new transmission starts only
    after the previous one has finished serializing,
  * an optional per-tick bit budget: the channel grants at most
    ``budget_bits_per_tick`` bits in any window of ``tick_s`` seconds; a
    transmission that does not fit in the remaining budget waits for the next
    tick (and may span several ticks). The rate controller reads
    ``budget_remaining()`` to pick an operating point that fits.

All times are in seconds on the channel's own virtual clock; nothing here
sleeps or touches the wall clock.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ChannelConfig:
    bandwidth_bps: float = 1e6       # bits per second on the wire
    base_latency_s: float = 0.01     # one-way propagation delay
    jitter_s: float = 0.0            # uniform [0, jitter_s) added per packet
    tick_s: float = 1.0              # budget accounting window
    budget_bits_per_tick: int | None = None   # None = unmetered
    # per-packet impairments (transmit_frame only; the metering paths
    # transmit/transmit_bytes stay lossless). Draws come from the channel's
    # seeded generator, so impaired runs replay bit-identically.
    loss_p: float = 0.0              # P(packet dropped in flight)
    corrupt_p: float = 0.0           # P(one bit flipped in a surviving packet)
    reorder_p: float = 0.0           # P(packet delayed by reorder_delay_s)
    reorder_delay_s: float = 0.0     # extra delay a reordered packet suffers
    mtu_bytes: int | None = None     # packetization unit (None = one packet)

    def __post_init__(self):
        for f in ("loss_p", "corrupt_p", "reorder_p"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f} must be a probability, got {v}")
        if self.reorder_delay_s < 0:
            raise ValueError("reorder_delay_s must be >= 0")
        if self.mtu_bytes is not None and self.mtu_bytes < 1:
            raise ValueError("mtu_bytes must be >= 1")


@dataclass(frozen=True)
class Transmission:
    """One packet's journey through the simulated link."""
    bits: int
    t_submit: float       # when the sender handed the packet to the channel
    t_start: float        # when the wire started serializing it
    t_arrive: float       # when the last bit (+ propagation) reached the cloud

    @property
    def latency_s(self) -> float:
        return self.t_arrive - self.t_submit

    @property
    def queue_wait_s(self) -> float:
        return self.t_start - self.t_submit


@dataclass(frozen=True)
class FrameDelivery:
    """One frame's packetized journey through a (possibly lossy) link.

    ``data`` is what the receiver can reassemble: None when any packet was
    lost (the frame cannot be reconstructed), otherwise the concatenated
    packet bytes — possibly bit-flipped when ``corrupted``. The bits of lost
    packets still occupied the wire (``tx.bits`` counts every packet sent);
    ``tx.t_arrive`` is when reassembly completes, i.e. the *last* packet's
    arrival — a reordered packet delays its whole frame.
    """
    tx: Transmission
    data: bytes | None
    n_packets: int
    lost_packets: int
    corrupted: bool

    @property
    def lost(self) -> bool:
        return self.data is None


class SimulatedChannel:
    """Virtual-clock channel; every run with the same seed is bit-identical."""

    def __init__(self, cfg: ChannelConfig, *, seed: int = 0):
        self.cfg = cfg
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self.now = 0.0                 # virtual clock (advanced by transmits)
        self._busy_until = 0.0         # wire occupied until here
        self._tick_used: dict[int, int] = {}   # tick index -> bits consumed
        self._metrics = None           # obs.MetricsRegistry (bind_metrics)
        self._metric_labels: dict = {}

    def bind_metrics(self, registry, **labels) -> None:
        """Mirror per-transmission accounting into an obs.MetricsRegistry
        (``channel_transmissions_total``, ``channel_wire_bits_total``,
        ``channel_queue_wait_seconds``), labeled e.g. ``tenant=...``.

        Handles are resolved once here — ``transmit`` is on the per-request
        hot path and must not pay a registry lookup per packet."""
        self._metrics = registry
        self._metric_labels = labels
        self._m_tx = registry.counter("channel_transmissions_total", **labels)
        self._m_bits = registry.counter("channel_wire_bits_total", **labels)
        self._m_wait = registry.histogram("channel_queue_wait_seconds",
                                          **labels)

    def reset(self) -> None:
        """Back to t=0 with the original seed — two serve runs over one
        channel replay bit-identically (benchmarks, deterministic tests)."""
        self._rng = np.random.default_rng(self.seed)
        self.now = 0.0
        self._busy_until = 0.0
        self._tick_used.clear()

    # -- budget -------------------------------------------------------------
    def _tick_of(self, t: float) -> int:
        return int(math.floor(t / self.cfg.tick_s))

    def budget_remaining(self, at: float | None = None) -> float:
        """Bits still grantable in the tick containing ``at`` (default: now)."""
        if self.cfg.budget_bits_per_tick is None:
            return math.inf
        tick = self._tick_of(self.now if at is None else at)
        return self.cfg.budget_bits_per_tick - self._tick_used.get(tick, 0)

    def _consume_budget(self, bits: int, t_start: float) -> tuple[float, float]:
        """Spend ``bits`` of tick budget starting at ``t_start``.

        Returns ``(begin, granted_by)``: the (possibly deferred) time the wire
        can begin, and the earliest time the *last* chunk of budget is granted
        — a packet spanning several ticks cannot finish before the tick that
        grants its final bits opens.
        """
        if self.cfg.budget_bits_per_tick is None:
            return t_start, t_start
        per_tick = self.cfg.budget_bits_per_tick
        tick = self._tick_of(t_start)
        # wait for a tick that can grant the packet's first chunk in full
        # (packets larger than a whole tick budget start on a fresh tick)
        first_chunk = min(bits, per_tick)
        while per_tick - self._tick_used.get(tick, 0) < first_chunk:
            tick += 1
        begin = max(t_start, tick * self.cfg.tick_s)
        remaining = bits
        while remaining > 0:
            grant = min(remaining, per_tick - self._tick_used.get(tick, 0))
            self._tick_used[tick] = self._tick_used.get(tick, 0) + grant
            remaining -= grant
            if remaining > 0:
                tick += 1
        return begin, tick * self.cfg.tick_s

    # -- transmission -------------------------------------------------------
    def transmit(self, bits: int, t_submit: float | None = None) -> Transmission:
        """Send ``bits`` over the link; advances the virtual clock."""
        bits = int(bits)
        if bits <= 0:
            raise ValueError(f"cannot transmit {bits} bits")
        t_submit = self.now if t_submit is None else max(t_submit, 0.0)
        t_ready = max(t_submit, self._busy_until)
        t_start, granted_by = self._consume_budget(bits, t_ready)
        serialization = bits / self.cfg.bandwidth_bps
        jitter = (float(self._rng.uniform(0.0, self.cfg.jitter_s))
                  if self.cfg.jitter_s > 0 else 0.0)
        # the last bit leaves no earlier than the tick granting it opens
        t_done = max(t_start + serialization, granted_by)
        t_arrive = t_done + self.cfg.base_latency_s + jitter
        self._busy_until = t_done
        # advance the clock through the whole transmission: the no-arg
        # budget_remaining() must read the tick the wire is committed to,
        # not a tick it already blew past.
        self.now = max(self.now, t_done)
        tx = Transmission(bits=bits, t_submit=t_submit, t_start=t_start,
                          t_arrive=t_arrive)
        if self._metrics is not None:
            self._m_tx.inc()
            self._m_bits.inc(bits)
            self._m_wait.observe(max(0.0, tx.queue_wait_s))
        return tx

    def transmit_bytes(self, data: bytes,
                       t_submit: float | None = None) -> Transmission:
        """Packetize an encoded wire blob: meters the *actual* container
        length (header + side info + entropy-coded payload), so channel
        occupancy reflects real bytes on the wire, not an estimate."""
        if len(data) == 0:
            raise ValueError("cannot transmit an empty packet")
        return self.transmit(8 * len(data), t_submit)

    def transmit_frame(self, data: bytes,
                       t_submit: float | None = None) -> FrameDelivery:
        """Packetize one frame at ``cfg.mtu_bytes`` and send each packet
        through the impaired link (loss / single-bit corruption / reorder
        delay, each an independent seeded draw per packet).

        Serialization and budget accounting go through :meth:`transmit`, so
        frames and plain blobs share one wire model; the frame arrives when
        its last packet does. Impairment-free configs make this exactly
        ``transmit_bytes`` plus packetization.
        """
        if len(data) == 0:
            raise ValueError("cannot transmit an empty frame")
        cfg = self.cfg
        mtu = cfg.mtu_bytes if cfg.mtu_bytes is not None else len(data)
        t_submit = self.now if t_submit is None else max(t_submit, 0.0)
        parts: list[bytes | None] = []
        first_start = None
        last_arrive = 0.0
        lost = 0
        corrupted = False
        for off in range(0, len(data), mtu):
            pkt = data[off:off + mtu]
            ptx = self.transmit(8 * len(pkt), t_submit)
            if first_start is None:
                first_start = ptx.t_start
            arrive = ptx.t_arrive
            # draws are gated on the probabilities so impairment-free frames
            # consume exactly the same RNG stream as transmit_bytes
            if cfg.loss_p > 0 and self._rng.random() < cfg.loss_p:
                lost += 1
                parts.append(None)
            else:
                if cfg.corrupt_p > 0 and self._rng.random() < cfg.corrupt_p:
                    flipped = bytearray(pkt)
                    pos = int(self._rng.integers(0, 8 * len(pkt)))
                    flipped[pos >> 3] ^= 1 << (pos & 7)
                    pkt = bytes(flipped)
                    corrupted = True
                if cfg.reorder_p > 0 and self._rng.random() < cfg.reorder_p:
                    arrive += cfg.reorder_delay_s
                parts.append(pkt)
            last_arrive = max(last_arrive, arrive)
        tx = Transmission(bits=8 * len(data), t_submit=t_submit,
                          t_start=first_start, t_arrive=last_arrive)
        payload = None if lost else b"".join(parts)
        return FrameDelivery(tx=tx, data=payload, n_packets=len(parts),
                             lost_packets=lost, corrupted=corrupted)

    def advance(self, dt: float) -> None:
        """Move the virtual clock forward (new tick budgets become current)."""
        if dt < 0:
            raise ValueError("time moves forward only")
        self.now += dt
