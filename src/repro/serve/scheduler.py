"""Multi-tenant uplink scheduling for the serving gateway.

The ROADMAP's north star is many concurrent consumers sharing a
bandwidth-constrained edge->cloud fabric (paper Fig. 1 at fleet scale). This
module adds the missing arbitration layer: N tenants, each with its own
request queue, channel, and quality floor, compete for a *shared* per-tick
bit budget. Following the multi-task bit-allocation line of work (Alvar &
Bajić 2020), the scheduler decides per tick who sends what:

  * **Deficit round robin (DRR)** over tenants: every scheduling round a
    tenant earns ``quantum_bits * weight`` of credit ("deficit"); its
    head-of-line job is granted once the credit covers the job's wire bits
    and the tick budget has room. Weighted fairness + O(1) per decision.
  * **Starvation freedom**: the rotation start advances every tick and
    credit persists across ticks, so a backlogged tenant cannot be locked
    out by a saturating neighbour — its head job is granted after a bounded
    number of ticks.
  * **Budget conservation**: the sum of granted bits inside one tick window
    never exceeds ``budget_bits_per_tick`` (``tick_grants`` keeps the audit
    trail; an oversize job — larger than a whole tick budget — consumes its
    tick exclusively, mirroring the channel's spanning-packet rule).
  * **Determinism**: no wall clock, no randomness — given the same enqueue
    sequence the grant sequence is bit-identical (the replay tests pin this).

The scheduler only *orders and meters* jobs; transmission timing stays in
each tenant's :class:`repro.serve.channel.SimulatedChannel` (construct those
unmetered — the shared budget lives here, per-link serialization there).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Iterable


@dataclass(frozen=True)
class TenantSpec:
    """Static per-tenant policy: DRR weight, an optional quality floor
    override consulted by the rate controller (None = controller default),
    the priority class admission control sheds by (higher = shed later;
    see repro.serve.executor.QueueDepthAdmission), and the declared task
    set — which downstream heads this tenant consumes (empty = undeclared:
    a task-aware gateway serves its full head set; see repro.tasks). The
    declaration is negotiated against the gateway's capabilities
    (pipeline.negotiate_tasks) and drives bit allocation, so a tenant
    declaring only ``classify`` never pays detection-grade bits."""
    name: str
    weight: float = 1.0
    quality_floor_db: float | None = None
    priority: int = 0
    tasks: tuple = ()

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")
        if not isinstance(self.tasks, tuple):
            object.__setattr__(self, "tasks", tuple(self.tasks))
        if any(not isinstance(t, str) or not t for t in self.tasks):
            raise ValueError(f"tenant {self.name!r}: tasks must be non-empty "
                             f"head names, got {self.tasks!r}")


@dataclass
class UplinkJob:
    """One encoded request waiting for an uplink grant."""
    tenant: str
    req_id: int              # per-tenant sequence number
    bits: int                # true wire cost: 8 * len(serialized container)
                             # (header + side info + entropy-coded payload)
    t_enqueue: float         # virtual time the edge finished encoding
    payload: Any = None      # opaque (op, enc, stats, ...) carried through


@dataclass
class _TenantQueue:
    spec: TenantSpec
    queue: deque = field(default_factory=deque)
    deficit: float = 0.0
    enqueued_bits: int = 0
    granted_bits: int = 0
    granted_jobs: int = 0


class DeficitRoundRobinScheduler:
    """Weighted DRR over per-tenant queues under a shared per-tick bit budget.

    Parameters
    ----------
    tenants : tenant specs (order fixes the base rotation order)
    budget_bits_per_tick : shared uplink budget per ``tick_s`` window
                           (None = unmetered: pure round-robin interleave)
    tick_s : budget accounting window on the virtual clock
    quantum_bits : DRR credit per round per unit weight; default is a quarter
                   of the per-weight tick budget, so a full rotation spends
                   at most ~1/4 tick and head-of-line jobs cannot monopolize
    """

    def __init__(self, tenants: Iterable[TenantSpec], *,
                 budget_bits_per_tick: int | None = None,
                 tick_s: float = 1.0, quantum_bits: int | None = None):
        specs = list(tenants)
        if not specs:
            raise ValueError("need at least one tenant")
        names = [t.name for t in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {names}")
        if tick_s <= 0:
            raise ValueError("tick_s must be > 0")
        self.tenants: dict[str, _TenantQueue] = {
            t.name: _TenantQueue(spec=t) for t in specs}
        self.budget_bits_per_tick = budget_bits_per_tick
        self.tick_s = tick_s
        if quantum_bits is None:
            total_w = sum(t.weight for t in specs)
            if budget_bits_per_tick is not None:
                quantum_bits = max(1, int(budget_bits_per_tick
                                          / (4.0 * total_w)))
            else:
                quantum_bits = 1            # unused when unmetered
        self.quantum_bits = quantum_bits
        self.tick_grants: dict[int, int] = {}   # tick index -> bits granted
        self._rr_start = 0
        self._order = names
        self._metrics = None

    def bind_metrics(self, registry) -> None:
        """Mirror grant accounting into an obs.MetricsRegistry: per-tenant
        ``sched_granted_bits_total``/``sched_granted_jobs_total`` counters
        and a live ``sched_backlog_jobs`` gauge.

        All handles resolve once here: enqueue/grant run per request and
        must not pay a registry lookup each time."""
        self._metrics = registry
        self._m_backlog = registry.gauge("sched_backlog_jobs")
        self._m_tenant = {
            name: (registry.counter("sched_enqueued_bits_total", tenant=name),
                   registry.counter("sched_granted_bits_total", tenant=name),
                   registry.counter("sched_granted_jobs_total", tenant=name))
            for name in self._order}

    # -- queue side ---------------------------------------------------------
    def enqueue(self, job: UplinkJob) -> None:
        tq = self.tenants.get(job.tenant)
        if tq is None:
            raise KeyError(f"unknown tenant {job.tenant!r}")
        if job.bits <= 0:
            raise ValueError(f"job bits must be > 0, got {job.bits}")
        tq.queue.append(job)
        tq.enqueued_bits += job.bits
        if self._metrics is not None:
            self._m_tenant[job.tenant][0].inc(job.bits)
            self._m_backlog.set(self.pending())

    def pending(self) -> int:
        return sum(len(t.queue) for t in self.tenants.values())

    # -- tick geometry ------------------------------------------------------
    def tick_of(self, t: float) -> int:
        return int(math.floor(t / self.tick_s))

    def next_tick_time(self, t: float) -> float:
        return (self.tick_of(t) + 1) * self.tick_s

    def budget_remaining(self, t: float) -> float:
        """Bits still grantable in the tick containing ``t`` — the quantity
        the rate controller keys operating points on."""
        if self.budget_bits_per_tick is None:
            return math.inf
        used = self.tick_grants.get(self.tick_of(t), 0)
        return self.budget_bits_per_tick - used

    # -- grant side ---------------------------------------------------------
    def drain(self, now: float) -> list[UplinkJob]:
        """Grant as much queued work as ``now``'s tick allows; DRR order.

        Returns granted jobs in grant order. Call again in a later tick for
        whatever remains (``pending()``).
        """
        tick = self.tick_of(now)
        per_tick = self.budget_bits_per_tick
        remaining = (math.inf if per_tick is None
                     else per_tick - self.tick_grants.get(tick, 0))
        order = [self.tenants[n] for n in
                 self._order[self._rr_start:] + self._order[:self._rr_start]]
        self._rr_start = (self._rr_start + 1) % len(self._order)

        granted: list[UplinkJob] = []
        if per_tick is None:
            # unmetered: no budget to apportion, so skip credit accrual
            # (which would cost O(job_bits / quantum) rounds per job) and
            # interleave head-of-line jobs round-robin until queues drain
            while self.pending():
                for tq in order:
                    if tq.queue:
                        job = tq.queue.popleft()
                        self._account(tick, tq, job)
                        granted.append(job)
            return granted
        while remaining > 0 and self.pending():
            # work conservation: keep cycling DRR rounds (credit accrues
            # every round) as long as SOME head-of-line job could still be
            # granted in this tick; stop only when nothing fits
            def _head_can_go(tq: _TenantQueue) -> bool:
                if not tq.queue:
                    return False
                bits = tq.queue[0].bits
                return (bits <= remaining
                        or (per_tick is not None and bits > per_tick
                            and remaining == per_tick))
            if not any(_head_can_go(tq) for tq in order):
                break
            for tq in order:
                if not tq.queue:
                    tq.deficit = 0.0        # classic DRR: no credit hoarding
                    continue
                tq.deficit += self.quantum_bits * tq.spec.weight
                while tq.queue and tq.queue[0].bits <= tq.deficit:
                    job = tq.queue[0]
                    if job.bits <= remaining:
                        tq.queue.popleft()
                        tq.deficit -= job.bits
                        remaining -= job.bits
                        self._account(tick, tq, job)
                        granted.append(job)
                    elif (per_tick is not None and job.bits > per_tick
                          and remaining == per_tick):
                        # oversize job on a fresh tick: ship it alone and
                        # close the tick (spanning-packet rule)
                        tq.queue.popleft()
                        tq.deficit = 0.0
                        remaining = 0
                        self._account_spanning(tick, tq, job, per_tick)
                        granted.append(job)
                        break
                    else:
                        break               # retry next tick
                if remaining <= 0:
                    break
        return granted

    def _account(self, tick: int, tq: _TenantQueue, job: UplinkJob) -> None:
        self.tick_grants[tick] = self.tick_grants.get(tick, 0) + job.bits
        tq.granted_bits += job.bits
        tq.granted_jobs += 1
        self._account_metrics(job)

    def _account_metrics(self, job: UplinkJob) -> None:
        if self._metrics is not None:
            _, bits, jobs = self._m_tenant[job.tenant]
            bits.inc(job.bits)
            jobs.inc()
            self._m_backlog.set(self.pending())

    def _account_spanning(self, tick: int, tq: _TenantQueue, job: UplinkJob,
                          per_tick: int) -> None:
        """Charge an oversize job across this and future ticks so per-tick
        conservation (``tick_grants[i] <= budget``) holds exactly."""
        left = job.bits
        while left > 0:
            room = per_tick - self.tick_grants.get(tick, 0)
            spend = min(left, room)
            if spend > 0:
                self.tick_grants[tick] = self.tick_grants.get(tick, 0) + spend
                left -= spend
            tick += 1
        tq.granted_bits += job.bits
        tq.granted_jobs += 1
        self._account_metrics(job)

    # -- introspection ------------------------------------------------------
    def grant_shares(self) -> dict[str, float]:
        """Fraction of total granted bits per tenant (fairness reporting)."""
        total = sum(t.granted_bits for t in self.tenants.values())
        if total == 0:
            return {n: 0.0 for n in self._order}
        return {n: self.tenants[n].granted_bits / total for n in self._order}
