"""Serving: LM prefill/decode engine (engine.py) and the collaborative-
intelligence split-inference gateway (gateway.py + channel/rate_control/
batcher/telemetry).

The LM engine pulls in the transformer model zoo, so it is intentionally NOT
imported here — use ``from repro.serve.engine import ...`` directly.

Observability (virtual-clock tracing, metrics registries) lives in
:mod:`repro.obs`; the gateway accepts ``tracer=``/``metrics=`` objects from
there. ``MetricsRegistry`` and ``Tracer`` are re-exported here for
convenience.
"""
from repro.obs import MetricsRegistry, Tracer
from repro.pipeline import Capabilities, NegotiationError
from repro.serve.batcher import (BucketKey, DecodedRequest, EncodedRequest,
                                 MicroBatch, MicroBatcher, PlanBucketKey,
                                 bucket_sizes)
from repro.serve.channel import (ChannelConfig, FrameDelivery,
                                 SimulatedChannel, Transmission)
from repro.serve.executor import (AdmissionDecision, AdmissionPolicy,
                                  AlwaysAdmit, CalibratedCostModel,
                                  CloudExecutor, CompositeAdmission,
                                  CostModel, ExecTicket, LinearCostModel,
                                  MeasuredCost, MultiQueueExecutor,
                                  QueueDepthAdmission, RequestShed,
                                  SerialExecutor, TokenBucketAdmission,
                                  priority_depth_limits)
from repro.serve.gateway import (GatewayFederation, GatewayResponse,
                                 MultiTenantGateway, ServingGateway,
                                 TenantRequest, serve_federated)
from repro.serve.mesh_executor import MeshExecutor, seed_cost_from_hlo
from repro.serve.rate_control import (ContentKeyedController,
                                      OperatingPoint, RateController,
                                      RDPoint, build_rd_table,
                                      codec_revision, load_or_build_rd_table,
                                      rd_grid, rd_table_from_json,
                                      rd_table_to_json,
                                      session_bits_per_frame)
from repro.serve.scheduler import (DeficitRoundRobinScheduler, TenantSpec,
                                   UplinkJob)
from repro.serve.telemetry import (DegradeRecord, RequestRecord, ShedRecord,
                                   Telemetry, jain_fairness)

__all__ = [
    "BucketKey", "DecodedRequest", "EncodedRequest", "MicroBatch",
    "MicroBatcher", "PlanBucketKey", "bucket_sizes",
    "Capabilities", "NegotiationError",
    "ChannelConfig", "FrameDelivery", "SimulatedChannel", "Transmission",
    "AdmissionDecision", "AdmissionPolicy", "AlwaysAdmit",
    "CalibratedCostModel", "CloudExecutor", "CompositeAdmission",
    "CostModel", "ExecTicket", "LinearCostModel", "MeasuredCost",
    "MeshExecutor", "MultiQueueExecutor", "QueueDepthAdmission",
    "RequestShed", "SerialExecutor", "TokenBucketAdmission",
    "priority_depth_limits", "seed_cost_from_hlo",
    "GatewayFederation", "GatewayResponse", "MultiTenantGateway",
    "ServingGateway", "TenantRequest", "serve_federated",
    "ContentKeyedController", "OperatingPoint",
    "RateController", "RDPoint", "build_rd_table", "codec_revision",
    "load_or_build_rd_table", "rd_grid", "rd_table_from_json",
    "rd_table_to_json", "session_bits_per_frame",
    "DeficitRoundRobinScheduler", "TenantSpec", "UplinkJob",
    "DegradeRecord", "RequestRecord", "ShedRecord", "Telemetry",
    "jain_fairness",
    "MetricsRegistry", "Tracer",
]
