"""Micro-batching queue for the serving gateway.

Requests arrive with heterogeneous operating points (the rate controller
varies them per request), but the jitted BaF-restore + cloud forward compile
per input shape. Left unchecked, every distinct batch size would trigger a
fresh XLA compile. The batcher therefore:

  * groups requests by bucket key — requests in a group share one restore
    compile *and* one batched host decode (``plan.decode_batch``),
  * pads each flushed group up to a small set of power-of-two batch sizes
    (1, 2, 4, ... max_batch), so the total number of compiles is bounded by
    ``|keys| * |bucket sizes|``,
  * preserves request identity: every :class:`MicroBatch` carries its
    requests in arrival order and ``pad`` tells the consumer how many
    trailing rows to drop.

Two request currencies are supported:

  * :class:`EncodedRequest` — the plan-API path: the bucket holds *encoded*
    wire blobs keyed by ``(operating point, H, W)`` and the gateway decodes
    the whole bucket in one ``plan.decode_batch`` call at dispatch time;
  * :class:`DecodedRequest` — the already-decoded currency (arrays stacked
    and padded here) for callers that decode upstream of the batcher.

Batch windows bound how long a partially-filled bucket may wait. With
``adaptive=True`` the window is *burst-aware*: each bucket tracks an EWMA of
its inter-arrival gap, and the deadline is the time the group is *expected*
to fill — ``gap_ewma * (max_batch - len(group))`` — clamped to
``[min_window_s, window_s]``. Bursty traffic fills buckets anyway, so the
deadline collapses toward ``min_window_s`` and latency is not spent waiting
for stragglers that are already in flight; sparse traffic would never fill
the bucket inside the window, so the group flushes early instead of idling
the full fixed window.

Pure host-side data plumbing — no JAX in here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

EWMA_ALPHA = 0.3     # weight of the newest inter-arrival gap


@dataclass(frozen=True)
class BucketKey:
    c: int
    bits: int
    h: int
    w: int


@dataclass(frozen=True)
class PlanBucketKey:
    """Bucket key of the plan-API path: the full operating point (backend,
    tiling, context included — mixed backends must never share one batched
    decode) plus the spatial shape."""
    op: Any                    # repro.pipeline.OperatingPoint
    h: int
    w: int

    @property
    def c(self) -> int:
        return self.op.c

    @property
    def bits(self) -> int:
        return self.op.bits


@dataclass
class EncodedRequest:
    """One request still in wire form — decoded batched, at dispatch."""
    req_id: int
    blob: Any                  # repro.pipeline.WireBlob
    t_arrive: float = 0.0      # channel arrival (virtual clock)
    meta: Any = None           # opaque caller payload (stats, op point, ...)
    tenant: str = ""           # owning tenant ("" = single-tenant serving)
    priority: int = 0          # TenantSpec.priority (executor scheduling)

    @property
    def key(self) -> PlanBucketKey:
        _, h, w, _ = self.blob.shape
        return PlanBucketKey(op=self.blob.op, h=h, w=w)


@dataclass
class DecodedRequest:
    """One request after wire decode, ready for restore (legacy path)."""
    req_id: int
    codes: np.ndarray          # (1, H, W, C) integer codes
    mins: np.ndarray           # (1, 1, 1, C) fp16
    maxs: np.ndarray           # (1, 1, 1, C) fp16
    c: int
    bits: int
    t_arrive: float = 0.0      # channel arrival (virtual clock)
    meta: Any = None           # opaque caller payload (stats, op point, ...)
    tenant: str = ""           # owning tenant ("" = single-tenant serving)
    priority: int = 0          # TenantSpec.priority (executor scheduling)

    @property
    def key(self) -> BucketKey:
        _, h, w, _ = self.codes.shape
        return BucketKey(c=self.c, bits=self.bits, h=h, w=w)


@dataclass
class MicroBatch:
    key: Any                             # BucketKey | PlanBucketKey
    requests: list                       # arrival order, len = true batch
    codes: np.ndarray | None = None      # (Npad, H, W, C); None = encoded
    mins: np.ndarray | None = None       # (Npad, 1, 1, C)
    maxs: np.ndarray | None = None       # (Npad, 1, 1, C)
    pad: int = 0                         # trailing padded rows to drop
    target: int | None = None            # padded size (encoded batches)

    @property
    def padded_size(self) -> int:
        if self.codes is not None:
            return self.codes.shape[0]
        return self.target if self.target is not None else len(self.requests)

    @property
    def encoded(self) -> bool:
        """True when the batch still holds wire blobs (decode at dispatch)."""
        return self.codes is None

    @property
    def priority(self) -> int:
        """Batch priority class the executor schedules on: the max over its
        requests' priorities (buckets mix tenants; the batch rides at the
        highest class aboard)."""
        return max((getattr(r, "priority", 0) for r in self.requests),
                   default=0)


def bucket_sizes(max_batch: int) -> tuple[int, ...]:
    """Powers of two up to and including ``max_batch``."""
    sizes, s = [], 1
    while s < max_batch:
        sizes.append(s)
        s *= 2
    sizes.append(max_batch)
    return tuple(dict.fromkeys(sizes))


class MicroBatcher:
    """Groups requests into padded bucket-shaped micro-batches.

    Buckets are keyed by the request's ``key`` property only — NOT by tenant
    — so heterogeneous multi-tenant traffic at the same operating point
    shares one bucket and the batched decode + fused restore + cloud forward
    stay recompile-free (``tenant`` rides along for telemetry/routing).

    ``window_s`` bounds how long a partially-filled bucket may wait: ``add``
    stamps each new group with its first arrival, ``deadline(key)`` is when
    that group must flush, and ``take(key, gen)`` flushes one group by its
    generation stamp — the event-driven gateway schedules a flush event per
    group and ``gen`` keeps a stale event from flushing a *newer* group that
    formed after the original filled up. With ``adaptive=True`` the deadline
    follows the bucket's arrival-rate EWMA (module docstring) and may move
    in *either* direction as arrivals update the estimate — re-read
    ``deadline`` after every add, and re-check it when a scheduled flush
    fires (the event-driven gateway re-pushes a flush whose deadline
    drifted later instead of flushing undersized).
    """

    def __init__(self, *, max_batch: int = 8, window_s: float | None = None,
                 adaptive: bool = False, min_window_s: float = 0.0):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if window_s is not None and window_s < 0:
            raise ValueError("window_s must be >= 0")
        if adaptive and window_s is None:
            raise ValueError("adaptive windows need a window_s cap")
        if min_window_s < 0:
            raise ValueError("min_window_s must be >= 0")
        self.max_batch = max_batch
        self.window_s = window_s
        self.adaptive = adaptive
        self.min_window_s = min_window_s
        self.sizes = bucket_sizes(max_batch)
        self._pending: dict[Any, list] = {}
        self._opened: dict[Any, tuple[float, int]] = {}   # (t_first, gen)
        self._gen = 0
        # burst estimation state persists across groups at the same key
        self._last_arrival: dict[Any, float] = {}
        self._gap_ewma: dict[Any, float] = {}

    def __len__(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def _observe_arrival(self, key, now: float) -> None:
        last = self._last_arrival.get(key)
        self._last_arrival[key] = now
        if last is None:
            return
        gap = max(now - last, 0.0)
        if self.window_s is not None:
            # a gap beyond the window cap measures *idleness* between
            # traffic epochs, not arrival rate — clamp it so one quiet
            # stretch cannot poison the burst estimate for the next epoch
            gap = min(gap, self.window_s)
        prev = self._gap_ewma.get(key)
        self._gap_ewma[key] = (gap if prev is None
                               else EWMA_ALPHA * gap + (1 - EWMA_ALPHA) * prev)

    def arrival_gap_ewma(self, key) -> float | None:
        """Current EWMA of the inter-arrival gap at ``key`` (None = fewer
        than two arrivals observed)."""
        return self._gap_ewma.get(key)

    def add(self, req, now: float | None = None) -> list[MicroBatch]:
        """Enqueue; returns any group that reached max_batch (flushed full)."""
        t = req.t_arrive if now is None else now
        self._observe_arrival(req.key, t)
        group = self._pending.setdefault(req.key, [])
        if not group:
            self._gen += 1
            self._opened[req.key] = (t, self._gen)
        group.append(req)
        if len(group) >= self.max_batch:
            del self._pending[req.key]
            self._opened.pop(req.key, None)
            return [self._make_batch(req.key, group)]
        return []

    def deadline(self, key) -> tuple[float, int] | None:
        """(flush-due time, generation) for the group at ``key``; None when
        no group is open or no window is configured."""
        if self.window_s is None or key not in self._opened:
            return None
        t_first, gen = self._opened[key]
        window = self.window_s
        if self.adaptive:
            ewma = self._gap_ewma.get(key)
            if ewma is not None:
                # expected time for the stragglers that would fill the
                # bucket; bursts collapse this toward min_window_s, sparse
                # traffic flushes early instead of idling the full window
                remaining = self.max_batch - len(self._pending.get(key, ()))
                window = min(window, max(ewma * remaining, self.min_window_s))
        return t_first + window, gen

    def take(self, key, gen: int | None = None) -> MicroBatch | None:
        """Flush the group at ``key`` now; None when it is gone or, with
        ``gen`` given, when a different (newer) group occupies the key."""
        if key not in self._pending:
            return None
        if gen is not None and self._opened.get(key, (0.0, -1))[1] != gen:
            return None
        group = self._pending.pop(key)
        self._opened.pop(key, None)
        return self._make_batch(key, group)

    def flush(self) -> list[MicroBatch]:
        """Drain every pending group (end of tick / shutdown)."""
        out = [self._make_batch(k, g) for k, g in self._pending.items()]
        self._pending.clear()
        self._opened.clear()
        return out

    def _make_batch(self, key, group: list) -> MicroBatch:
        n = len(group)
        target = next(s for s in self.sizes if s >= n)
        pad = target - n
        if isinstance(group[0], EncodedRequest):
            # wire blobs stay packed; the gateway decodes the whole bucket
            # in one plan.decode_batch and pads the decoded stack to target
            return MicroBatch(key=key, requests=list(group), pad=pad,
                              target=target)

        def stack(field_name):
            arrs = [getattr(r, field_name) for r in group]
            arrs += [arrs[-1]] * pad            # repeat last row as padding
            return np.concatenate(arrs, axis=0)
        return MicroBatch(key=key, requests=list(group), codes=stack("codes"),
                          mins=stack("mins"), maxs=stack("maxs"), pad=pad)
