"""Micro-batching queue for the serving gateway.

Requests arrive with heterogeneous operating points ``(C, bits)`` (the rate
controller varies them per request), but the jitted BaF-restore + cloud
forward compile per input shape. Left unchecked, every distinct batch size
would trigger a fresh XLA compile. The batcher therefore:

  * groups decoded requests by bucket key ``(C, bits, H, W)`` — requests in a
    group share one restore compile,
  * pads each flushed group up to a small set of power-of-two batch sizes
    (1, 2, 4, ... max_batch) by repeating the last element, so the total
    number of compiles is bounded by ``|keys| * |bucket sizes|``,
  * preserves request identity: every :class:`MicroBatch` carries its
    requests in arrival order and ``pad`` tells the consumer how many
    trailing rows to drop.

Pure host-side data plumbing — no JAX in here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np


@dataclass(frozen=True)
class BucketKey:
    c: int
    bits: int
    h: int
    w: int


@dataclass
class DecodedRequest:
    """One request after wire decode, ready for restore."""
    req_id: int
    codes: np.ndarray          # (1, H, W, C) integer codes
    mins: np.ndarray           # (1, 1, 1, C) fp16
    maxs: np.ndarray           # (1, 1, 1, C) fp16
    c: int
    bits: int
    t_arrive: float = 0.0      # channel arrival (virtual clock)
    meta: Any = None           # opaque caller payload (stats, op point, ...)

    @property
    def key(self) -> BucketKey:
        _, h, w, _ = self.codes.shape
        return BucketKey(c=self.c, bits=self.bits, h=h, w=w)


@dataclass
class MicroBatch:
    key: BucketKey
    requests: list[DecodedRequest]       # arrival order, len = true batch
    codes: np.ndarray                    # (Npad, H, W, C)
    mins: np.ndarray                     # (Npad, 1, 1, C)
    maxs: np.ndarray                     # (Npad, 1, 1, C)
    pad: int                             # trailing padded rows to drop

    @property
    def padded_size(self) -> int:
        return self.codes.shape[0]


def bucket_sizes(max_batch: int) -> tuple[int, ...]:
    """Powers of two up to and including ``max_batch``."""
    sizes, s = [], 1
    while s < max_batch:
        sizes.append(s)
        s *= 2
    sizes.append(max_batch)
    return tuple(dict.fromkeys(sizes))


class MicroBatcher:
    """Groups decoded requests into padded bucket-shaped micro-batches."""

    def __init__(self, *, max_batch: int = 8):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self.sizes = bucket_sizes(max_batch)
        self._pending: dict[BucketKey, list[DecodedRequest]] = {}

    def __len__(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def add(self, req: DecodedRequest) -> list[MicroBatch]:
        """Enqueue; returns any group that reached max_batch (flushed full)."""
        group = self._pending.setdefault(req.key, [])
        group.append(req)
        if len(group) >= self.max_batch:
            del self._pending[req.key]
            return [self._make_batch(req.key, group)]
        return []

    def flush(self) -> list[MicroBatch]:
        """Drain every pending group (end of tick / shutdown)."""
        out = [self._make_batch(k, g) for k, g in self._pending.items()]
        self._pending.clear()
        return out

    def _make_batch(self, key: BucketKey, group: list[DecodedRequest]) -> MicroBatch:
        n = len(group)
        target = next(s for s in self.sizes if s >= n)
        pad = target - n
        def stack(field_name):
            arrs = [getattr(r, field_name) for r in group]
            arrs += [arrs[-1]] * pad            # repeat last row as padding
            return np.concatenate(arrs, axis=0)
        return MicroBatch(key=key, requests=list(group), codes=stack("codes"),
                          mins=stack("mins"), maxs=stack("maxs"), pad=pad)
