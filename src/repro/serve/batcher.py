"""Micro-batching queue for the serving gateway.

Requests arrive with heterogeneous operating points ``(C, bits)`` (the rate
controller varies them per request), but the jitted BaF-restore + cloud
forward compile per input shape. Left unchecked, every distinct batch size
would trigger a fresh XLA compile. The batcher therefore:

  * groups decoded requests by bucket key ``(C, bits, H, W)`` — requests in a
    group share one restore compile,
  * pads each flushed group up to a small set of power-of-two batch sizes
    (1, 2, 4, ... max_batch) by repeating the last element, so the total
    number of compiles is bounded by ``|keys| * |bucket sizes|``,
  * preserves request identity: every :class:`MicroBatch` carries its
    requests in arrival order and ``pad`` tells the consumer how many
    trailing rows to drop.

Pure host-side data plumbing — no JAX in here.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np


@dataclass(frozen=True)
class BucketKey:
    c: int
    bits: int
    h: int
    w: int


@dataclass
class DecodedRequest:
    """One request after wire decode, ready for restore."""
    req_id: int
    codes: np.ndarray          # (1, H, W, C) integer codes
    mins: np.ndarray           # (1, 1, 1, C) fp16
    maxs: np.ndarray           # (1, 1, 1, C) fp16
    c: int
    bits: int
    t_arrive: float = 0.0      # channel arrival (virtual clock)
    meta: Any = None           # opaque caller payload (stats, op point, ...)
    tenant: str = ""           # owning tenant ("" = single-tenant serving)

    @property
    def key(self) -> BucketKey:
        _, h, w, _ = self.codes.shape
        return BucketKey(c=self.c, bits=self.bits, h=h, w=w)


@dataclass
class MicroBatch:
    key: BucketKey
    requests: list[DecodedRequest]       # arrival order, len = true batch
    codes: np.ndarray                    # (Npad, H, W, C)
    mins: np.ndarray                     # (Npad, 1, 1, C)
    maxs: np.ndarray                     # (Npad, 1, 1, C)
    pad: int                             # trailing padded rows to drop

    @property
    def padded_size(self) -> int:
        return self.codes.shape[0]


def bucket_sizes(max_batch: int) -> tuple[int, ...]:
    """Powers of two up to and including ``max_batch``."""
    sizes, s = [], 1
    while s < max_batch:
        sizes.append(s)
        s *= 2
    sizes.append(max_batch)
    return tuple(dict.fromkeys(sizes))


class MicroBatcher:
    """Groups decoded requests into padded bucket-shaped micro-batches.

    Buckets are keyed by ``(C, bits, H, W)`` only — NOT by tenant — so
    heterogeneous multi-tenant traffic at the same operating point shares one
    bucket and the fused restore + cloud forward stay recompile-free
    (``DecodedRequest.tenant`` rides along for telemetry/response routing).

    ``window_s`` bounds how long a partially-filled bucket may wait: ``add``
    stamps each new group with its first arrival, ``deadline(key)`` is when
    that group must flush, and ``take(key, gen)`` flushes one group by its
    generation stamp — the event-driven gateway schedules a flush event per
    group and ``gen`` keeps a stale event from flushing a *newer* group that
    formed after the original filled up.
    """

    def __init__(self, *, max_batch: int = 8, window_s: float | None = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if window_s is not None and window_s < 0:
            raise ValueError("window_s must be >= 0")
        self.max_batch = max_batch
        self.window_s = window_s
        self.sizes = bucket_sizes(max_batch)
        self._pending: dict[BucketKey, list[DecodedRequest]] = {}
        self._opened: dict[BucketKey, tuple[float, int]] = {}  # (t_first, gen)
        self._gen = 0

    def __len__(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def add(self, req: DecodedRequest,
            now: float | None = None) -> list[MicroBatch]:
        """Enqueue; returns any group that reached max_batch (flushed full)."""
        group = self._pending.setdefault(req.key, [])
        if not group:
            self._gen += 1
            t_first = req.t_arrive if now is None else now
            self._opened[req.key] = (t_first, self._gen)
        group.append(req)
        if len(group) >= self.max_batch:
            del self._pending[req.key]
            self._opened.pop(req.key, None)
            return [self._make_batch(req.key, group)]
        return []

    def deadline(self, key: BucketKey) -> tuple[float, int] | None:
        """(flush-due time, generation) for the group at ``key``; None when
        no group is open or no window is configured."""
        if self.window_s is None or key not in self._opened:
            return None
        t_first, gen = self._opened[key]
        return t_first + self.window_s, gen

    def take(self, key: BucketKey,
             gen: int | None = None) -> MicroBatch | None:
        """Flush the group at ``key`` now; None when it is gone or, with
        ``gen`` given, when a different (newer) group occupies the key."""
        if key not in self._pending:
            return None
        if gen is not None and self._opened.get(key, (0.0, -1))[1] != gen:
            return None
        group = self._pending.pop(key)
        self._opened.pop(key, None)
        return self._make_batch(key, group)

    def flush(self) -> list[MicroBatch]:
        """Drain every pending group (end of tick / shutdown)."""
        out = [self._make_batch(k, g) for k, g in self._pending.items()]
        self._pending.clear()
        self._opened.clear()
        return out

    def _make_batch(self, key: BucketKey, group: list[DecodedRequest]) -> MicroBatch:
        n = len(group)
        target = next(s for s in self.sizes if s >= n)
        pad = target - n
        def stack(field_name):
            arrs = [getattr(r, field_name) for r in group]
            arrs += [arrs[-1]] * pad            # repeat last row as padding
            return np.concatenate(arrs, axis=0)
        return MicroBatch(key=key, requests=list(group), codes=stack("codes"),
                          mins=stack("mins"), maxs=stack("maxs"), pad=pad)
