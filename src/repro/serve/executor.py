"""Pluggable cloud-side execution for the serving gateway.

The gateways used to model the cloud as one hardwired serial executor baked
into the event loop (``cloud_busy = start + compute_s``). This module makes
the cloud half a first-class, swappable object:

  * :class:`CloudExecutor` — the protocol every cloud model implements:
    ``submit(batch, t_ready) -> ExecTicket`` plans the batch onto a queue on
    the *virtual* clock (the real jitted compute runs inline, its wall time
    is measured separately), ``poll(now)`` / ``drain()`` surface finished
    tickets, and capacity / queue-depth introspection feeds admission
    control.
  * :class:`SerialExecutor` — one queue, measured-wall-time cost model:
    bit-identical to the old inline serial cloud. The default.
  * :class:`MultiQueueExecutor` — N parallel queues (think N accelerator
    replicas behind the gateway) with per-queue service rates.
    Work-conserving selection: a batch goes to whichever queue finishes it
    first (earliest ``max(t_ready, busy_until) + cost/rate``); ties prefer
    the queue that last served the same plan bucket (trace/cache affinity),
    then the lowest index — fully deterministic.
  * :class:`AdmissionPolicy` objects — token buckets per tenant,
    queue-depth thresholds with per-priority limits, and composition.
    Every rejection is an explicit :class:`RequestShed` outcome; nothing is
    ever silently dropped.

Virtual-clock cost model: the executor *plans* service durations with a
:class:`CostModel`. :class:`MeasuredCost` (default) uses the measured wall
time of the real compute — honest, but not replayable bit-for-bit.
:class:`LinearCostModel` is a deterministic ``base + per_item * padded_size``
model: two runs of the same workload produce bit-identical tickets and
telemetry (the replay tests and the overload benchmark pin this).

Pure host-side scheduling — the only JAX in here is whatever the bound
``run_fn`` does.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable


# ---------------------------------------------------------------------------
# Cost models (virtual-clock service durations)
# ---------------------------------------------------------------------------

class CostModel:
    """Maps one micro-batch to its virtual service duration in seconds."""

    def duration_s(self, batch, measured_s: float) -> float:
        raise NotImplementedError


class MeasuredCost(CostModel):
    """Virtual duration = measured wall time of the real compute.

    Matches the pre-executor gateways exactly, but replays only as
    bit-identically as the host's clock does (use :class:`LinearCostModel`
    when the run must replay bit-for-bit)."""

    def duration_s(self, batch, measured_s: float) -> float:
        return measured_s


@dataclass(frozen=True)
class LinearCostModel(CostModel):
    """Deterministic affine cost: ``base_s + per_item_s * padded_size``.

    The virtual clock then depends only on the workload, never on host
    timing — same seed, same tickets, same telemetry, bit for bit."""
    base_s: float = 0.002
    per_item_s: float = 0.001

    def duration_s(self, batch, measured_s: float) -> float:
        return self.base_s + self.per_item_s * batch.padded_size


class CalibratedCostModel(CostModel):
    """Affine cost **fit from measured compute** — calibrate, freeze, replay.

    Life cycle:

      1. *Calibrating* (``frozen=False``): every ``duration_s`` call records a
         ``(padded_size, wall_s)`` sample and returns the measured wall time
         (behaves like :class:`MeasuredCost`). Drive a warm executor through
         a spread of batch sizes to collect the samples.
      2. ``freeze()``: least-squares affine fit ``base_s + per_item_s * n``
         over the samples (coefficients clamped >= 0; degenerate sample sets
         fall back to the seed coefficients, e.g. roofline estimates from
         ``launch/hlo_cost``).
      3. *Frozen*: ``duration_s`` is a pure function of ``padded_size`` —
         the virtual clock depends only on the workload, so two runs replay
         bit-identically, like :class:`LinearCostModel` but with constants
         the hardware chose.

    Calibrate on warm compute only: a sample that includes jit compilation
    poisons the fit.
    """

    def __init__(self, *, seed_base_s: float = 0.0,
                 seed_per_item_s: float = 0.0):
        if seed_base_s < 0 or seed_per_item_s < 0:
            raise ValueError("seed coefficients must be >= 0")
        self.seed_base_s = float(seed_base_s)
        self.seed_per_item_s = float(seed_per_item_s)
        self.base_s = self.seed_base_s
        self.per_item_s = self.seed_per_item_s
        self.samples: list[tuple[int, float]] = []
        self.frozen = False

    def observe(self, n_items: int, wall_s: float) -> None:
        if self.frozen:
            raise RuntimeError("frozen CalibratedCostModel takes no samples")
        self.samples.append((int(n_items), float(wall_s)))

    def duration_s(self, batch, measured_s: float) -> float:
        if self.frozen:
            return self.predict(batch.padded_size)
        self.observe(batch.padded_size, measured_s)
        return measured_s

    def predict(self, n_items: int) -> float:
        return self.base_s + self.per_item_s * n_items

    def fit(self) -> tuple[float, float]:
        """Closed-form least squares over the samples -> (base_s, per_item_s).

        Needs >= 2 distinct batch sizes to separate the intercept from the
        slope; with fewer, the seed slope is kept and only the intercept is
        adjusted to the sample mean."""
        if not self.samples:
            return self.base_s, self.per_item_s
        ns = [float(n) for n, _ in self.samples]
        ys = [y for _, y in self.samples]
        k = len(ns)
        n_mean = sum(ns) / k
        y_mean = sum(ys) / k
        var = sum((n - n_mean) ** 2 for n in ns)
        if var > 0:
            cov = sum((n - n_mean) * (y - y_mean) for n, y in zip(ns, ys))
            per_item = max(cov / var, 0.0)
        else:
            per_item = self.seed_per_item_s
        base = max(sum(y - per_item * n for n, y in zip(ns, ys)) / k, 0.0)
        self.base_s, self.per_item_s = base, per_item
        return base, per_item

    def freeze(self) -> "CalibratedCostModel":
        """Fit (if samples were collected) and pin the coefficients."""
        if not self.frozen:
            self.fit()
            self.frozen = True
        return self

    def fit_rel_err(self) -> float:
        """Mean |predicted - measured| / measured over the calibration
        samples — the acceptance gate asks this to stay within 0.25."""
        errs = [abs(self.predict(n) - y) / y for n, y in self.samples if y > 0]
        return sum(errs) / len(errs) if errs else 0.0


# ---------------------------------------------------------------------------
# Tickets
# ---------------------------------------------------------------------------

@dataclass
class ExecTicket:
    """One submitted micro-batch's journey through the cloud executor."""
    seq: int                     # submission order (deterministic tiebreak)
    batch: Any                   # serve.batcher.MicroBatch
    t_submit: float              # virtual time the gateway handed it over
    t_start: float               # virtual time its queue begins service
    t_done: float                # virtual completion time
    service_s: float             # virtual service duration (cost model)
    wall_s: float                # measured wall time of the real compute
    queue: int                   # queue index that served it
    logits: Any = None           # real compute output (set at submit)
    state: str = "queued"        # queued -> running -> done
    priority: int = 0            # batch priority class (max over requests)

    @property
    def queue_wait_s(self) -> float:
        return self.t_start - self.t_submit


@dataclass(frozen=True)
class RequestShed:
    """Explicit not-served outcome of admission control.

    Takes the response slot the request would have occupied, so callers see
    every submission end in exactly one of {response, shed} — never a silent
    drop. Telemetry keeps these in their own series (``Telemetry.shed``),
    separate from the served-latency percentiles."""
    req_id: int                  # per-tenant sequence number
    tenant: str
    t_submit: float
    reason: str                  # e.g. "token-bucket" / "queue-depth 8>=8"
    priority: int = 0

    @property
    def shed(self) -> bool:      # duck-type discriminator vs GatewayResponse
        return True


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------

@dataclass
class _Queue:
    rate: float                  # service-rate multiplier (1.0 = nominal)
    busy_until: float = 0.0
    depth: int = 0               # tickets submitted but not completed
    served: int = 0
    busy_s: float = 0.0          # integrated virtual service time
    last_key: Any = None         # plan bucket last served (affinity)
    last_priority: int | None = None   # priority class last served


class CloudExecutor:
    """Base class + protocol for cloud-side batch execution.

    The gateway binds ``run_fn`` (its batched decode+restore+forward) at
    construction; ``submit`` runs it inline (real compute, measured wall
    time) and plans ``t_start``/``t_done`` on the virtual clock. The event
    loop then replays those times as ``exec_start``/``exec_done`` events,
    calling :meth:`on_start` / :meth:`complete` so depth introspection — the
    signal admission control keys on — tracks the virtual clock exactly.
    """

    def __init__(self, *, queues: "list[_Queue]", cost: CostModel | None):
        if not queues:
            raise ValueError("executor needs at least one queue")
        self.cost = cost if cost is not None else MeasuredCost()
        self.run_fn: Callable | None = None
        self.metrics = None       # obs.MetricsRegistry: live depth gauges
        self._gauge_cache = None  # (registry, depth gauge, per-queue gauges)
        self._template = [q.rate for q in queues]
        self._queues = queues
        self._seq = 0
        self.history: list[ExecTicket] = []     # every ticket, submit order
        self._outstanding: dict[int, ExecTicket] = {}   # seq -> not-yet-done
        self.max_depth_seen = 0

    # -- lifecycle ----------------------------------------------------------
    def reset(self) -> None:
        """Back to an idle executor — serve runs replay bit-identically."""
        self._queues = [_Queue(rate=r) for r in self._template]
        self._seq = 0
        self.history = []
        self._outstanding = {}
        self.max_depth_seen = 0

    # -- introspection -------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Parallel service slots (number of queues)."""
        return len(self._queues)

    def depth(self) -> int:
        """Batches submitted but not yet completed (all queues)."""
        return sum(q.depth for q in self._queues)

    def queue_depths(self) -> list[int]:
        return [q.depth for q in self._queues]

    def busy_until(self) -> float:
        return max(q.busy_until for q in self._queues)

    def utilization(self, span_s: float) -> float:
        """Mean fraction of queue-seconds spent serving over ``span_s``."""
        if span_s <= 0:
            return 0.0
        return sum(q.busy_s for q in self._queues) / (
            span_s * len(self._queues))

    def _gauge_depths(self, queue: int) -> None:
        m = self.metrics
        if m is not None:
            # handle cache: submit/complete run per request, a registry
            # lookup per event would dominate the gauge update itself
            cache = self._gauge_cache
            if cache is None or cache[0] is not m:
                cache = self._gauge_cache = (
                    m, m.gauge("executor_depth"),
                    {i: m.gauge("executor_queue_depth", queue=i)
                     for i in range(len(self._queues))})
            cache[2][queue].set(self._queues[queue].depth)
            cache[1].set(self.depth())

    def export_metrics(self, registry=None, *, span_s: float | None = None):
        """Dump per-queue counters/gauges into an obs registry.

        ``span_s`` defaults to the virtual makespan of the run history, so
        ``executor_utilization`` reports busy-seconds per queue-second over
        the span actually served. Returns the registry written to."""
        m = registry if registry is not None else self.metrics
        if m is None:
            raise ValueError("no registry: pass one or set executor.metrics")
        if span_s is None:
            span_s = (max(t.t_done for t in self.history)
                      - min(t.t_submit for t in self.history)
                      if self.history else 0.0)
        for i, q in enumerate(self._queues):
            m.gauge("executor_queue_depth", queue=i).set(q.depth)
            m.gauge("executor_queue_served", queue=i).set(q.served)
            m.gauge("executor_queue_busy_seconds", queue=i).set(q.busy_s)
        m.gauge("executor_depth").set(self.depth())
        m.gauge("executor_max_depth_seen").set(self.max_depth_seen)
        m.gauge("executor_utilization").set(self.utilization(span_s))
        return m

    # -- queue selection -----------------------------------------------------
    def _select_queue(self, batch, t_ready: float,
                      duration: float) -> tuple[int, float, float]:
        """Work-conserving pick: earliest finish; ties broken by plan-bucket
        affinity, then priority affinity, then index.

        The priority tie-break (TenantSpec.priority, carried on the batch)
        prefers a queue that last served this batch's priority class — under
        contention, priority classes settle onto disjoint queues, so
        best-effort churn stops evicting the premium class's bucket
        affinity. A fresh queue (``last_priority`` None) matches every
        class, and when all traffic shares one priority every queue matches
        always, so the rank ordering reduces exactly to the pre-priority
        ``(done, affinity, index)`` — equal-priority workloads replay
        bit-identically.
        """
        key = getattr(batch, "key", None)
        priority = int(getattr(batch, "priority", 0))
        best = None
        for i, q in enumerate(self._queues):
            start = max(t_ready, q.busy_until)
            dur = duration / q.rate
            done = start + dur
            affinity = 0 if (key is not None and q.last_key == key) else 1
            prio_tie = 0 if q.last_priority in (None, priority) else 1
            rank = (done, affinity, prio_tie, i)
            if best is None or rank < best[0]:
                best = (rank, i, start, dur)
        _, i, start, dur = best
        return i, start, dur

    # -- protocol ------------------------------------------------------------
    def _plan_duration(self, batch, wall_s: float) -> float:
        """Virtual service duration for one batch. Subclass hook — the mesh
        executor evaluates the cost model at its per-shard row count."""
        return self.cost.duration_s(batch, wall_s)

    def submit(self, batch, t_ready: float, *,
               run_fn: Callable | None = None) -> ExecTicket:
        """Run the real compute and plan the batch onto the virtual clock.

        ``run_fn`` overrides the bound callable for this submission — how
        federated gateways share one executor while each supplying their own
        batched decode+restore+forward."""
        run = run_fn if run_fn is not None else self.run_fn
        if run is None:
            raise RuntimeError("executor has no bound run_fn (the gateway "
                               "binds its batched decode+restore+forward at "
                               "construction)")
        logits, wall_s = run(batch)
        duration = self._plan_duration(batch, wall_s)
        i, start, dur = self._select_queue(batch, t_ready, duration)
        q = self._queues[i]
        q.busy_until = start + dur
        q.busy_s += dur
        q.depth += 1
        q.last_key = getattr(batch, "key", None)
        q.last_priority = int(getattr(batch, "priority", 0))
        ticket = ExecTicket(seq=self._seq, batch=batch, t_submit=t_ready,
                            t_start=start, t_done=start + dur,
                            service_s=dur, wall_s=wall_s, queue=i,
                            logits=logits, priority=q.last_priority)
        self._seq += 1
        self.history.append(ticket)
        self._outstanding[ticket.seq] = ticket
        self.max_depth_seen = max(self.max_depth_seen, self.depth())
        self._gauge_depths(i)
        return ticket

    def on_start(self, ticket: ExecTicket) -> None:
        """The ``exec_start`` event: the queue begins serving this batch."""
        ticket.state = "running"

    def complete(self, ticket: ExecTicket) -> None:
        """The ``exec_done`` event: service finished, slot freed.

        Releases the ticket's payload references (batch, logits) — consume
        them *before* completing, or memory grows with the whole workload
        instead of with what is in flight. Timing fields survive for
        post-run introspection (``history`` makespans, replay audits)."""
        if ticket.state == "done":
            raise RuntimeError(f"ticket {ticket.seq} completed twice")
        ticket.state = "done"
        ticket.batch = None
        ticket.logits = None
        self._outstanding.pop(ticket.seq, None)
        q = self._queues[ticket.queue]
        q.depth -= 1
        q.served += 1
        self._gauge_depths(ticket.queue)

    def poll(self, now: float) -> list[ExecTicket]:
        """Tickets whose virtual completion time has passed, in completion
        order — the same order the gateways' exec_done events fire in.
        Scans only outstanding tickets, not the whole run history."""
        out = [t for t in self._outstanding.values() if t.t_done <= now]
        return sorted(out, key=lambda t: (t.t_done, t.seq))

    def drain(self) -> list[ExecTicket]:
        """Every ticket still outstanding, in completion order."""
        return sorted(self._outstanding.values(),
                      key=lambda t: (t.t_done, t.seq))


class SerialExecutor(CloudExecutor):
    """One queue, measured cost by default — the old inline serial cloud."""

    def __init__(self, *, cost: CostModel | None = None):
        super().__init__(queues=[_Queue(rate=1.0)], cost=cost)


class MultiQueueExecutor(CloudExecutor):
    """N parallel queues with per-queue service rates.

    ``rates`` scales each queue's speed (duration / rate); defaults to
    1.0 everywhere. Queue selection is work-conserving and deterministic
    (see :meth:`CloudExecutor._select_queue`)."""

    def __init__(self, n_queues: int = 4, *,
                 rates: "list[float] | tuple[float, ...] | None" = None,
                 cost: CostModel | None = None):
        if n_queues < 1:
            raise ValueError(f"n_queues must be >= 1, got {n_queues}")
        if rates is None:
            rates = [1.0] * n_queues
        rates = [float(r) for r in rates]
        if len(rates) != n_queues:
            raise ValueError(f"{len(rates)} rates for {n_queues} queues")
        if any(r <= 0 for r in rates):
            raise ValueError(f"service rates must be > 0, got {rates}")
        super().__init__(queues=[_Queue(rate=r) for r in rates], cost=cost)


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AdmissionDecision:
    admitted: bool
    reason: str = ""             # set when shed ("" when admitted)


class AdmissionPolicy:
    """Decides, per submission, whether the cloud takes the request.

    Called by the multi-tenant event loop *before* any edge compute or
    encoding is spent on the request. Policies are deterministic functions
    of (tenant, priority, virtual time, executor state); ``reset()`` returns
    them to their initial state so serve runs replay bit-identically."""

    def reset(self) -> None:
        pass

    def admit(self, *, tenant: str, priority: int, t: float,
              executor: CloudExecutor) -> AdmissionDecision:
        raise NotImplementedError


class AlwaysAdmit(AdmissionPolicy):
    def admit(self, *, tenant, priority, t, executor) -> AdmissionDecision:
        return AdmissionDecision(True)


class TokenBucketAdmission(AdmissionPolicy):
    """Per-tenant request-rate token bucket.

    Each tenant's bucket refills at ``rate_per_s`` tokens/second up to
    ``burst``; a submission spends one token or is shed. ``per_tenant``
    overrides ``(rate_per_s, burst)`` for named tenants (e.g. a premium
    tier with a deeper bucket)."""

    def __init__(self, rate_per_s: float, burst: float, *,
                 per_tenant: "dict[str, tuple[float, float]] | None" = None):
        if rate_per_s <= 0 or burst <= 0:
            raise ValueError("token bucket needs rate_per_s > 0, burst > 0")
        self.rate_per_s = float(rate_per_s)
        self.burst = float(burst)
        self.per_tenant = dict(per_tenant or {})
        for name, (r, b) in self.per_tenant.items():
            if r <= 0 or b <= 0:
                raise ValueError(f"tenant {name!r}: rate/burst must be > 0")
        self._state: dict[str, tuple[float, float]] = {}  # (tokens, last_t)

    def reset(self) -> None:
        self._state.clear()

    def _params(self, tenant: str) -> tuple[float, float]:
        return self.per_tenant.get(tenant, (self.rate_per_s, self.burst))

    def admit(self, *, tenant, priority, t, executor) -> AdmissionDecision:
        rate, burst = self._params(tenant)
        tokens, last = self._state.get(tenant, (burst, t))
        tokens = min(burst, tokens + rate * max(t - last, 0.0))
        if tokens >= 1.0:
            self._state[tenant] = (tokens - 1.0, t)
            return AdmissionDecision(True)
        self._state[tenant] = (tokens, t)
        return AdmissionDecision(
            False, f"token-bucket: tenant {tenant!r} over {rate:g} req/s "
                   f"(burst {burst:g})")


class QueueDepthAdmission(AdmissionPolicy):
    """Shed when the executor backlog reaches this priority's depth limit.

    ``max_depth`` is the limit for any priority without an explicit entry in
    ``per_priority``. Give higher priorities larger limits and shedding is
    priority-ordered by construction: at any backlog, if a high-priority
    request is shed, every lower-priority request is too (brown-out: best
    effort goes first, premium last)."""

    def __init__(self, max_depth: int, *,
                 per_priority: "dict[int, int] | None" = None):
        if max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        self.max_depth = int(max_depth)
        self.per_priority = {int(k): int(v)
                             for k, v in (per_priority or {}).items()}
        if any(v < 1 for v in self.per_priority.values()):
            raise ValueError("per-priority depth limits must be >= 1")

    def limit_for(self, priority: int) -> int:
        return self.per_priority.get(int(priority), self.max_depth)

    def admit(self, *, tenant, priority, t, executor) -> AdmissionDecision:
        limit = self.limit_for(priority)
        depth = executor.depth()
        if depth < limit:
            return AdmissionDecision(True)
        return AdmissionDecision(
            False, f"queue-depth {depth}>={limit} (priority {priority})")


class CompositeAdmission(AdmissionPolicy):
    """All sub-policies must admit; the first rejection's reason wins.

    Evaluation short-circuits, so a request shed by an earlier policy never
    spends a later policy's tokens."""

    def __init__(self, policies: "list[AdmissionPolicy]"):
        if not policies:
            raise ValueError("composite admission needs >= 1 policy")
        self.policies = list(policies)

    def reset(self) -> None:
        for p in self.policies:
            p.reset()

    def admit(self, *, tenant, priority, t, executor) -> AdmissionDecision:
        for p in self.policies:
            d = p.admit(tenant=tenant, priority=priority, t=t,
                        executor=executor)
            if not d.admitted:
                return d
        return AdmissionDecision(True)


def priority_depth_limits(base: int, priorities, *,
                          headroom: int | None = None) -> dict[int, int]:
    """Monotone per-priority limits: priority p gets ``base + p*headroom``.

    Convenience for :class:`QueueDepthAdmission` — guarantees the
    shed-priority ordering property (limits non-decreasing in priority).
    ``headroom`` defaults to ``base``."""
    if base < 1:
        raise ValueError("base depth must be >= 1")
    step = base if headroom is None else int(headroom)
    if step < 0:
        raise ValueError("headroom must be >= 0")
    return {int(p): base + int(p) * step for p in priorities}
