"""Entropy-coding subsystem: context-adaptive interleaved rANS.

The real coder behind the wire codec's ``rans`` / ``rans-ctx`` backends
(core/codec.py). Layers, bottom to top:

  * ``rans.py``      — interleaved multi-stream rANS core (numpy-vectorized
                       over lanes, bit-exact round-trip, normalized tables)
  * ``context.py``   — adaptive quantized-up-neighbor/channel context model
                       (nothing transmitted; decoder mirrors adaptation)
  * ``container.py`` — versioned bitstream container with per-tile chunks,
                       partial decode, and distinct corruption errors
  * ``backend.py``   — tensor-level adapters registered with core/codec.py
  * ``batch.py``     — cross-container batched decode: chunks of a whole
                       micro-batch share one interleaved decode loop
                       (bit-identical to the per-blob path)

Symbol statistics for static tables are computed on device by the Pallas
histogram/CDF kernels (repro.kernels.histogram).
"""
from repro.codec.backend import (decode_channels, decode_tensor,
                                 encode_adaptive_tensor, encode_static_tensor)
from repro.codec.batch import decode_tensor_batch
from repro.codec.container import RansContainer
from repro.codec.context import decode_ctx, encode_ctx, plan_lanes
from repro.codec.rans import (CorruptStream, RansTable, normalize_freqs,
                              rans_decode, rans_encode)

__all__ = [
    "CorruptStream", "RansContainer", "RansTable",
    "decode_channels", "decode_ctx", "decode_tensor", "decode_tensor_batch",
    "encode_adaptive_tensor", "encode_ctx", "encode_static_tensor",
    "normalize_freqs", "plan_lanes", "rans_decode", "rans_encode",
]
