"""Context-adaptive rANS modeling for BaF residual tiles.

The static backend transmits one frequency table per channel; for small
tiles that table blob dominates the payload. This model transmits *nothing*:
encoder and decoder run the same deterministic adaptation, so the only
per-chunk overhead is the lane states.

Model
-----
  * context = the quantized **up-neighbor**: the symbol one tile row above,
    bucketed to its top ``CTX_BITS`` bits (BaF residual tiles are spatially
    smooth, so the up-neighbor's coarse magnitude is a strong predictor of
    the current symbol's distribution), plus one extra bucket for positions
    with no neighbor (first row / flat streams). Channels are separate
    chunks, so the model is per-channel by construction — the
    "quantized-neighbor/channel" context.
  * adaptation = per-context symbol counts start uniform and increment with
    every coded symbol; frequency tables are renormalized every
    ``refresh_every`` interleave steps (not every symbol) so table rebuilds
    amortize while the model still tracks local statistics.

Lane causality: with ``lanes <= neighbor_dist`` the up-neighbor of every
symbol in step t was decoded in a strictly earlier step, so the decoder can
compute all N lane contexts with one gather before decoding the step — the
same vectorized loop shape as the static coder. ``plan_lanes`` enforces
this; when the stream has no usable row structure the model degrades to a
single-context adaptive order-0 coder.
"""
from __future__ import annotations

import numpy as np

from repro.codec.rans import (RANS_L, WORD_BITS, CorruptStream,
                              normalize_freqs, pad_to_lanes, rans_encode)

CTX_BITS = 2                 # context buckets = 2^CTX_BITS (+1 "no neighbor")
PROB_BITS_CTX = 12           # floor; see ctx_prob_bits
MAX_PROB_BITS_CTX = 15
DEFAULT_LANES = 8
REFRESH_SYMBOLS = 128        # rebuild tables roughly this often
COUNT_INCREMENT = 32         # adaptation speed: observed mass per symbol vs
                             # the uniform prior mass of 1 per alphabet entry

_U64 = np.uint64


def plan_lanes(count: int, neighbor_dist: int) -> int:
    """Lane count compatible with the up-neighbor context.

    Needs ``lanes <= neighbor_dist`` so contexts come from earlier steps;
    a degenerate ``neighbor_dist`` (< 2) keeps vector lanes but drops the
    neighbor context (callers pass neighbor_dist=0 then).
    """
    if count <= 0:
        return 1
    cap = neighbor_dist if neighbor_dist >= 2 else DEFAULT_LANES
    return max(1, min(DEFAULT_LANES, cap, count))


def ctx_prob_bits(bits: int) -> int:
    """Probability resolution for the adaptive model at this bit depth.

    Must exceed the alphabet size by a margin: at prob_bits == bits every
    frequency is pinned to the min of 1 (uniform — no compression at all),
    so wide alphabets get 2 extra bits of headroom. Encoder and decoder
    derive this identically from ``bits``; the container header records it.
    """
    return min(MAX_PROB_BITS_CTX, max(PROB_BITS_CTX, bits + 2))


def _n_ctx(bits: int) -> int:
    return (1 << min(CTX_BITS, bits)) + 1      # + the "no neighbor" bucket


def _ctx_shift(bits: int) -> int:
    return max(0, bits - CTX_BITS)


def refresh_due(t: int, refresh_every: int) -> bool:
    """Table-refresh schedule: exponential early (steps 1, 2, 4, 8, …) so
    the model escapes the uniform prior quickly, then periodic. ONE source
    of truth — the scalar model and the cross-container batch decoder
    (repro.codec.batch) must refresh on identical steps or decode diverges
    from encode."""
    if t == 0:
        return False                     # initial tables already built
    if t < refresh_every:
        return t & (t - 1) == 0          # powers of two
    return t % refresh_every == 0


def rebuild_tables(counts: np.ndarray, prob_bits: int, freqs_out: np.ndarray,
                   cums_out: np.ndarray) -> None:
    """Renormalize per-context counts (nctx, nsym) into frequency +
    exclusive-cumulative tables, written in place. Shared by the scalar
    model and the batch decoder so the adaptation math cannot fork."""
    for cx in range(counts.shape[0]):
        f = normalize_freqs(counts[cx], prob_bits)
        freqs_out[cx] = f
        cums_out[cx] = np.cumsum(f, dtype=np.uint64) - f


class _AdaptiveModel:
    """Shared encoder/decoder adaptation state (identical on both sides)."""

    def __init__(self, bits: int, lanes: int):
        self.nsym = 1 << bits
        self.nctx = _n_ctx(bits)
        self.shift = _ctx_shift(bits)
        self.prob_bits = ctx_prob_bits(bits)
        self.counts = np.ones((self.nctx, self.nsym), np.int64)
        self.refresh_every = max(1, REFRESH_SYMBOLS // lanes)
        self.freqs = np.empty((self.nctx, self.nsym), np.uint32)
        self.cums = np.empty((self.nctx, self.nsym), np.uint32)
        self.rebuild()

    def rebuild(self) -> None:
        rebuild_tables(self.counts, self.prob_bits, self.freqs, self.cums)

    def refresh_due(self, t: int) -> bool:
        return refresh_due(t, self.refresh_every)

    def contexts(self, idx: np.ndarray, stream: np.ndarray,
                 neighbor_dist: int) -> np.ndarray:
        """Context bucket per symbol index, gathered from decoded history."""
        if neighbor_dist < 1:
            return np.full(idx.size, self.nctx - 1, np.int64)
        nb = idx - neighbor_dist
        has = nb >= 0
        ctx = np.full(idx.size, self.nctx - 1, np.int64)
        ctx[has] = stream[nb[has]].astype(np.int64) >> self.shift
        return ctx

    def update(self, ctx: np.ndarray, syms: np.ndarray) -> None:
        np.add.at(self.counts, (ctx, syms.astype(np.int64)), COUNT_INCREMENT)


def _normalize_neighbor(lanes: int, neighbor_dist: int) -> int:
    """The up-neighbor context is usable only when every lane's neighbor
    comes from an earlier interleave step (lanes <= dist); anything else
    degrades to the single-context adaptive order-0 model. Encoder and
    decoder apply the same rule, so the geometry is consistent by
    construction."""
    return neighbor_dist if neighbor_dist >= lanes else 0


def encode_ctx(symbols: np.ndarray, bits: int, lanes: int,
               neighbor_dist: int) -> tuple[np.ndarray, bytes]:
    """Adaptive encode: forward model pass gathers per-symbol (f, c), then
    the model-agnostic reverse rANS pass codes them."""
    symbols = np.asarray(symbols, np.uint32).reshape(-1)
    if symbols.size == 0:
        return np.full(lanes, RANS_L, "<u4"), b""
    neighbor_dist = _normalize_neighbor(lanes, neighbor_dist)
    padded = pad_to_lanes(symbols, lanes, 0)
    steps = padded.size // lanes
    model = _AdaptiveModel(bits, lanes)
    f = np.empty(padded.size, np.uint32)
    c = np.empty(padded.size, np.uint32)
    base = np.arange(lanes, dtype=np.int64)
    for t in range(steps):
        if model.refresh_due(t):
            model.rebuild()
        idx = t * lanes + base
        ctx = model.contexts(idx, padded, neighbor_dist)
        s = padded[idx]
        f[idx] = model.freqs[ctx, s]
        c[idx] = model.cums[ctx, s]
        model.update(ctx, s)
    return rans_encode(f, c, model.prob_bits, lanes)


def decode_ctx(states: np.ndarray, words: bytes, count: int, bits: int,
               lanes: int, neighbor_dist: int) -> np.ndarray:
    """Mirror of :func:`encode_ctx`: identical adaptation, forward decode."""
    if lanes < 1 or states.size != lanes:
        raise CorruptStream(
            f"expected {lanes} lane states, got {states.size}")
    neighbor_dist = _normalize_neighbor(lanes, neighbor_dist)
    if count == 0:
        if len(words):
            raise CorruptStream("nonempty word stream for an empty chunk")
        return np.empty(0, np.uint32)
    steps = -(-count // lanes)
    model = _AdaptiveModel(bits, lanes)
    mask = _U64((1 << model.prob_bits) - 1)
    pb = _U64(model.prob_bits)
    w = np.frombuffer(words, "<u2")
    x = states.astype(_U64)
    out = np.empty(steps * lanes, np.uint32)
    base = np.arange(lanes, dtype=np.int64)
    slot_tables = None
    ptr = 0
    for t in range(steps):
        if slot_tables is None or model.refresh_due(t):
            if t:
                model.rebuild()
            slot_tables = np.empty((model.nctx, 1 << model.prob_bits),
                                   np.uint32)
            for ctx in range(model.nctx):
                slot_tables[ctx] = np.repeat(
                    np.arange(model.nsym, dtype=np.uint32),
                    model.freqs[ctx])
        idx = t * lanes + base
        ctx = model.contexts(idx, out, neighbor_dist)
        slot = x & mask
        s = slot_tables[ctx, slot]
        x = (model.freqs[ctx, s].astype(_U64) * (x >> pb)
             + slot - model.cums[ctx, s].astype(_U64))
        need = x < _U64(RANS_L)
        nneed = int(np.count_nonzero(need))
        if nneed:
            if ptr + nneed > w.size:
                raise CorruptStream(
                    f"rANS word stream truncated: needed {ptr + nneed} "
                    f"words, have {w.size}")
            x[need] = (x[need] << _U64(WORD_BITS)) | w[ptr:ptr + nneed]
            ptr += nneed
        out[idx] = s
        model.update(ctx, s)
    if ptr != w.size:
        raise CorruptStream(
            f"rANS word stream has {w.size - ptr} unread trailing words")
    if not bool(np.all(x == _U64(RANS_L))):
        raise CorruptStream(
            "rANS lane states did not return to initial value "
            "(corrupt payload)")
    return out[:count]
