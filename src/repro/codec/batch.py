"""Cross-container batched rANS decode — chunk-level interleave.

``decode_many`` used to fall back to a per-blob loop for the rANS backends:
each container's chunks decode one after another, and every chunk pays the
full python-loop overhead of its ``steps = count / lanes`` interleave steps
at a vector width of only ``lanes`` (often 2-8 on small BaF tiles). A
micro-batch bucket of N same-shape containers therefore runs
``N * C * steps`` tiny numpy dispatches.

This module coalesces the interleave across *all* chunks of *all*
containers in the batch: chunks with identical coding geometry (lanes,
probability resolution, symbol count, context distance) stack into one
``(M, lanes)`` state matrix and the decode loop runs ``steps`` iterations
total at vector width ``M * lanes`` — each chunk still consumes its own
word stream through a per-row pointer, so outputs are bit-identical to the
per-blob decoder (the batched pipeline's hard invariant).

Static-table chunks and adaptive-context chunks batch separately; within
the adaptive group the per-chunk adaptation state (context counts, tables)
carries a leading batch axis and refreshes on the same schedule as the
scalar model, so encoder/decoder symmetry is preserved by construction.

All integrity checks of the scalar path run here too: container/chunk CRCs
(via ``RansContainer.chunk_parts``), word-stream exhaustion, and the
lane-state return-to-initial check, each raising :class:`CorruptStream`.
"""
from __future__ import annotations

import numpy as np

from repro.codec import container as box
from repro.codec import context as ctx
from repro.codec.backend import _chunk_layout
from repro.codec.rans import RANS_L, WORD_BITS, CorruptStream, RansTable
from repro.obs import hooks

_U64 = np.uint64


def _pad_words(jobs_words: "list[bytes]") -> tuple[np.ndarray, np.ndarray]:
    """Stack ragged word streams -> (padded (M, W) uint16, lengths (M,))."""
    rows = [np.frombuffer(w, "<u2") for w in jobs_words]
    wlen = np.array([r.size for r in rows], np.int64)
    out = np.zeros((len(rows), int(wlen.max()) if len(rows) else 0),
                   np.uint16)
    for r, row in enumerate(rows):
        out[r, :row.size] = row
    return out, wlen


def _renorm(x, need, words, ptr, wlen):
    """One shared renormalization step: rows gather their own next words."""
    nneed = need.sum(axis=1)
    if nneed.any():
        if np.any(ptr + nneed > wlen):
            bad = int(np.argmax(ptr + nneed > wlen))
            raise CorruptStream(
                f"rANS word stream truncated in batch row {bad}: needed "
                f"{int(ptr[bad] + nneed[bad])} words, have {int(wlen[bad])}")
        idx = ptr[:, None] + np.cumsum(need, axis=1) - 1
        rowi = np.arange(x.shape[0])[:, None]
        w = words[rowi, np.where(need, idx, 0)]
        x = np.where(need, (x << _U64(WORD_BITS)) | w.astype(_U64), x)
        ptr += nneed
    return x, ptr


def _finish_checks(x, ptr, wlen):
    if np.any(ptr != wlen):
        bad = int(np.argmax(ptr != wlen))
        raise CorruptStream(
            f"rANS word stream has {int(wlen[bad] - ptr[bad])} unread "
            f"trailing words in batch row {bad}")
    if not bool(np.all(x == _U64(RANS_L))):
        raise CorruptStream(
            "rANS lane states did not return to initial value "
            "(corrupt payload)")


def _slot_lookup(slot: np.ndarray, cums_rows: np.ndarray) -> np.ndarray:
    """Slot -> symbol without materializing 2^prob_bits lookup tables.

    ``cums_rows`` is each row's exclusive cumulative-frequency array; the
    decoded symbol is the last one whose cum <= slot. The scalar coder
    answers this with a ``(1 << prob_bits)``-entry table — thousands of
    entries per symbol decoded on small tiles, the dominant cost of the
    per-blob loop. The broadcast count over the S-symbol alphabet is
    bit-identical and O(S) per lane instead of O(2^prob_bits) per table."""
    return (np.sum(slot[..., None] >= cums_rows, axis=-1) - 1).astype(
        np.int64)


def _decode_static_group(jobs, count: int, prob_bits: int,
                         lanes: int) -> np.ndarray:
    """jobs: [(states, words bytes, freq table (S,) array)] -> (M, count)."""
    m = len(jobs)
    steps = -(-count // lanes)
    tables = [RansTable(freqs=np.asarray(t, np.uint32), prob_bits=prob_bits)
              for _, _, t in jobs]
    freqs = np.stack([t.freqs for t in tables]).astype(_U64)
    cums = np.stack([t.cum for t in tables]).astype(_U64)
    x = np.stack([np.asarray(s) for s, _, _ in jobs]).astype(_U64)
    words, wlen = _pad_words([w for _, w, _ in jobs])
    mask = _U64((1 << prob_bits) - 1)
    pb = _U64(prob_bits)
    ptr = np.zeros(m, np.int64)
    rowi = np.arange(m)[:, None]
    out = np.empty((m, steps * lanes), np.uint32)
    cums_b = cums[:, None, :]                      # (M, 1, S) for the lookup
    for t in range(steps):
        slot = x & mask
        s = _slot_lookup(slot, cums_b)
        out[:, t * lanes:(t + 1) * lanes] = s
        x = freqs[rowi, s] * (x >> pb) + slot - cums[rowi, s]
        x, ptr = _renorm(x, x < _U64(RANS_L), words, ptr, wlen)
    _finish_checks(x, ptr, wlen)
    return out[:, :count]


def _decode_adaptive_group(jobs, count: int, bits: int, lanes: int,
                           neighbor_dist: int) -> np.ndarray:
    """jobs: [(states, words bytes)] -> (M, count), adaptive context model.

    The batch axis rides in front of the scalar model's state
    (``counts/freqs/cums/slot_tables``); adaptation math and the refresh
    schedule are the scalar model's, row for row, so every row decodes
    exactly as the per-blob path would."""
    m = len(jobs)
    neighbor_dist = ctx._normalize_neighbor(lanes, neighbor_dist)
    steps = -(-count // lanes)
    nsym = 1 << bits
    nctx = ctx._n_ctx(bits)
    shift = ctx._ctx_shift(bits)
    prob_bits = ctx.ctx_prob_bits(bits)
    refresh_every = max(1, ctx.REFRESH_SYMBOLS // lanes)
    counts = np.ones((m, nctx, nsym), np.int64)
    freqs = np.empty((m, nctx, nsym), np.uint64)
    cums = np.empty((m, nctx, nsym), np.uint64)

    def rebuild_freqs():
        # the scalar model's own rebuild, once per batch row — adaptation
        # math stays single-sourced in repro.codec.context
        for r in range(m):
            ctx.rebuild_tables(counts[r], prob_bits, freqs[r], cums[r])

    rebuild_freqs()
    x = np.stack([np.asarray(s) for s, _ in jobs]).astype(_U64)
    words, wlen = _pad_words([w for _, w in jobs])
    mask = _U64((1 << prob_bits) - 1)
    pb = _U64(prob_bits)
    ptr = np.zeros(m, np.int64)
    rowi = np.arange(m)[:, None]
    base = np.arange(lanes, dtype=np.int64)
    out = np.empty((m, steps * lanes), np.uint32)
    for t in range(steps):
        if t and ctx.refresh_due(t, refresh_every):
            rebuild_freqs()
        idx = t * lanes + base
        if neighbor_dist < 1:
            cxv = np.full((m, lanes), nctx - 1, np.int64)
        else:
            nb = idx - neighbor_dist
            has = nb >= 0
            cxv = np.full((m, lanes), nctx - 1, np.int64)
            cxv[:, has] = out[:, nb[has]].astype(np.int64) >> shift
        slot = x & mask
        s = _slot_lookup(slot, cums[rowi, cxv])
        x = freqs[rowi, cxv, s] * (x >> pb) + slot - cums[rowi, cxv, s]
        x, ptr = _renorm(x, x < _U64(RANS_L), words, ptr, wlen)
        out[:, idx] = s
        np.add.at(counts, (rowi, cxv, s), ctx.COUNT_INCREMENT)
    _finish_checks(x, ptr, wlen)
    return out[:, :count]


def decode_tensor_batch(payloads: "list[bytes]", shape: tuple,
                        bits: int) -> np.ndarray:
    """Decode N same-shape containers -> (N, prod(shape)) channel-last rows.

    The backend's ``decode_batch`` hook (core/codec.py registry): output
    row i equals ``decode_tensor(payloads[i], shape, bits).ravel()`` bit for
    bit, but all compatible chunks across the whole batch share one
    interleaved decode loop."""
    with hooks.timed("codec.decode_batch"):
        return _decode_tensor_batch(payloads, shape, bits)


def _decode_tensor_batch(payloads: "list[bytes]", shape: tuple,
                         bits: int) -> np.ndarray:
    shape = tuple(shape)
    n_ch, k, _ = _chunk_layout(shape)
    count_total = int(np.prod(shape)) if shape else 1
    conts = [box.RansContainer.parse(p) for p in payloads]
    for cont in conts:
        h = cont.header
        if h.bits != bits:
            raise CorruptStream(
                f"container codes {h.bits} bits, wire header says {bits}")
        if h.n_chunks != n_ch:
            raise CorruptStream(
                f"container has {h.n_chunks} tile chunks, shape {shape} "
                f"needs {n_ch}")
        # symbol-count validation runs before the zero-size shortcut, like
        # the scalar decoder — a chunk claiming symbols for an empty shape
        # is corrupt, not ignorable
        for j in range(h.n_chunks):
            if cont.chunk_count(j) != k:
                raise CorruptStream(
                    f"chunk {j} holds {cont.chunk_count(j)} symbols, "
                    f"shape {shape} needs {k}")
    n = len(conts)
    if n_ch == 0 or k == 0:
        return np.zeros((n, count_total), np.uint32)
    mats = np.empty((n, n_ch, k), np.uint32)
    # group chunks by coding geometry; each group shares one decode loop
    static_groups: dict = {}
    adaptive_groups: dict = {}
    for i, cont in enumerate(conts):
        h = cont.header
        for j in range(h.n_chunks):
            _count, states, words = cont.chunk_parts(j)   # CRC-verified
            if h.mode == box.MODE_STATIC:
                key = (h.prob_bits, h.lanes)
                static_groups.setdefault(key, []).append(
                    ((i, j), (states, words, cont.chunk_table(j))))
            else:
                key = (h.lanes, h.neighbor_dist)
                adaptive_groups.setdefault(key, []).append(
                    ((i, j), (states, words)))
    trace_lanes = hooks.enabled()
    for (prob_bits, lanes), entries in static_groups.items():
        if trace_lanes:
            # effective interleave width: all grouped chunks' lanes decode
            # in one vector pass (the whole point of the batched path)
            hooks.observe("codec_rans_batch_width", len(entries) * lanes,
                          mode="static")
        rows = _decode_static_group([job for _, job in entries], k,
                                    prob_bits, lanes)
        for (i, j), row in zip((pos for pos, _ in entries), rows):
            mats[i, j] = row
    for (lanes, neighbor), entries in adaptive_groups.items():
        if trace_lanes:
            hooks.observe("codec_rans_batch_width", len(entries) * lanes,
                          mode="adaptive")
        rows = _decode_adaptive_group([job for _, job in entries], k, bits,
                                      lanes, neighbor)
        for (i, j), row in zip((pos for pos, _ in entries), rows):
            mats[i, j] = row
    # channel-last reassembly, one transpose over the whole stack
    return np.ascontiguousarray(
        mats.transpose(0, 2, 1)).reshape(n, count_total)
