"""Tensor-level rANS backends for the wire codec (core/codec.py registry).

Maps a channel-last code tensor onto the container's per-tile chunks:

  * chunk i = channel i's symbols in raster order over the leading axes
    (for a (B, H, W, C) BaF residual tensor: all of channel i, batch-major);
  * ``neighbor_dist = shape[-2]`` so the adaptive model's lane-strided
    context is exactly the up-neighbor inside each tile row structure;
  * ``rans``     — static per-channel frequency tables. Symbol statistics
    come from the on-device histogram kernel (kernels/histogram.py); tables
    travel in the container's zlib'd table blob. Encoder picks per-channel
    tables or one shared pooled table, whichever yields fewer wire bytes
    (small tiles can't amortize C tables) — the choice is recorded per
    container by simply repeating the pooled table, so the decoder never
    special-cases it.
  * ``rans-ctx`` — context-adaptive, nothing transmitted but lane states.

Unlike the image-codec backends, rANS needs no tiled 2D image: the tensor is
coded directly and the tiling step is skipped (core/split.py).
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.codec import container as box
from repro.codec import context as ctx
from repro.obs import hooks
from repro.codec.rans import (MAX_PROB_BITS, CorruptStream, RansTable,
                              encode_static, normalize_freqs)

MAX_BITS = 12                # slot tables are 2^prob_bits; keep them sane
STATIC_LANES = 32
PROB_BITS_STATIC = 14


def _chunk_layout(shape: tuple) -> tuple[int, int, int]:
    """(n_chunks, symbols per chunk, up-neighbor distance) for a shape.

    Channel-last for >= 2-D tensors; a 1-D/0-D stream is a SINGLE chunk
    (treating each element of a flat array as its own channel would emit a
    chunk header + lane states per element — a 14x blowup).
    """
    if len(shape) >= 2:
        c = shape[-1]
        k = int(np.prod(shape[:-1]))
        return c, k, shape[-2]
    k = shape[0] if shape else 1
    return (1 if k else 0), k, 0


def _as_symbol_matrix(codes: np.ndarray, bits: int) -> tuple[np.ndarray, int]:
    """(..., C) -> (C, K) uint32 symbol streams + up-neighbor distance."""
    arr = np.asarray(codes)
    if not 1 <= bits <= MAX_BITS:
        raise ValueError(f"rans backends support 1..{MAX_BITS} bits, "
                         f"got {bits}")
    if arr.size:
        amin, amax = int(arr.min()), int(arr.max())
        if amin < 0:
            raise ValueError(f"rans backend: negative code {amin}")
        if amax >= 1 << bits:
            raise ValueError(f"rans backend: code {amax} does not fit "
                             f"{bits} bits")
    c, _k, neighbor = _chunk_layout(arr.shape)
    mat = arr.reshape(-1, c).T.astype(np.uint32) if c else \
        np.empty((0, 0), np.uint32)
    return np.ascontiguousarray(mat), neighbor


def _expected_payload_bits(counts: np.ndarray, tables: list[RansTable],
                           prob_bits: int) -> float:
    """Cross-entropy estimate of the coded size of each chunk under its
    table: sum_s counts[s] * (prob_bits - log2(freq[s])). rANS realizes
    this within ~1%, which is plenty to pick a table layout without coding."""
    total = 0.0
    for i, t in enumerate(tables):
        f = t.freqs.astype(np.float64)
        total += float(np.sum(counts[i] * (prob_bits - np.log2(f))))
    return total


def encode_static_tensor(codes: np.ndarray, bits: int) -> bytes:
    """The ``rans`` backend: per-channel (or pooled) static tables."""
    with hooks.timed("codec.encode", mode="static"):
        return _encode_static_tensor(codes, bits)


def _encode_static_tensor(codes: np.ndarray, bits: int) -> bytes:
    from repro.kernels.histogram import channel_histogram

    mat, _ = _as_symbol_matrix(codes, bits)
    n_ch, k = mat.shape
    counts = channel_histogram(mat.T, bits)       # (K, C): chunk layout

    # scale lanes with the chunk's expected *compressed* size: each lane
    # costs 4 bytes of state on the wire, so a heavily skewed (low-entropy)
    # chunk takes fewer lanes — target <= ~6% state overhead — while long
    # high-entropy chunks take the full vector width
    total = counts.sum(axis=1, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        p = counts / np.maximum(total, 1)
        ent_bits = float(-(counts * np.where(p > 0, np.log2(p, where=p > 0),
                                             0.0)).sum())
    payload_guess = max(1, int(ent_bits / 8) // max(n_ch, 1))
    lanes = max(1, min(STATIC_LANES, k // 32 or 1, payload_guess // 64 or 1))
    if hooks.enabled():
        # lane occupancy: interleave width per chunk and symbols each lane
        # carries — how well the chunk fills the SIMD decode loop
        hooks.observe("codec_rans_lanes", lanes, mode="static")
        hooks.observe("codec_rans_lane_occupancy", k / lanes, mode="static")
    prob_bits = min(MAX_PROB_BITS, max(PROB_BITS_STATIC, bits + 2))
    if n_ch == 0 or k == 0:
        chunks = [(0, np.full(lanes, ctx.RANS_L, "<u4"), b"")] * n_ch
        tables = [normalize_freqs(np.ones(1 << bits), prob_bits)] * n_ch
        return box.pack_container(
            mode=box.MODE_STATIC, bits=bits, prob_bits=prob_bits,
            lanes=lanes, neighbor_dist=0, tables=tables, chunks=chunks)

    def build(tables: list[RansTable]):
        chunks = []
        for i in range(n_ch):
            states, words = encode_static(mat[i], tables[i], lanes)
            chunks.append((k, states, words))
        return box.pack_container(
            mode=box.MODE_STATIC, bits=bits, prob_bits=prob_bits,
            lanes=lanes, neighbor_dist=0,
            tables=[t.freqs for t in tables], chunks=chunks)

    per_channel = [RansTable.from_counts(counts[i], prob_bits)
                   for i in range(n_ch)]
    tables = per_channel
    if n_ch > 1:
        # pick the table layout BEFORE coding: compare the cross-entropy
        # payload estimate plus the zlib'd table blob each layout transmits
        # (small tiles cannot amortize C tables), then code once
        pooled = RansTable.from_counts(counts.sum(axis=0), prob_bits)
        pooled_tables = [pooled] * n_ch

        def table_blob_bits(ts):
            raw = np.concatenate([t.freqs.astype("<u2") for t in ts])
            return 8 * len(zlib.compress(raw.tobytes(), 9))

        cost_per = (_expected_payload_bits(counts, per_channel, prob_bits)
                    + table_blob_bits(per_channel))
        cost_pool = (_expected_payload_bits(counts, pooled_tables, prob_bits)
                     + table_blob_bits(pooled_tables))
        if cost_pool < cost_per:
            tables = pooled_tables
    return build(tables)


def encode_adaptive_tensor(codes: np.ndarray, bits: int) -> bytes:
    """The ``rans-ctx`` backend: adaptive up-neighbor/channel context."""
    with hooks.timed("codec.encode", mode="adaptive"):
        return _encode_adaptive_tensor(codes, bits)


def _encode_adaptive_tensor(codes: np.ndarray, bits: int) -> bytes:
    mat, neighbor = _as_symbol_matrix(codes, bits)
    n_ch, k = mat.shape
    lanes = ctx.plan_lanes(k, neighbor)
    if hooks.enabled() and k:
        hooks.observe("codec_rans_lanes", lanes, mode="adaptive")
        hooks.observe("codec_rans_lane_occupancy", k / lanes,
                      mode="adaptive")
    chunks = []
    for i in range(n_ch):
        states, words = ctx.encode_ctx(mat[i], bits, lanes, neighbor)
        chunks.append((k, states, words))
    return box.pack_container(
        mode=box.MODE_ADAPTIVE, bits=bits, prob_bits=ctx.ctx_prob_bits(bits),
        lanes=lanes, neighbor_dist=neighbor, tables=None, chunks=chunks)


def decode_tensor(payload: bytes, shape: tuple, bits: int) -> np.ndarray:
    """Decode a container back to the channel-last code tensor ``shape``."""
    with hooks.timed("codec.decode"):
        return _decode_tensor(payload, shape, bits)


def _decode_tensor(payload: bytes, shape: tuple, bits: int) -> np.ndarray:
    cont = box.RansContainer.parse(payload)
    h = cont.header
    if h.bits != bits:
        raise CorruptStream(
            f"container codes {h.bits} bits, wire header says {bits}")
    n_ch, k, _ = _chunk_layout(tuple(shape))
    if h.n_chunks != n_ch:
        raise CorruptStream(
            f"container has {h.n_chunks} tile chunks, shape {shape} "
            f"needs {n_ch}")
    for i in range(n_ch):
        if cont.chunk_count(i) != k:
            raise CorruptStream(
                f"chunk {i} holds {cont.chunk_count(i)} symbols, shape "
                f"{shape} needs {k}")
    if n_ch == 0 or k == 0:
        return np.zeros(shape, np.uint32)
    mat = cont.decode_all()                        # (C, K)
    return mat.T.reshape(shape)


def decode_channels(payload: bytes, indices, count: int | None = None
                    ) -> np.ndarray:
    """Partial decode of selected tile chunks -> (len(indices), K)."""
    cont = box.RansContainer.parse(payload)
    out = cont.decode_channels(indices)
    if count is not None and out.size and out.shape[1] != count:
        raise CorruptStream(
            f"chunks hold {out.shape[1]} symbols, expected {count}")
    return out
