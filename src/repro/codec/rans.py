"""Interleaved multi-stream rANS — the repo's real entropy coder.

Range asymmetric numeral systems (Duda 2013) in the interleaved formulation
of Giesen's ryg_rans: N independent lane states share one 16-bit word stream
with a fixed, deterministic interleaving, so encode/decode vectorize over
lanes with numpy while remaining bit-exact.

Construction (all little-endian):

  * state x ∈ [L, L·2^16) with L = 2^16; renormalization emits/reads one
    16-bit word. ``x_max = f << (32 - prob_bits)`` ≥ 2^16 whenever
    ``prob_bits <= 16``, so at most ONE renormalization per symbol — the
    per-step emit is a single masked operation, no data-dependent loops.
  * lane l owns symbols l, l+N, l+2N, …; encoding walks the symbols in
    reverse, emitting each step's renorm words in reverse lane order and
    reversing the whole word array at the end, so the decoder (walking
    forward) reads words in increasing lane order with a single pointer.
  * the encoder takes *per-symbol* (freq, cumfreq) arrays — one static table
    (``RansTable``) or a context model (repro.codec.context) both reduce to
    a gather before the coding loop, so the loop itself is model-agnostic.
  * decoding a full stream must return every lane to the initial state L;
    ``rans_decode`` checks this, which catches most payload corruption that
    happens to keep slots in range.

Frequencies are normalized to sum exactly to ``1 << prob_bits`` with every
alphabet symbol kept ≥ 1 (``normalize_freqs``), so any symbol — including
lane padding — is always codable.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

RANS_L = 1 << 16            # lower bound of the normalization interval
WORD_BITS = 16              # renormalization word size
MAX_PROB_BITS = 15          # freqs must fit uint16 in the table blob

_U64 = np.uint64


class CorruptStream(ValueError):
    """A bitstream failed structural or arithmetic validation."""


def normalize_freqs(counts: np.ndarray, prob_bits: int) -> np.ndarray:
    """Scale histogram ``counts`` to sum exactly to ``1 << prob_bits``.

    Every symbol of the alphabet gets frequency >= 1 (even zero-count ones),
    so the resulting table can code *any* symbol — required for lane padding
    and for adaptive models that may meet unseen symbols. Deterministic:
    ties break by symbol index, so encoder and decoder derive identical
    tables from identical counts.
    """
    if not 1 <= prob_bits <= MAX_PROB_BITS:
        raise ValueError(f"prob_bits must be in [1, {MAX_PROB_BITS}], "
                         f"got {prob_bits}")
    c = np.maximum(np.asarray(counts, dtype=np.int64), 0)
    n = c.size
    target = 1 << prob_bits
    if n == 0:
        raise ValueError("empty alphabet")
    if n > target:
        raise ValueError(f"alphabet of {n} symbols does not fit "
                         f"prob_bits={prob_bits}")
    total = int(c.sum())
    if total == 0:
        c = np.ones(n, dtype=np.int64)
        total = n
    scaled = (c * target) // total
    freqs = np.maximum(scaled, 1)
    diff = target - int(freqs.sum())
    if diff > 0:
        # hand the shortfall to the largest fractional remainders
        rem = c * target - scaled * total
        order = np.lexsort((np.arange(n), -rem))
        freqs[order[:diff]] += 1
    elif diff < 0:
        # the min-1 bumps oversubscribed the budget; reclaim from the
        # largest frequencies (they lose the least precision)
        order = np.argsort(-freqs, kind="stable")
        need = -diff
        for i in order:
            take = min(int(freqs[i]) - 1, need)
            freqs[i] -= take
            need -= take
            if need == 0:
                break
        assert need == 0, "cannot normalize: alphabet too large"
    return freqs.astype(np.uint32)


@dataclass
class RansTable:
    """Static frequency table: freqs + exclusive cumulative + slot lookup."""
    freqs: np.ndarray               # (S,) uint32, sums to 1 << prob_bits
    prob_bits: int
    cum: np.ndarray = field(init=False)           # (S,) exclusive prefix sum
    _slots: np.ndarray | None = field(init=False, default=None, repr=False)

    def __post_init__(self):
        self.freqs = np.asarray(self.freqs, np.uint32)
        if int(self.freqs.sum()) != 1 << self.prob_bits:
            raise CorruptStream(
                f"frequency table sums to {int(self.freqs.sum())}, "
                f"expected {1 << self.prob_bits}")
        if self.freqs.size and int(self.freqs.min()) < 1:
            raise CorruptStream("frequency table has zero-frequency symbols")
        self.cum = (np.cumsum(self.freqs, dtype=np.uint64)
                    - self.freqs).astype(np.uint32)

    @classmethod
    def from_counts(cls, counts, prob_bits: int) -> "RansTable":
        return cls(freqs=normalize_freqs(counts, prob_bits),
                   prob_bits=prob_bits)

    def slot_symbols(self) -> np.ndarray:
        """(1 << prob_bits,) slot -> symbol decode lookup (lazily built)."""
        if self._slots is None:
            self._slots = np.repeat(
                np.arange(self.freqs.size, dtype=np.uint32),
                self.freqs).astype(np.uint32)
        return self._slots


def pad_to_lanes(symbols: np.ndarray, lanes: int,
                 pad_value: int) -> np.ndarray:
    """Pad the symbol stream to a whole number of interleave steps."""
    k = symbols.size
    rem = (-k) % lanes
    if rem == 0:
        return symbols
    return np.concatenate(
        [symbols, np.full(rem, pad_value, dtype=symbols.dtype)])


def rans_encode(freqs: np.ndarray, cums: np.ndarray, prob_bits: int,
                lanes: int) -> tuple[np.ndarray, bytes]:
    """Encode a symbol stream given its per-symbol (freq, cumfreq) gathers.

    freqs/cums: (K,) with K a multiple of ``lanes`` (callers pad, see
    :func:`pad_to_lanes`); entry i belongs to symbol i of the stream.
    Returns ``(final lane states (lanes,) uint32, word stream bytes)``.
    """
    k = freqs.size
    if k % lanes or lanes < 1:
        raise ValueError(f"{k} symbols do not fill {lanes} lanes")
    shift = _U64(32 - prob_bits)
    pb = _U64(prob_bits)
    f = np.ascontiguousarray(freqs, _U64).reshape(-1, lanes)
    c = np.ascontiguousarray(cums, _U64).reshape(-1, lanes)
    x = np.full(lanes, RANS_L, _U64)
    chunks: list[np.ndarray] = []
    for t in range(f.shape[0] - 1, -1, -1):
        ft, ct = f[t], c[t]
        need = x >= (ft << shift)
        if need.any():
            # reverse lane order: the final global reversal flips it back,
            # so the decoder reads renorm words in increasing lane order
            chunks.append((x[need] & _U64(0xFFFF)).astype("<u2")[::-1])
            x = np.where(need, x >> _U64(WORD_BITS), x)
        x = ((x // ft) << pb) + (x % ft) + ct
    if chunks:
        words = np.concatenate(chunks)[::-1]
    else:
        words = np.empty(0, "<u2")
    return x.astype("<u4"), words.tobytes()


def rans_decode(states: np.ndarray, words: bytes, count: int,
                table: RansTable, lanes: int) -> np.ndarray:
    """Decode ``count`` symbols coded with one static table.

    Raises :class:`CorruptStream` on a short/overlong word stream or when
    the lane states fail to return to the initial value (bit corruption).
    """
    if lanes < 1 or states.size != lanes:
        raise CorruptStream(
            f"expected {lanes} lane states, got {states.size}")
    steps = -(-count // lanes) if count else 0
    slot_syms = table.slot_symbols()
    freqs = table.freqs.astype(_U64)
    cums = table.cum.astype(_U64)
    mask = _U64((1 << table.prob_bits) - 1)
    pb = _U64(table.prob_bits)
    w = np.frombuffer(words, "<u2")
    x = states.astype(_U64)
    out = np.empty((steps, lanes), np.uint32)
    ptr = 0
    for t in range(steps):
        slot = x & mask
        s = slot_syms[slot]
        out[t] = s
        x = freqs[s] * (x >> pb) + slot - cums[s]
        need = x < _U64(RANS_L)
        nneed = int(np.count_nonzero(need))
        if nneed:
            if ptr + nneed > w.size:
                raise CorruptStream(
                    f"rANS word stream truncated: needed {ptr + nneed} "
                    f"words, have {w.size}")
            x[need] = (x[need] << _U64(WORD_BITS)) | w[ptr:ptr + nneed]
            ptr += nneed
    if ptr != w.size:
        raise CorruptStream(
            f"rANS word stream has {w.size - ptr} unread trailing words")
    if steps and not bool(np.all(x == _U64(RANS_L))):
        raise CorruptStream(
            "rANS lane states did not return to initial value "
            "(corrupt payload)")
    return out.reshape(-1)[:count]


def encode_static(symbols: np.ndarray, table: RansTable,
                  lanes: int) -> tuple[np.ndarray, bytes]:
    """Static-table convenience wrapper: pad, gather (f, c), run the coder.

    Padding uses the table's most probable symbol (cheapest per pad symbol);
    the decoder truncates by count, so only the wire cost is affected.
    """
    symbols = np.asarray(symbols).reshape(-1)
    if symbols.size == 0:
        return np.full(lanes, RANS_L, "<u4"), b""
    pad_value = int(np.argmax(table.freqs))
    padded = pad_to_lanes(symbols.astype(np.uint32), lanes, pad_value)
    f = table.freqs[padded]
    c = table.cum[padded]
    return rans_encode(f, c, table.prob_bits, lanes)
