"""Versioned rANS bitstream container — per-tile chunks, partial decode.

Layout (all little-endian)::

    header   "RTC1" | u8 version | u8 mode | u8 bits | u8 prob_bits |
             u16 lanes | u16 neighbor_dist | u32 n_chunks | u32 table_len |
             u32 crc32(header fields above)
    tables   zlib(table blob)   # static mode: n_chunks tables of
                                # (1 << bits) uint16 frequencies each;
                                # adaptive mode: empty (nothing transmitted)
    chunk[i] u32 count | u32 n_words | u32 crc32(count|n_words|states|words)
             | lanes * u32 lane states | n_words * u16 rANS words

One chunk per tile (= channel plane of the BaF residual tensor, matching
``core/tiling.py``'s channel tiles). Chunk boundaries are computable from
the fixed-size chunk headers alone, so a decoder can skip straight to any
subset of tiles (:meth:`RansContainer.decode_channels`) without touching the
other payloads — the table blob is the only shared section.

Every structural violation raises :class:`CorruptStream` with a distinct
message: bad magic, unknown version/mode, truncated header, truncated table
blob, truncated chunk, trailing garbage. Bit corruption is caught in depth:
the header carries its own CRC32, the table blob rides zlib's adler32, each
chunk is CRC32'd (verified on decode of that chunk), and the rANS coder
additionally checks that every lane state returns to its initial value.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass

import numpy as np

from repro.codec import context as ctx
from repro.codec.rans import RANS_L, CorruptStream, RansTable, rans_decode

MAGIC = b"RTC1"
VERSION = 1
MODE_STATIC = 0
MODE_ADAPTIVE = 1

_HEADER = struct.Struct("<4sBBBBHHII")
_HEADER_CRC = struct.Struct("<I")
_CHUNK_HEADER = struct.Struct("<III")     # count | n_words | crc32


@dataclass(frozen=True)
class ContainerHeader:
    mode: int
    bits: int
    prob_bits: int
    lanes: int
    neighbor_dist: int
    n_chunks: int


def pack_container(*, mode: int, bits: int, prob_bits: int, lanes: int,
                   neighbor_dist: int,
                   tables: list[np.ndarray] | None,
                   chunks: list[tuple[int, np.ndarray, bytes]]) -> bytes:
    """Assemble the wire blob.

    tables : per-chunk frequency arrays (static mode) or None (adaptive)
    chunks : [(symbol count, lane states (lanes,) uint32, word bytes)]
    """
    if tables is not None and len(tables) != len(chunks):
        raise ValueError(f"{len(tables)} tables for {len(chunks)} chunks")
    table_blob = b""
    if tables is not None and tables:
        raw = np.concatenate([t.astype("<u2") for t in tables]).tobytes()
        table_blob = zlib.compress(raw, 9)
    hdr = _HEADER.pack(MAGIC, VERSION, mode, bits, prob_bits, lanes,
                       neighbor_dist, len(chunks), len(table_blob))
    out = [hdr, _HEADER_CRC.pack(zlib.crc32(hdr)), table_blob]
    for count, states, words in chunks:
        if len(words) % 2:
            raise ValueError("word stream must be whole 16-bit words")
        body = (struct.pack("<II", count, len(words) // 2)
                + np.ascontiguousarray(states, "<u4").tobytes() + words)
        out.append(_CHUNK_HEADER.pack(count, len(words) // 2,
                                      zlib.crc32(body)))
        out.append(body[8:])                      # states + words
    return b"".join(out)


class RansContainer:
    """Parsed, validated view over a container blob; decodes lazily."""

    def __init__(self, header: ContainerHeader, tables: list[np.ndarray],
                 chunk_meta: list[tuple[int, int, int]], blob: bytes):
        self.header = header
        self._tables = tables
        self._chunk_meta = chunk_meta      # (count, states_off, words_len)
        self._blob = blob

    @classmethod
    def parse(cls, blob: bytes) -> "RansContainer":
        hdr_size = _HEADER.size + _HEADER_CRC.size
        if len(blob) < hdr_size:
            raise CorruptStream(
                f"truncated container header: {len(blob)} bytes, "
                f"need {hdr_size}")
        (magic, version, mode, bits, prob_bits, lanes, neighbor_dist,
         n_chunks, table_len) = _HEADER.unpack_from(blob, 0)
        if magic != MAGIC:
            raise CorruptStream(f"bad container magic {magic!r}")
        if version != VERSION:
            raise CorruptStream(f"unsupported container version {version}")
        (hdr_crc,) = _HEADER_CRC.unpack_from(blob, _HEADER.size)
        if hdr_crc != zlib.crc32(blob[:_HEADER.size]):
            raise CorruptStream("container header CRC mismatch")
        if mode not in (MODE_STATIC, MODE_ADAPTIVE):
            raise CorruptStream(f"unknown container mode {mode}")
        if not 1 <= bits <= 16 or lanes < 1:
            raise CorruptStream(
                f"implausible container geometry: bits={bits} lanes={lanes}")
        off = hdr_size
        if off + table_len > len(blob):
            raise CorruptStream(
                f"truncated table blob: header claims {table_len} bytes, "
                f"{len(blob) - off} remain")
        tables: list[np.ndarray] = []
        if mode == MODE_STATIC and n_chunks:
            try:
                raw = zlib.decompress(blob[off:off + table_len])
            except zlib.error as e:
                raise CorruptStream(f"undecodable table blob: {e}") from e
            nsym = 1 << bits
            if len(raw) != n_chunks * nsym * 2:
                raise CorruptStream(
                    f"table blob holds {len(raw)} bytes, expected "
                    f"{n_chunks * nsym * 2} ({n_chunks} tables of "
                    f"{nsym} uint16)")
            flat = np.frombuffer(raw, "<u2").reshape(n_chunks, nsym)
            tables = [flat[i] for i in range(n_chunks)]
        elif table_len and mode == MODE_ADAPTIVE:
            raise CorruptStream("adaptive container carries a table blob")
        off += table_len
        chunk_meta = []
        for i in range(n_chunks):
            if off + _CHUNK_HEADER.size > len(blob):
                raise CorruptStream(
                    f"truncated chunk {i} header at byte {off}")
            count, n_words, crc = _CHUNK_HEADER.unpack_from(blob, off)
            off += _CHUNK_HEADER.size
            states_off = off
            need = 4 * lanes + 2 * n_words
            if off + need > len(blob):
                raise CorruptStream(
                    f"truncated chunk {i}: needs {need} bytes at byte "
                    f"{off}, {len(blob) - off} remain")
            chunk_meta.append((count, states_off, 2 * n_words, crc))
            off += need
        if off != len(blob):
            raise CorruptStream(
                f"{len(blob) - off} bytes of trailing garbage after "
                f"chunk {n_chunks - 1 if n_chunks else 'header'}")
        header = ContainerHeader(mode=mode, bits=bits, prob_bits=prob_bits,
                                 lanes=lanes, neighbor_dist=neighbor_dist,
                                 n_chunks=n_chunks)
        return cls(header, tables, chunk_meta, blob)

    # -- decode -------------------------------------------------------------
    def chunk_count(self, i: int) -> int:
        return self._chunk_meta[i][0]

    def chunk_parts(self, i: int) -> tuple[int, np.ndarray, bytes]:
        """CRC-verified raw parts of chunk ``i``: (count, lane states, words).

        The shared extraction step behind :meth:`decode_chunk` and the
        cross-container batched decoder (repro.codec.batch) — every consumer
        gets the same integrity checks before touching a payload byte."""
        h = self.header
        count, states_off, words_len, crc = self._chunk_meta[i]
        end = states_off + 4 * h.lanes + words_len
        body = (struct.pack("<II", count, words_len // 2)
                + self._blob[states_off:end])
        if crc != zlib.crc32(body):
            raise CorruptStream(f"chunk {i} CRC mismatch (corrupt payload)")
        states = np.frombuffer(
            self._blob, "<u4", count=h.lanes, offset=states_off)
        words = self._blob[states_off + 4 * h.lanes:end]
        if count == 0:
            if words_len:
                raise CorruptStream(
                    f"chunk {i}: nonempty word stream for an empty chunk")
            if not bool(np.all(states == RANS_L)):
                raise CorruptStream(
                    f"chunk {i}: empty chunk with non-initial lane states")
        return count, states, words

    def chunk_table(self, i: int) -> np.ndarray | None:
        """Static-mode frequency table of chunk ``i`` (None when adaptive)."""
        return self._tables[i] if self.header.mode == MODE_STATIC else None

    def decode_chunk(self, i: int) -> np.ndarray:
        """Decode tile ``i`` alone; other chunks are never touched."""
        h = self.header
        count, states, words = self.chunk_parts(i)
        if count == 0:
            return np.empty(0, np.uint32)
        if h.mode == MODE_STATIC:
            table = RansTable(freqs=self._tables[i].astype(np.uint32),
                              prob_bits=h.prob_bits)
            return rans_decode(states, words, count, table, h.lanes)
        return ctx.decode_ctx(states, words, count, h.bits, h.lanes,
                              h.neighbor_dist)

    def decode_channels(self, indices) -> np.ndarray:
        """Partial decode: (len(indices), count) for the requested tiles."""
        rows = [self.decode_chunk(int(i)) for i in indices]
        return np.stack(rows) if rows else np.empty((0, 0), np.uint32)

    def decode_all(self) -> np.ndarray:
        return self.decode_channels(range(self.header.n_chunks))
