"""Fused consolidation Pallas kernel — paper eq. (6).

For each transmitted channel element, the BaF estimate Z̃ is kept when it lies
inside the quantizer bin the decoder received, and clamped to the nearest bin
boundary otherwise — exactly ``clip(Z̃, bin_lo, bin_hi)`` (core/baf.py).

The naive formulation materializes the (lo, hi) bound tensors in HBM; this
kernel reconstructs the bounds from the uint8 codes + fp16 side info inside
VMEM and writes only the consolidated output: 3 HBM tensor reads
(z̃, codes, side info) + 1 write instead of 5 reads + 3 writes. Pure
elementwise VPU work, no MXU.

Grid: (B, R // BR), channels kept whole per block (the side info is per
channel, so a (BR, C) block needs exactly one (C,) side-info row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.kernels.compat import pl


def _consolidate_kernel(z_ref, codes_ref, mins_ref, maxs_ref, out_ref,
                        *, levels: int):
    z = z_ref[0].astype(jnp.float32)                    # (BR, C)
    c = codes_ref[0].astype(jnp.float32)
    m = mins_ref[0].astype(jnp.float32)                 # (C,)
    mx = maxs_ref[0].astype(jnp.float32)
    step = (mx - m) / levels
    lo = m[None, :] + (c - 0.5) * step[None, :]
    hi = m[None, :] + (c + 0.5) * step[None, :]
    out_ref[0] = jnp.clip(z, lo, hi)


def consolidate_pallas(z_tilde: jax.Array, codes: jax.Array, mins: jax.Array,
                       maxs: jax.Array, bits: int, *, block_r: int = 512,
                       interpret: bool | None = None) -> jax.Array:
    """z_tilde/codes: (B, R, C); mins/maxs: (B, C) f16 -> (B, R, C) f32."""
    b, r, c = z_tilde.shape
    br = min(block_r, r)
    assert r % br == 0, f"R={r} not divisible by block_r={br}"
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    levels = (1 << bits) - 1

    grid = (b, r // br)
    return pl.pallas_call(
        functools.partial(_consolidate_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br, c), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, br, c), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, c), lambda i, j: (i, 0)),
            pl.BlockSpec((1, c), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, br, c), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, c), jnp.float32),
        interpret=interpret,
    )(z_tilde, codes, mins, maxs)
