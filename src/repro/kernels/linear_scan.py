"""Chunked linear-attention / SSD scan Pallas kernel (RWKV-6 & Mamba-2).

TPU adaptation of the CUDA per-thread recurrences in the RWKV-6 / Mamba-2
papers (DESIGN.md §4): instead of per-element sequential state updates, the
sequence is chunked so that

  * intra-chunk interactions are (L, dk) x (dk, L) / (L, L) x (L, dv) MXU
    matmuls (matmul form of the recurrence),
  * the inter-chunk state S ∈ (dk, dv) is carried in VMEM scratch across the
    sequential chunk grid dimension — it never round-trips to HBM.

Recurrence (per head):  S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
  rwkv mode:  y_t = q_t·S_{t-1} + (q_t ⊙ u ⊙ k_t)·v_t     (bonus u, strict)
  ssm  mode:  y_t = q_t·S_t                                (inclusive)

Numerics: identical to models/linear_attention.py — fp32 throughout, log-decay
clamped to [LOG_DECAY_MIN, -1e-9] by the ops wrapper so exp(±cum log decay)
stays finite within a chunk.

Grid: (B·H, S/L) with the chunk dimension sequential ("arbitrary").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.kernels.compat import pl, pltpu, tpu_compiler_params


def _scan_kernel(q_ref, k_ref, v_ref, ld_ref, u_ref, s0_ref,
                 y_ref, sfinal_ref, state_ref, *,
                 mode: str, nc_total: int, use_bonus: bool):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)                    # (L, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)                    # (L, dv)
    ld = ld_ref[0].astype(jnp.float32)                  # (L, dk)
    L = q.shape[0]

    la = jnp.cumsum(ld, axis=0)                         # inclusive cum log-decay
    la_prev = la - ld                                   # exclusive
    la_end = la[-1:, :]                                 # (1, dk)

    la_q = la_prev if mode == "rwkv" else la
    qd = q * jnp.exp(la_q)
    kd = k * jnp.exp(-la)
    k_rem = k * jnp.exp(la_end - la)

    # intra-chunk: strict lower-triangular (rwkv) / inclusive (ssm)
    row = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tri = (col < row) if mode == "rwkv" else (col <= row)
    scores = jax.lax.dot_general(qd, kd, (((1,), (1,)), ((), ())))
    scores = jnp.where(tri, scores, 0.0)
    y = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())))

    if use_bonus:
        u = u_ref[0].astype(jnp.float32)                # (1, dk)
        bq = jnp.sum(q * u * k, axis=-1, keepdims=True)  # (L, 1)
        y = y + bq * v

    # inter-chunk: contribution of the carried state, then state update
    state = state_ref[...]                              # (dk, dv)
    y = y + jax.lax.dot_general(qd, state, (((1,), (0,)), ((), ())))
    state_ref[...] = jnp.exp(la_end[0])[:, None] * state + jax.lax.dot_general(
        k_rem, v, (((0,), (0,)), ((), ())))
    y_ref[0] = y

    @pl.when(ic == nc_total - 1)
    def _finalize():
        sfinal_ref[0] = state_ref[...]


def linear_scan_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                       log_decay: jax.Array, *, bonus: jax.Array | None = None,
                       initial_state: jax.Array | None = None,
                       chunk: int = 16, mode: str = "rwkv",
                       interpret: bool | None = None):
    """q,k,ld: (BH, S, dk); v: (BH, S, dv); bonus: (BH, dk) or None;
    initial_state: (BH, dk, dv) or None. Returns (y (BH,S,dv), state).

    Head flattening / decay clamping / bonus broadcasting live in ops.py.
    """
    bh, s, dk = q.shape
    dv = v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nc = s // chunk

    if bonus is None:
        bonus = jnp.zeros((bh, dk), jnp.float32)
        use_bonus = False
    else:
        use_bonus = mode == "rwkv"
    if initial_state is None:
        initial_state = jnp.zeros((bh, dk, dv), jnp.float32)

    kern = functools.partial(_scan_kernel, mode=mode, nc_total=nc,
                             use_bonus=use_bonus)
    y, sfinal = pl.pallas_call(
        kern,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk), lambda b, c: (b, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
    )(q, k, v, log_decay, bonus, initial_state)
    return y, sfinal
