"""Fused per-channel min/max + quantize Pallas kernel — paper eq. (4).

The naive pipeline reads the activation tensor from HBM twice: once to reduce
per-channel (min, max), once to apply the affine quantization. This kernel
holds one (example, channel-block) column — the full spatial/sequence extent
of a block of channels — resident in VMEM, computes the per-channel stats and
the uint8 codes in a single pass, and emits the fp16 side info the paper
transmits (C·32 bits).

Roofline: the op is purely bandwidth-bound (2 flops/byte); fusing halves HBM
traffic, so the kernel sits at the memory roofline by construction. Block
sizing: (R, BC) with R = spatial extent (e.g. 64·64 = 4096 for the paper's
split tensor) and BC channels such that R·BC·4 B ≲ 4 MiB of VMEM — BC = 128
covers the paper's tensor at 2 MiB/block with lane-aligned (·, 128) tiles.

Grid: (B, C // BC); every grid step is independent ("parallel" semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.kernels.compat import pl


def _quantize_kernel(x_ref, codes_ref, mins_ref, maxs_ref, *, levels: int):
    x = x_ref[0].astype(jnp.float32)                    # (R, BC) one VMEM block
    mn = jnp.min(x, axis=0)                             # (BC,)
    mx = jnp.max(x, axis=0)
    # paper §3.2: side info is fp16; widen the max to the next representable
    # so fp16 rounding can never push a data point above the top code, but
    # saturate at finite fp16 — an inf bound zeroes every code and restores NaN.
    f16_max = jnp.asarray(65504.0, jnp.float16)
    mn16 = jnp.maximum(mn.astype(jnp.float16), -f16_max)
    mx16 = mx.astype(jnp.float16)
    mx16 = jnp.minimum(
        jnp.maximum(mx16, jnp.nextafter(mx16, jnp.asarray(jnp.inf, jnp.float16))),
        f16_max)
    m = mn16.astype(jnp.float32)
    rng = jnp.maximum(mx16.astype(jnp.float32) - m, 1e-12)
    scaled = (x - m[None, :]) / rng[None, :] * levels
    codes_ref[0] = jnp.clip(jnp.round(scaled), 0, levels).astype(jnp.uint8)
    mins_ref[0] = mn16
    maxs_ref[0] = mx16


def quantize_pallas(x: jax.Array, bits: int, *, block_c: int = 128,
                    interpret: bool | None = None):
    """x: (B, R, C) channel-last -> (codes uint8, mins f16 (B,C), maxs f16 (B,C)).

    One (min,max) pair per (example, channel) — the paper's per-transmission
    side info. R·block_c·4B must fit the VMEM budget (~4 MiB/block).
    """
    assert bits <= 8, "uint8 code path; higher depths use the jnp reference"
    b, r, c = x.shape
    bc = min(block_c, c)
    assert c % bc == 0, f"C={c} not divisible by block_c={bc}"
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    levels = (1 << bits) - 1

    grid = (b, c // bc)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, levels=levels),
        grid=grid,
        in_specs=[pl.BlockSpec((1, r, bc), lambda i, j: (i, 0, j))],
        out_specs=[
            pl.BlockSpec((1, r, bc), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1, bc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, r, c), jnp.uint8),
            jax.ShapeDtypeStruct((b, c), jnp.float16),
            jax.ShapeDtypeStruct((b, c), jnp.float16),
        ],
        interpret=interpret,
    )(x)
