"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §4).

  quantize.py         fused per-channel min/max + quantize (paper eq. 4)
  consolidate.py      fused bin-bound clip (paper eq. 6)
  flash_attention.py  (block_q, block_kv) VMEM-tiled attention
  linear_scan.py      chunked RWKV-6 / Mamba-2 state-passing scan

``ops`` holds the jit'd public wrappers, ``ref`` the pure-jnp oracles.
Kernels execute in interpret mode on CPU (this container) and compile for
TPU (the target).
"""
from repro.kernels.ops import (consolidate_fused, flash_attention, linear_scan,
                               quantize_fused)
