"""jit'd public wrappers around the Pallas kernels.

Layout adaptation (head flattening, kv-head repetition, decay clamping,
QuantParams packing) lives here so kernel bodies stay pure block math. Every
wrapper defaults ``interpret`` to True on CPU (this container) and False on
TPU (the target); tests validate interpret-mode kernels against ref.py.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QuantParams
from repro.kernels.consolidate import consolidate_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.linear_scan import linear_scan_pallas
from repro.kernels.quantize import quantize_pallas
from repro.models.linear_attention import LOG_DECAY_MIN


# ---------------------------------------------------------------------------
# Quantize (paper eq. 4) — per-(example, channel) side info
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("bits", "block_c", "interpret"))
def quantize_fused(x: jax.Array, bits: int, *, block_c: int = 128,
                   interpret: Optional[bool] = None):
    """x: (B, ..., C) channel-last -> (codes uint8 (B, ..., C), QuantParams).

    QuantParams mins/maxs have singleton middle dims (per_example layout of
    core.quant.compute_quant_params), so dequantize/bin_bounds broadcast.
    """
    b, c = x.shape[0], x.shape[-1]
    mid = x.shape[1:-1]
    x3 = x.reshape(b, -1, c)
    codes, mins, maxs = quantize_pallas(x3.astype(jnp.float32), bits,
                                        block_c=block_c, interpret=interpret)
    side_shape = (b,) + (1,) * len(mid) + (c,)
    qp = QuantParams(mins=mins.reshape(side_shape),
                     maxs=maxs.reshape(side_shape), bits=bits)
    return codes.reshape(x.shape), qp


# ---------------------------------------------------------------------------
# Consolidation (paper eq. 6)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("bits", "interpret"))
def consolidate_fused(z_tilde: jax.Array, codes: jax.Array, mins: jax.Array,
                      maxs: jax.Array, bits: int, *,
                      interpret: Optional[bool] = None) -> jax.Array:
    """z_tilde/codes: (B, ..., C); mins/maxs broadcastable (B, ..1.., C)."""
    b, c = z_tilde.shape[0], z_tilde.shape[-1]
    z3 = z_tilde.reshape(b, -1, c)
    out = consolidate_pallas(
        z3.astype(jnp.float32), codes.reshape(b, -1, c),
        mins.reshape(b, c).astype(jnp.float16),
        maxs.reshape(b, c).astype(jnp.float16), bits, interpret=interpret)
    return out.reshape(z_tilde.shape).astype(z_tilde.dtype)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv",
                                   "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, K, hd) with K | H (GQA repeat here)."""
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    if kh != h:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    o = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=interpret)
    return o.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# Linear scan (RWKV-6 / Mamba-2 SSD)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("chunk", "mode", "interpret"))
def linear_scan(q: jax.Array, k: jax.Array, v: jax.Array,
                log_decay: jax.Array, *, bonus: Optional[jax.Array] = None,
                initial_state: Optional[jax.Array] = None, chunk: int = 16,
                mode: str = "rwkv", interpret: Optional[bool] = None):
    """q,k: (B,S,H,dk); v: (B,S,H,dv); log_decay: (B,S,H,dk) or (B,S,H,1);
    bonus: (H, dk) or None; initial_state: (B,H,dk,dv) or None.
    Returns (y (B,S,H,dv) f32, final_state (B,H,dk,dv) f32) — identical
    contract to models.linear_attention.chunked_linear_attention.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    ld = jnp.clip(log_decay.astype(jnp.float32), LOG_DECAY_MIN, -1e-9)
    ld = jnp.broadcast_to(ld, (b, s, h, dk))

    def flat(t, d):
        return t.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    qf, kf, vf = flat(q, dk), flat(k, dk), flat(v, dv)
    ldf = flat(ld, dk)
    bo = None
    if bonus is not None:
        bo = jnp.broadcast_to(bonus.astype(jnp.float32)[None], (b, h, dk))
        bo = bo.reshape(b * h, dk)
    s0 = None
    if initial_state is not None:
        s0 = initial_state.astype(jnp.float32).reshape(b * h, dk, dv)
    y, sf = linear_scan_pallas(qf, kf, vf, ldf, bonus=bo, initial_state=s0,
                               chunk=chunk, mode=mode, interpret=interpret)
    return (y.reshape(b, h, s, dv).transpose(0, 2, 1, 3),
            sf.reshape(b, h, dk, dv))
