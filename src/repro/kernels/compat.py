"""Pallas API compatibility shim.

The Pallas TPU surface was renamed across jax releases: ``pltpu.CompilerParams``
(jax >= 0.5 naming, used by current docs) was ``pltpu.TPUCompilerParams``
before that, and some older releases spell compiler knobs differently again.
Kernels import the resolved names from here instead of guessing, so the same
kernel source runs on whatever jax the container bakes in.

    from repro.kernels.compat import CompilerParams, tpu_compiler_params

``tpu_compiler_params(...)`` additionally drops keyword arguments the
installed class does not accept (e.g. very old jax without
``dimension_semantics``), degrading to "no hint" rather than crashing —
the hints are performance metadata, never correctness.

This module is also the one sanctioned import site for the pallas modules
themselves (lint rule RA03): ``jax.experimental`` is an unstable namespace
— pallas has already moved once and is slated to graduate out of
experimental — so kernels spell

    from repro.kernels.compat import pl, pltpu

and a future module move is absorbed here, in one place, instead of in
every kernel.
"""
from __future__ import annotations

import inspect
from typing import Any

# the import shim boundary: raw jax.experimental is allowed here and in
# repro/compat.py only (both files are RA03-exempt by config)
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["CompilerParams", "pl", "pltpu", "tpu_compiler_params"]

# Resolve the compiler-params class across the rename. Newest first.
if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
elif hasattr(pltpu, "TPUCompilerParams"):
    CompilerParams = pltpu.TPUCompilerParams
else:                                        # pragma: no cover - ancient jax
    CompilerParams = None

if CompilerParams is not None:
    _ACCEPTED = frozenset(inspect.signature(CompilerParams).parameters)
else:                                        # pragma: no cover - ancient jax
    _ACCEPTED = frozenset()


def tpu_compiler_params(**kwargs: Any):
    """Build a compiler-params object, dropping unsupported keywords.

    Returns None (callers pass ``compiler_params=None``, which pallas_call
    accepts) when the installed jax exposes no compiler-params class at all.
    """
    if CompilerParams is None:               # pragma: no cover - ancient jax
        return None
    return CompilerParams(**{k: v for k, v in kwargs.items()
                             if k in _ACCEPTED})
