"""Flash attention Pallas kernel — (block_q, block_kv) VMEM tiling.

TPU-native formulation of the attention hot path: softmax statistics (running
max m, normalizer l) and the output accumulator live in VMEM scratch across
the sequential kv-block grid dimension; the (S, S) score matrix is never
materialized in HBM. Matmul operands are (block_q, hd) x (hd, block_kv) —
128-aligned on both MXU dims for hd ∈ {64, 128} with the default blocks.

Grid: (B·H, S/block_q, S/block_kv) with the kv dimension sequential
("arbitrary" semantics): scratch persists across it, and fully-masked kv
blocks are skipped via pl.when (causal ⇒ ~half the blocks do no work;
windowed ⇒ only ~2W/S of them do).

Numerics: scores and the accumulator are fp32 regardless of input dtype;
masked lanes use a -1e30 fill (finite, so exp() underflows to exactly 0
without NaN edge cases at all-masked blocks — those are skipped anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.kernels.compat import pl, pltpu, tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window, bq: int, bk: int,
                  nk_total: int, q_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block skip predicate (trace-time grid indices -> cheap scalar compare)
    run = True
    if causal:
        # kv block strictly after the last query of this q block: fully masked
        run = ik * bk <= (iq + 1) * bq - 1 + q_offset
    if window is not None:
        run = jnp.logical_and(run, (ik + 1) * bk - 1 > iq * bq + q_offset - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # (bq, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                          # (bq, bk)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == nk_total - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool | None = None) -> jax.Array:
    """q,k,v: (BH, S, hd), kv heads already repeated to BH. Returns (BH, Sq, hd).

    Supports Sq != Sk (q_offset-aligned causal masking for chunked prefill).
    """
    bh, sq, hd = q.shape
    sk = k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_kv, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    nk = sk // bk
    scale = 1.0 / (hd ** 0.5)

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk_total=nk, q_offset=sk - sq)
    return pl.pallas_call(
        kern,
        grid=(bh, sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v)
