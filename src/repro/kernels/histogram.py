"""Per-channel symbol histogram + CDF Pallas kernels — codec table stage.

The static rANS backend (repro/codec) needs per-channel symbol counts of the
quantized BaF residual tensor before the host-side coding pass. The codes
are already on device (the quantize kernel produced them), so the histogram
should be too: one pass over the codes in VMEM instead of a host bincount
over a device->host copy.

Kernel 1 (histogram): grid ``(C blocks, R blocks)``; the R axis revisits the
same output block and accumulates, so arbitrarily long code streams stream
through a fixed VMEM footprint. Counts are computed as a broadcast
compare-and-sum against a symbol iota — elementwise VPU work, no MXU.

Kernel 2 (CDF): one (S, BC) block per channel block; exclusive prefix sum
along the symbol axis — exactly the cumulative table rANS needs.

Both default to interpret mode on CPU like the other kernels in this
package; numerics are integer-exact either way (validated against
``np.bincount`` in tests/test_rans.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from repro.kernels.compat import pl


def _hist_kernel(x_ref, counts_ref, *, nsym: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...].astype(jnp.int32)                      # (BR, BC)
    sym = jax.lax.broadcasted_iota(jnp.int32, (nsym, 1, 1), 0)
    eq = (x[None, :, :] == sym).astype(jnp.int32)         # (S, BR, BC)
    counts_ref[...] += jnp.sum(eq, axis=1)


def _cdf_kernel(counts_ref, cdf_ref):
    c = counts_ref[...]
    cdf_ref[...] = jnp.cumsum(c, axis=0) - c              # exclusive


@functools.lru_cache(maxsize=64)
def _jitted_hist(nsym: int, br: int, bc: int, rp: int, cp: int,
                 interpret: bool):
    call = pl.pallas_call(
        functools.partial(_hist_kernel, nsym=nsym),
        grid=(cp // bc, rp // br),
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (j, i))],
        out_specs=pl.BlockSpec((nsym, bc), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((nsym, cp), jnp.int32),
        interpret=interpret,
    )
    return jax.jit(call)


def histogram_pallas(codes: jax.Array, nsym: int, *, block_r: int = 256,
                     block_c: int = 8,
                     interpret: bool | None = None) -> jax.Array:
    """codes: (R, C) integer array -> counts (nsym, C) int32.

    Out-of-range values (negative or >= nsym) are counted nowhere — callers
    use ``nsym`` itself as the padding sentinel. The pallas_call is jitted
    and cached per shape, so the serving hot path (same tile shape per
    bucket) traces once.
    """
    r, c = codes.shape
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    # the kernel materializes an (nsym, BR, BC) int32 compare — keep that
    # intermediate within a ~4 MB VMEM budget by shrinking the row block as
    # the alphabet grows (nsym=4096 at the default blocks would be ~33 MB)
    bc = min(block_c, max(c, 1))
    br_cap = max(1, (1 << 20) // (max(nsym, 1) * bc))
    br = min(block_r, br_cap, max(r, 1))
    pad_r = (-r) % br
    pad_c = (-c) % bc
    if pad_r or pad_c:
        codes = jnp.pad(codes.astype(jnp.int32), ((0, pad_r), (0, pad_c)),
                        constant_values=nsym)
    rp, cp = r + pad_r, c + pad_c
    counts = _jitted_hist(nsym, br, bc, rp, cp, interpret)(
        codes.astype(jnp.int32))
    return counts[:, :c]


@functools.lru_cache(maxsize=64)
def _jitted_cdf(s: int, bc: int, cp: int, interpret: bool):
    call = pl.pallas_call(
        _cdf_kernel,
        grid=(cp // bc,),
        in_specs=[pl.BlockSpec((s, bc), lambda i: (0, i))],
        out_specs=pl.BlockSpec((s, bc), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((s, cp), jnp.int32),
        interpret=interpret,
    )
    return jax.jit(call)


def cdf_pallas(counts: jax.Array, *, block_c: int = 8,
               interpret: bool | None = None) -> jax.Array:
    """counts: (S, C) -> exclusive CDF (S, C), same dtype widening to i32."""
    s, c = counts.shape
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bc = min(block_c, max(c, 1))
    pad_c = (-c) % bc
    if pad_c:
        counts = jnp.pad(counts, ((0, 0), (0, pad_c)))
    cdf = _jitted_cdf(s, bc, c + pad_c, interpret)(counts.astype(jnp.int32))
    return cdf[:, :c]


def channel_histogram(codes, bits: int, *,
                      interpret: bool | None = None) -> np.ndarray:
    """Per-channel symbol counts of a channel-last code tensor, on device.

    codes: (..., C) integers in [0, 2^bits) -> counts (C, S) as a host numpy
    array, ready for table normalization (repro.codec.rans.normalize_freqs
    runs host-side; the heavy O(R·C·S) reduction stays on device). This is
    the encoder hot path — the CDF kernel is not run here.
    """
    nsym = 1 << bits
    arr = np.asarray(codes)
    if arr.ndim == 0:
        arr = arr.reshape(1, 1)
    c = arr.shape[-1]               # channel-last, matching repro.codec
    flat = arr.reshape(-1, c) if c else arr.reshape(-1, 1)
    if flat.size == 0 or c == 0:
        return np.zeros((c, nsym), np.int64)
    counts = histogram_pallas(jnp.asarray(flat, jnp.int32), nsym,
                              interpret=interpret)
    return np.asarray(counts).T.astype(np.int64)


def channel_histogram_cdf(codes, bits: int, *,
                          interpret: bool | None = None
                          ) -> tuple[np.ndarray, np.ndarray]:
    """Counts plus the exclusive CDF (both (C, S)), both computed on device."""
    nsym = 1 << bits
    arr = np.asarray(codes)
    if arr.ndim == 0:
        arr = arr.reshape(1, 1)
    c = arr.shape[-1]
    flat = arr.reshape(-1, c) if c else arr.reshape(-1, 1)
    if flat.size == 0 or c == 0:
        z = np.zeros((c, nsym), np.int64)
        return z, z.copy()
    counts = histogram_pallas(jnp.asarray(flat, jnp.int32), nsym,
                              interpret=interpret)
    cdf = cdf_pallas(counts, interpret=interpret)
    return (np.asarray(counts).T.astype(np.int64),
            np.asarray(cdf).T.astype(np.int64))
