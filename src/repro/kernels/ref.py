"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the corresponding kernel is
validated against (tests/test_kernels.py sweeps shapes/dtypes and
assert_allclose's kernel vs oracle). They deliberately reuse the library's
reference implementations so "kernel == oracle == paper equations" is a
single chain.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QuantParams, bin_bounds, compute_quant_params, quantize
from repro.models.linear_attention import reference_scan


# ---------------------------------------------------------------------------
# quantize.py oracle — paper eq. (4) with per-(example, channel) side info
# ---------------------------------------------------------------------------

def quantize_fused_ref(x: jax.Array, bits: int):
    """x: (B, R, C) -> (codes uint8 (B, R, C), mins f16 (B, C), maxs f16 (B, C)).

    Matches core.quant.compute_quant_params(per_example=True) + quantize,
    with the side info squeezed to (B, C).
    """
    qp = compute_quant_params(x, bits, per_example=True)
    codes = quantize(x, qp)
    return codes, qp.mins.reshape(x.shape[0], -1), qp.maxs.reshape(x.shape[0], -1)


# ---------------------------------------------------------------------------
# consolidate.py oracle — paper eq. (6)
# ---------------------------------------------------------------------------

def consolidate_ref(z_tilde: jax.Array, codes: jax.Array, mins: jax.Array,
                    maxs: jax.Array, bits: int) -> jax.Array:
    """z_tilde/codes: (B, R, C); mins/maxs: (B, C) f16. clip(z̃, bin_lo, bin_hi)."""
    qp = QuantParams(mins=mins[:, None, :], maxs=maxs[:, None, :], bits=bits)
    lo, hi = bin_bounds(codes, qp)
    return jnp.clip(z_tilde.astype(jnp.float32), lo, hi)


# ---------------------------------------------------------------------------
# flash_attention.py oracle — full-softmax attention
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q,k,v: (B, S, H, hd), kv heads already repeated. fp32 softmax."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# linear_scan.py oracle — O(S) recurrence (RWKV-6 / Mamba-2 SSD)
# ---------------------------------------------------------------------------

def linear_scan_ref(q, k, v, log_decay, *, bonus=None, initial_state=None,
                    mode: str = "rwkv"):
    """q,k: (B,S,H,dk) v: (B,S,H,dv) log_decay: (B,S,H,dk)|(B,S,H,1).

    Pure recurrent scan — exactly models.linear_attention.reference_scan.
    """
    return reference_scan(q, k, v, log_decay, bonus=bonus,
                          initial_state=initial_state, mode=mode)
