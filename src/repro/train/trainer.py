"""Distributed training step: microbatch gradient accumulation, bf16 compute
with fp32 master params, remat'd scanned layers, optional compressed cross-pod
gradient all-reduce (the paper's quantizer — optim/grad_compress.py).

The step is a pure function pytree->pytree, so pjit handles all partitioning:
params/opt-state via distributed/sharding.py specs, batch over (pod, data).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import nn
from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.distributed import shard_hidden
from repro.models.encdec import encdec_loss
from repro.models.lm import lm_loss
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_with_warmup
from repro.optim.grad_compress import quantized_pod_mean


@dataclass(frozen=True)
class TrainConfig:
    num_microbatches: int = 1
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    adamw: AdamWConfig = AdamWConfig()
    # cross-pod gradient compression (None = exact bf16/fp32 all-reduce)
    grad_compress_bits: Optional[int] = None
    error_feedback: bool = True
    # activation-checkpoint policy: 'full' | 'dots' | 'dots_no_batch'
    remat_policy: str = "full"


class TrainState(NamedTuple):
    params: Any            # fp32 master
    opt: Any               # AdamWState (fp32, congruent with params)
    step: jax.Array
    ef: Any = None         # error-feedback residuals (grad compression)


def init_train_state(params, tcfg: TrainConfig) -> TrainState:
    ef = None
    if tcfg.grad_compress_bits is not None and tcfg.error_feedback:
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32), ef=ef)


def loss_for(cfg: ArchConfig):
    return encdec_loss if cfg.family == "audio" else lm_loss


def _microbatch(batch, n: int):
    """(B, ...) -> (n, B/n, ...) for lax.scan accumulation."""
    return jax.tree.map(lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]),
                        batch)


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig, *, mesh=None,
                    multi_pod: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""
    base_loss = loss_for(cfg)
    if cfg.family == "audio":
        loss_fn = base_loss          # encdec has its own fixed remat
    else:
        loss_fn = partial(base_loss, remat_policy=tcfg.remat_policy)
    sched = cosine_with_warmup(tcfg.peak_lr, tcfg.warmup_steps, tcfg.total_steps)

    def grads_of(params, batch):
        """Microbatch-accumulated mean loss/grads, bf16 forward."""
        bf16 = nn.tree_cast(params, cfg.dtype)

        if tcfg.num_microbatches == 1:
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch))(bf16)
        else:
            mbs = _microbatch(batch, tcfg.num_microbatches)

            def body2(acc, mb):
                l, g = jax.value_and_grad(lambda p: loss_fn(p, cfg, mb))(bf16)
                acc_l, acc_g = acc
                acc_g = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc_g, g)
                return (acc_l + l, acc_g), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), bf16)
            (loss, grads), _ = jax.lax.scan(body2, (jnp.zeros(()), zero_g), mbs)
            loss = loss / tcfg.num_microbatches
            grads = jax.tree.map(lambda g: g / tcfg.num_microbatches, grads)
        # grads computed w.r.t. bf16 copy; structure matches fp32 master
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return loss, grads

    def train_step(state: TrainState, batch):
        new_ef = state.ef
        if tcfg.grad_compress_bits is not None and multi_pod:
            # The compressed cross-pod exchange must be ISOLATED from pjit's
            # automatic gradient reduction: under plain pjit the pod factor
            # fuses into the (pod, data) all-reduce and quantizing afterwards
            # adds bytes instead of saving them (measured — EXPERIMENTS.md
            # §Tier-C). shard_map over the pod axis keeps the bwd psum on
            # the data axis only; the pod hop is the int8 ring exchange.
            from jax.sharding import PartitionSpec as P
            from repro.distributed import api as dist_api
            from repro.optim.grad_compress import _quantized_psum_one
            npod = mesh.shape["pod"]

            def pod_local(params, ef, mb):
                with dist_api.axis_ctx(dist_api.train_rules(False)):
                    loss, grads = grads_of(params, mb)
                if ef is not None:
                    grads = jax.tree.map(lambda g, e: g + e, grads, ef)
                flat, treedef = jax.tree.flatten(grads)
                outs = [_quantized_psum_one(g, tcfg.grad_compress_bits,
                                            "pod", npod) for g in flat]
                grads = jax.tree.unflatten(treedef, [o[0] for o in outs])
                resid = jax.tree.unflatten(treedef, [o[1] for o in outs])
                loss = jax.lax.pmean(loss, "pod")
                return loss, grads, resid

            batch_specs = jax.tree.map(lambda _: P("pod"), batch)
            ef_specs = (jax.tree.map(lambda _: P(), state.ef)
                        if state.ef is not None else None)
            loss, grads, residual = shard_map(
                pod_local, mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P(), state.params),
                          ef_specs, batch_specs),
                out_specs=(P(), jax.tree.map(lambda _: P(), state.params),
                           jax.tree.map(lambda _: P(), state.params)),
                axis_names={"pod"}, check_vma=False,
            )(state.params, state.ef, batch)
            if state.ef is not None:
                new_ef = residual
            metrics = {"loss": loss}
        else:
            loss, grads = grads_of(state.params, batch)
            metrics = {"loss": loss}
        lr = sched(state.step)
        new_params, new_opt, om = adamw_update(grads, state.opt, state.params,
                                               lr, tcfg.adamw)
        metrics.update(om)
        metrics["lr"] = lr
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1, ef=new_ef), metrics

    return train_step
