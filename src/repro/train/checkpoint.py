"""Checkpointing + restart (fault tolerance).

Layout: <dir>/step_<N>/  with one .npz of flattened leaves + a msgpack
manifest of the treedef/dtypes/shapes. Writes are atomic (tmp dir + rename),
so a preemption mid-write never corrupts the latest checkpoint; ``restore``
picks the newest complete step. The manifest stores *logical* content only —
nothing about the mesh — so a checkpoint taken on 2 pods restores onto 1 or 4
(elastic scaling): pjit reshards on the way in via the target shardings.

At real scale the np.savez leaves become per-host shard files keyed by the
same manifest (array-contents-per-shard is the only part that changes); the
restore path and atomicity protocol are identical.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomically save a pytree as checkpoint ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    manifest = {
        "step": step,
        "names": names,
        "dtypes": [str(a.dtype) for a in leaves],
        "shapes": [list(a.shape) for a in leaves],
    }
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": a for i, a in enumerate(leaves)})
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.msgpack")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, *, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``like``; optionally device_put with
    ``shardings`` (congruent pytree) — this is where elastic resharding
    happens. Returns (tree, step) or (None, None) if nothing to restore."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = [data[f"a{i}"] for i in range(len(manifest["names"]))]
    flat_like, treedef = jax.tree.flatten(like)
    assert len(flat_like) == len(leaves), \
        f"checkpoint has {len(leaves)} leaves, expected {len(flat_like)}"
    if shardings is not None:
        flat_sh = jax.tree.leaves(shardings,
                                  is_leaf=lambda x: x is None) if not isinstance(
            shardings, list) else shardings
        flat_sh = jax.tree.flatten(shardings)[0]
        out = [jax.device_put(a.astype(l.dtype), s)
               for a, l, s in zip(leaves, flat_like, flat_sh)]
    else:
        out = [jnp.asarray(a, dtype=l.dtype) for a, l in zip(leaves, flat_like)]
    return jax.tree.unflatten(treedef, out), step


def retain_last(ckpt_dir: str, keep: int = 3):
    """GC old checkpoints, keeping the newest ``keep``."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (
        int(m.group(1)) for m in
        (_STEP_RE.match(d) for d in os.listdir(ckpt_dir)) if m))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
