"""Tier-A training: (1) pretrain the CNN on the detection-proxy task,
(2) offline channel-selection statistics, (3) train the BaF predictor with the
original network FROZEN — exactly the paper's protocol (§4):

  * inputs to the BaF net are the *dequantized quantized* selected channels
    (quantization in the loop, per-example side info),
  * target is the post-activation tensor Y = sigma(Z) of the split layer,
  * loss is the Charbonnier penalty (eq. 7), eps = 1e-3,
  * consolidation (eq. 6) is ignored during training,
  * no gradient ever reaches the original network weights.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core.baf import BaFConvConfig, baf_conv_predict, init_baf_conv
from repro.core.losses import charbonnier
from repro.core.quant import compute_quant_params, dequantize, quantize
from repro.core.selection import correlation_matrix_conv, select_channels
from repro.data.synthetic import ShapesDatasetConfig, shapes_batch_iterator
from repro.models.cnn import CNNConfig, cnn_cloud, cnn_edge, cnn_forward, cnn_forward_train, init_cnn
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_with_warmup


# ---------------------------------------------------------------------------
# 1. CNN pretraining (stand-in for darknet COCO weights — DESIGN.md §6)
# ---------------------------------------------------------------------------

def pretrain_cnn(cnn_cfg: CNNConfig, data_cfg: ShapesDatasetConfig, *,
                 steps: int = 400, lr: float = 3e-3, seed: int = 0,
                 log_every: int = 100, verbose: bool = True):
    key = jax.random.PRNGKey(seed)
    params = init_cnn(key, cnn_cfg)
    opt = adamw_init(params)
    sched = cosine_with_warmup(lr, steps // 10, steps)
    ocfg = AdamWConfig(weight_decay=1e-4)

    @jax.jit
    def step_fn(params, opt, step, img, labels):
        def loss_fn(p):
            logits, new_p = cnn_forward_train(p, img)
            ll = jax.nn.log_softmax(logits)
            loss = -jnp.mean(jnp.take_along_axis(ll, labels[:, None], 1))
            acc = jnp.mean(jnp.argmax(logits, -1) == labels)
            return loss, (acc, new_p)
        (loss, (acc, new_p)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        # BN EMA stats come back through new_p; trainable update via AdamW
        new_params, new_opt, _ = adamw_update(grads, opt, params, sched(step), ocfg)
        # keep the EMA'd BN running stats from the train-mode forward
        def merge(path, a, b):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            return b if name in ("mean", "var") else a
        merged = jax.tree_util.tree_map_with_path(merge, new_params, new_p)
        return merged, new_opt, loss, acc

    it = shapes_batch_iterator(data_cfg, seed=seed + 1)
    hist = []
    for s in range(steps):
        img, labels = next(it)
        params, opt, loss, acc = step_fn(params, opt, jnp.asarray(s), img, labels)
        if s % log_every == 0 or s == steps - 1:
            hist.append((s, float(loss), float(acc)))
            if verbose:
                print(f"  [cnn-pretrain] step {s:4d} loss {float(loss):.4f} acc {float(acc):.3f}")
    return params, hist


def eval_cnn(params, data_cfg: ShapesDatasetConfig, *, batches: int = 20, seed: int = 10_000):
    fwd = jax.jit(cnn_forward)
    it = shapes_batch_iterator(data_cfg, seed=seed)
    accs = []
    for _ in range(batches):
        img, labels = next(it)
        accs.append(float(jnp.mean(jnp.argmax(fwd(params, img), -1) == labels)))
    return float(np.mean(accs))


# ---------------------------------------------------------------------------
# 2. Offline channel selection (paper: 1k COCO images; here: n batches)
# ---------------------------------------------------------------------------

def compute_channel_order(params, data_cfg: ShapesDatasetConfig, *,
                          batches: int = 16, seed: int = 999):
    edge = jax.jit(lambda p, img: cnn_edge(p, img))
    it = shapes_batch_iterator(data_cfg, seed=seed)
    acc = None
    for _ in range(batches):
        img, _ = next(it)
        x_in, z = edge(params, img)
        r = correlation_matrix_conv(z, x_in)
        acc = r if acc is None else acc + r
    return select_channels(acc / batches)


# ---------------------------------------------------------------------------
# 3. BaF predictor training (frozen original network)
# ---------------------------------------------------------------------------

class BaFTrainResult(NamedTuple):
    baf_params: dict
    sel_idx: np.ndarray
    losses: list


def make_baf_loss(cnn_params, sel_idx, bits: int):
    """Charbonnier loss of sigma(Z_tilde) vs sigma(Z), quantization in the loop."""
    sel = jnp.asarray(sel_idx, jnp.int32)
    split = cnn_params["split"]

    def loss_fn(baf_params, z):
        y_target = nn.leaky_relu(z)                       # sigma(Z): paper's Y
        z_sel = z[..., sel]
        qp = compute_quant_params(z_sel, bits, per_example=True)
        z_hat_sel = dequantize(quantize(z_sel, qp), qp)   # decoder sees this
        z_tilde = baf_conv_predict(baf_params, split["conv"], split["bn"],
                                   sel, z_hat_sel)        # no consolidation (§4)
        return charbonnier(nn.leaky_relu(z_tilde), y_target)

    return loss_fn


def train_baf(cnn_params, cnn_cfg: CNNConfig, data_cfg: ShapesDatasetConfig,
              sel_idx, *, bits: int = 8, hidden: int = 64, steps: int = 600,
              lr: float = 2e-3, seed: int = 42, log_every: int = 200,
              verbose: bool = True) -> BaFTrainResult:
    c = len(sel_idx)
    bcfg = BaFConvConfig(c=c, q=cnn_cfg.split_q, hidden=hidden)
    baf_params = init_baf_conv(jax.random.PRNGKey(seed), bcfg)
    opt = adamw_init(baf_params)
    sched = cosine_with_warmup(lr, max(steps // 20, 1), steps)
    ocfg = AdamWConfig(weight_decay=0.0)   # small predictor; paper uses none
    loss_fn = make_baf_loss(cnn_params, sel_idx, bits)
    edge = jax.jit(lambda img: cnn_edge(cnn_params, img)[1])

    @jax.jit
    def step_fn(baf_params, opt, step, z):
        loss, grads = jax.value_and_grad(loss_fn)(baf_params, z)
        new_bp, new_opt, _ = adamw_update(grads, opt, baf_params, sched(step), ocfg)
        return new_bp, new_opt, loss

    it = shapes_batch_iterator(data_cfg, seed=seed + 7)
    losses = []
    for s in range(steps):
        img, _ = next(it)
        z = edge(img)                      # frozen original network
        baf_params, opt, loss = step_fn(baf_params, opt, jnp.asarray(s), z)
        if s % log_every == 0 or s == steps - 1:
            losses.append((s, float(loss)))
            if verbose:
                print(f"  [baf C={c} n={bits}] step {s:4d} charbonnier {float(loss):.5f}")
    return BaFTrainResult(baf_params=baf_params, sel_idx=np.asarray(sel_idx),
                          losses=losses)
