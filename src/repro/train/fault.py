"""Fault-tolerance policy for 1000+-node synchronous training.

What is implemented and testable here on one host:
  * atomic checkpoint / newest-complete restore / retention (checkpoint.py)
  * auto-resume: launch/train.py restores the latest step and the data
    pipeline is a pure function of (seed, step), so a restarted job replays
    the exact batch sequence (tests/test_checkpoint.py asserts bit-identical
    losses after a simulated preemption)
  * elastic scaling: checkpoints are mesh-agnostic; restore reshards onto the
    current mesh (pod count is a config, not baked into the checkpoint)
  * a watchdog harness (below) that wraps the step function with a deadline
    and converts hangs into clean preemptions (single-host analogue of the
    straggler escape hatch).

Design notes for the real cluster (documented, not simulatable on CPU):
  * node failure: jax.distributed heartbeats surface as a collective error;
    the runner traps it, the scheduler replaces the node, all hosts restart
    from the latest complete checkpoint (bounded loss = ckpt interval).
  * stragglers: synchronous SPMD cannot drop a slow worker mid-step; the
    mitigations are (a) checkpoint-interval bounding, (b) per-step deadline
    watchdog that forces the restart path when a step exceeds k x median
    (the watchdog below), (c) data-pipeline prefetch so input skew never
    stalls the collective.
  * the compressed cross-pod all-reduce (optim/grad_compress.py) shrinks the
    DCN phase — the phase with the highest straggler variance.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class StepDeadlineExceeded(RuntimeError):
    pass


@dataclass
class Watchdog:
    """Per-step deadline: k x running-median wall time (min_floor seconds).

    Call ``guard(fn)`` around the blocking step; on overrun raises
    StepDeadlineExceeded, which launch/train.py turns into
    checkpoint-and-exit (the cluster runner then reschedules).
    SIGALRM-based — single-host dev harness; the cluster version uses the
    runner's external heartbeat instead.
    """
    factor: float = 5.0
    min_floor: float = 30.0
    history: list = field(default_factory=list)

    def _deadline(self) -> float:
        if not self.history:
            return max(self.min_floor, 300.0)
        med = sorted(self.history)[len(self.history) // 2]
        return max(self.min_floor, self.factor * med)

    def guard(self, fn: Callable, *args, **kwargs):
        deadline = self._deadline()

        def _raise(signum, frame):
            raise StepDeadlineExceeded(f"step exceeded {deadline:.1f}s")

        old = signal.signal(signal.SIGALRM, _raise)
        signal.setitimer(signal.ITIMER_REAL, deadline)
        t0 = time.monotonic()
        try:
            out = fn(*args, **kwargs)
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0.0)
            signal.signal(signal.SIGALRM, old)
        self.history.append(time.monotonic() - t0)
        if len(self.history) > 64:
            self.history.pop(0)
        return out


@dataclass
class PreemptionFlag:
    """Cooperative preemption: SIGTERM sets a flag; the train loop checkpoints
    at the next step boundary and exits 0 (clean requeue)."""
    triggered: bool = False

    def install(self):
        def _handler(signum, frame):
            self.triggered = True
        signal.signal(signal.SIGTERM, _handler)
        return self
