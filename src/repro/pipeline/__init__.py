"""Unified compression-pipeline API: declarative operating points compiled
into executable plans.

    from repro import pipeline

    op   = pipeline.OperatingPoint(c=8, bits=6, backend="rans")
    plan = pipeline.compile(op, pipeline.ModelSpec(sel_idx=sel,
                                                   params=params,
                                                   baf_params=baf))
    blob    = plan.encode(z)                 # quantize/tile/entropy-code
    decoded = plan.decode_batch([blob, ...]) # vectorized host decode
    z_tilde = plan.restore(decoded)          # jitted BaF restore

One plan owns a request's coding configuration end to end; serve/ and the
benchmarks construct all coding state through this package (the old loose
``(C, bits, backend)`` entry points in core/split.py are deprecated shims).
"""
from repro.pipeline.op import (SESSION_WIRE_VERSION, WIRE_PROFILE_VERSION,
                               Capabilities, NegotiationError, OperatingPoint,
                               negotiate, negotiate_session, negotiate_tasks)
from repro.pipeline.plan import (CompressionPlan, DecodedBatch, ModelSpec,
                                 WireBlob, blob_from_tensor, compile)

__all__ = [
    "SESSION_WIRE_VERSION", "WIRE_PROFILE_VERSION", "Capabilities",
    "NegotiationError", "OperatingPoint", "negotiate", "negotiate_session",
    "negotiate_tasks",
    "CompressionPlan", "DecodedBatch", "ModelSpec", "WireBlob",
    "blob_from_tensor", "compile",
]
