"""Declarative operating points for the BaF compression pipeline.

An :class:`OperatingPoint` is the single value object that names *everything*
about how one request's split activation is coded on the wire: how many
channels travel (C), the quantizer depth (n), which entropy backend codes the
stream, whether the channels are tiled into a 2D image first, which context
model the coder runs, and which wire-profile generation the container speaks.
Before this existed, ``(C, bits, backend)`` tuples were re-plumbed by hand
through core/split.py, core/codec.py, and every serve/ call site.

``auto`` fields resolve from the backend registry (``resolve()``), so callers
write ``OperatingPoint(c=8, bits=6, backend="rans")`` and the pipeline fills
in the tiling detour and context mode the backend needs.

Capability negotiation lets a gateway refuse — or, when allowed, downgrade —
an operating point whose wire profile or backend it does not speak, instead
of failing deep inside the codec on the cloud side.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

# Wire-profile generation: bumped with the container magic (core/codec.py
# writes BaF2). A gateway advertises the profiles it can decode; encode and
# decode sides must agree before any bytes move.
WIRE_PROFILE_VERSION = 2

# Streaming-session wire profile: the SessionFrame framing that wraps I/P
# frames (repro.session.codec writes SSF1). Negotiated separately from the
# container profile — an endpoint may decode plain containers but not speak
# the temporal-delta framing, in which case sessions fall back to I-only.
SESSION_WIRE_VERSION = 1

_TILING_MODES = ("auto", "tiled", "direct")
_CONTEXT_MODES = ("auto", "none", "static", "adaptive")


class NegotiationError(ValueError):
    """The gateway cannot serve this operating point and may not downgrade."""


@dataclass(frozen=True)
class OperatingPoint:
    """One coding configuration, end to end.

    c        : transmitted channels (power of two; tiling constraint)
    bits     : quantizer depth n
    backend  : entropy backend family ('zlib' | 'png' | 'raw' | 'rans' | ...)
    tiling   : 'auto' resolves from the backend ('tiled' = 2D image detour,
               'direct' = channel-last tensor coded as-is)
    context  : 'auto' resolves from the backend; 'adaptive' upgrades 'rans'
               to the context-adaptive coder ('rans-ctx' on the wire)
    profile  : wire-profile generation this point's containers speak
    """
    c: int
    bits: int
    backend: str = "zlib"
    tiling: str = "auto"
    context: str = "auto"
    profile: int = WIRE_PROFILE_VERSION

    def __post_init__(self):
        if self.c < 1:
            raise ValueError(f"c must be >= 1, got {self.c}")
        if not 1 <= self.bits <= 16:
            raise ValueError(f"bits must be in 1..16, got {self.bits}")
        if self.tiling not in _TILING_MODES:
            raise ValueError(f"tiling must be one of {_TILING_MODES}, "
                             f"got {self.tiling!r}")
        if self.context not in _CONTEXT_MODES:
            raise ValueError(f"context must be one of {_CONTEXT_MODES}, "
                             f"got {self.context!r}")

    # -- resolution ---------------------------------------------------------
    @property
    def wire_backend(self) -> str:
        """Registry name of the backend that actually codes the stream.

        ``context='adaptive'`` upgrades the static 'rans' family to the
        context-adaptive coder; every other combination passes through.
        """
        if self.backend == "rans" and self.context == "adaptive":
            return "rans-ctx"
        return self.backend

    def resolve(self) -> "OperatingPoint":
        """Fill every ``auto`` field from the backend registry."""
        from repro.core import codec as wire
        tiling = self.tiling
        if tiling == "auto":
            tiling = ("tiled" if wire.backend_wants_tiling(self.wire_backend)
                      else "direct")
        if tiling == "tiled" and (self.c & (self.c - 1)) != 0:
            raise ValueError(
                f"backend {self.wire_backend!r} tiles the channels into a 2D "
                f"image, which requires a power-of-two C (got {self.c}); "
                f"use a direct backend such as 'rans' for this C")
        context = self.context
        if context == "auto":
            context = {"rans": "static", "rans-ctx": "adaptive"}.get(
                self.backend, "none")
        if tiling == self.tiling and context == self.context:
            return self
        return dataclasses.replace(self, tiling=tiling, context=context)

    def with_backend(self, backend: str) -> "OperatingPoint":
        """Same point on a different backend; tiling/context re-resolve."""
        if backend == self.backend:
            return self
        return dataclasses.replace(self, backend=backend, tiling="auto",
                                   context="auto")


@dataclass(frozen=True)
class Capabilities:
    """What one gateway (or decoder) can speak.

    profiles  : wire-profile generations the decode side understands
    backends  : entropy backends it can decode (None = everything registered);
                order matters — the first entry is the downgrade target
    max_bits  : deepest quantizer it will decode
    downgrade : whether :func:`negotiate` may substitute a supported backend
                / shallower bit depth instead of refusing
    session_profiles : SessionFrame framing generations the decode side
                speaks (empty tuple = no temporal P-frames; sessions run
                I-only when downgrade is allowed)
    task_heads : downstream task heads this endpoint serves (None = every
                registered head; see repro.tasks.heads). A declared task
                the endpoint does not serve is dropped when downgrade is
                allowed, refused otherwise (:func:`negotiate_tasks`)
    """
    profiles: tuple = (WIRE_PROFILE_VERSION,)
    backends: tuple | None = None
    max_bits: int = 16
    downgrade: bool = True
    session_profiles: tuple = (SESSION_WIRE_VERSION,)
    task_heads: tuple | None = None

    def serves_task(self, name: str) -> bool:
        return self.task_heads is None or name in self.task_heads

    def speaks_backend(self, name: str) -> bool:
        return self.backends is None or name in self.backends


def negotiate(op: OperatingPoint, caps: Capabilities | None) -> OperatingPoint:
    """Fit ``op`` to ``caps``: pass through, downgrade, or refuse.

    A wire-profile mismatch always refuses — there is no lower profile to
    fall back to, the container format itself is foreign. Backend and bit
    depth downgrade to the capabilities' preferred backend / max depth when
    ``caps.downgrade`` allows it, otherwise raise :class:`NegotiationError`.
    """
    if caps is None:
        return op
    if op.profile not in caps.profiles:
        raise NegotiationError(
            f"gateway speaks wire profiles {caps.profiles}, operating point "
            f"requires profile {op.profile}")
    out = op
    if not caps.speaks_backend(out.wire_backend):
        if not caps.downgrade or not caps.backends:
            raise NegotiationError(
                f"gateway cannot decode backend {out.wire_backend!r} "
                f"(speaks {caps.backends}) and downgrade is disabled")
        # full re-base, context included: downgrading 'rans'+adaptive to
        # plain 'rans' must also drop the context upgrade that made the
        # wire backend unsupported in the first place
        out = dataclasses.replace(out, backend=caps.backends[0],
                                  tiling="auto", context="auto")
    if out.bits > caps.max_bits:
        if not caps.downgrade:
            raise NegotiationError(
                f"gateway decodes at most {caps.max_bits} bits, operating "
                f"point requires {out.bits}")
        out = dataclasses.replace(out, bits=caps.max_bits)
    try:
        # negotiation promises a servable point: a downgrade that lands on
        # a backend unable to code this C (e.g. rans C=12 -> tiled zlib,
        # which needs a power-of-two C) must refuse here, not blow up with
        # a ValueError at plan-compile time
        out.resolve()
    except ValueError as e:
        raise NegotiationError(
            f"no supported backend can serve this operating point: {e}"
        ) from None
    return out


def negotiate_session(caps: Capabilities | None, *,
                      profile: int = SESSION_WIRE_VERSION) -> bool:
    """Can a session stream temporal P-frames at this endpoint?

    True = the decode side speaks the SessionFrame profile, P-frames may
    flow. False = it does not, but downgrade is allowed, so the session runs
    I-frame-only (every frame a standalone container — correct, just more
    bits). Refusal (profile unknown AND downgrade disabled) raises
    :class:`NegotiationError` before any frame is encoded.
    """
    if caps is None or profile in caps.session_profiles:
        return True
    if caps.downgrade:
        return False
    raise NegotiationError(
        f"endpoint speaks session profiles {caps.session_profiles}, stream "
        f"requires profile {profile} and downgrade is disabled")


def negotiate_tasks(tasks, caps: Capabilities | None) -> tuple:
    """Fit a tenant's declared task set to the endpoint's served heads.

    Returns the effective task tuple (declaration order kept, duplicates
    dropped). A declared head the endpoint does not serve is dropped when
    ``caps.downgrade`` allows it — the tenant is served the subset and,
    through bit allocation, only pays for that subset; with downgrade
    disabled, or when nothing declared survives, the whole declaration is
    refused (:class:`NegotiationError`). Task negotiation never touches the
    operating point — wire-profile and backend fitting stay in
    :func:`negotiate`, so a foreign wire profile still refuses regardless
    of how few heads a tenant declares.
    """
    declared = tuple(dict.fromkeys(tasks))
    if not declared:
        raise ValueError("empty task declaration (declare at least one "
                         "task head)")
    if caps is None or caps.task_heads is None:
        return declared
    served = tuple(t for t in declared if t in caps.task_heads)
    if served == declared:
        return declared
    dropped = [t for t in declared if t not in caps.task_heads]
    if not caps.downgrade:
        raise NegotiationError(
            f"endpoint serves task heads {sorted(caps.task_heads)}, tenant "
            f"declared unsupported {dropped} and downgrade is disabled")
    if not served:
        raise NegotiationError(
            f"endpoint serves task heads {sorted(caps.task_heads)}; none of "
            f"the declared tasks {list(declared)} can be served")
    return served
