"""Plan/execute split for the BaF compression pipeline.

``compile(op, model_spec)`` turns a declarative :class:`OperatingPoint` plus
model weights into a :class:`CompressionPlan` — a jit-like executable object
owning one request's coding configuration end to end:

    plan.encode(z)            -> WireBlob         (quantize/tile/entropy-code)
    plan.decode_batch(blobs)  -> DecodedBatch     (vectorized host decode)
    plan.restore(decoded)     -> z_tilde          (jitted BaF restore)

Compilation is cached per ``(operating point, model spec, flags)`` and the
device-side restore reuses one jitted trace per distinct
``(C, bits, batch-bucket)`` — callers that bucket their batches
(serve/batcher.py) never re-trace, no matter how many plans they hold.

``decode_batch`` is the batched/vectorized host decode path: N same-bucket
wire blobs are parsed once, their payloads coalesced through the backend's
vectorized batch decoder (core/codec.py ``decode_many``), and the channel
untiling runs as one numpy pass over the whole stack instead of one
jnp dispatch per request. Outputs are bit-identical to per-request decode.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import codec as wire
from repro.core.quant import compute_quant_params, quantize
from repro.core.split import (SplitStats, restore_codes, restore_codes_fused)
from repro.core.tiling import tile_batch, tile_grid
from repro.obs import hooks
from repro.pipeline.op import OperatingPoint


@dataclass(frozen=True, eq=False)
class ModelSpec:
    """Model-side inputs a plan binds to.

    eq/hash are object identity: two specs are "the same model" only when
    they are literally the same object, which is what the compile cache keys
    on (params pytrees are not hashable, and value-comparing them per encode
    would defeat the point of a cached plan).

    ``params``/``baf_params`` may be None for an encode/decode-only plan
    (e.g. the edge side of a split deployment); ``restore`` then refuses.

    Compiled plans cache *on the spec itself* (``_plans``), so dropping the
    spec (e.g. on a model reload) releases its plans and weights — nothing
    is pinned in a process-wide cache.
    """
    sel_idx: Any                 # (C,) ordered selected-channel indices
    params: Any = None           # CNN params (models/cnn.py); needs ["split"]
    baf_params: Any = None       # trained BaF predictor for this C
    _plans: dict = field(default_factory=dict, init=False, repr=False)


@dataclass(frozen=True)
class WireBlob:
    """One request's serialized container plus the plan-level metadata the
    cloud side needs before it decodes a single payload byte: the operating
    point and the codes shape (the micro-batcher buckets on these)."""
    data: bytes
    op: OperatingPoint
    shape: tuple                 # codes shape, (B, H, W, C)
    stats: SplitStats | None = None

    @property
    def nbytes(self) -> int:
        return len(self.data)

    def to_tensor(self) -> wire.EncodedTensor:
        """Parse back to the wire-format view (header validation included)."""
        return wire.EncodedTensor.from_bytes(self.data)


@dataclass
class DecodedBatch:
    """Stacked decode output, restore-ready."""
    codes: np.ndarray            # (N, H, W, C) integer codes
    mins: np.ndarray             # (N, 1, 1, C) fp16
    maxs: np.ndarray             # (N, 1, 1, C) fp16

    def __len__(self) -> int:
        return self.codes.shape[0]

    def pad_to(self, target: int) -> "DecodedBatch":
        """Pad to a bucket size by repeating the last row (dropped after
        restore); the device never sees a shape outside the bucket set."""
        n = len(self)
        if target < n:
            raise ValueError(f"cannot pad {n} rows down to {target}")
        if target == n:
            return self
        reps = [1] * n
        reps[-1] += target - n
        rep = np.repeat
        return DecodedBatch(codes=rep(self.codes, reps, axis=0),
                            mins=rep(self.mins, reps, axis=0),
                            maxs=rep(self.maxs, reps, axis=0))


def _untile_np(tiles: np.ndarray, c: int) -> np.ndarray:
    """(M, rows*H, cols*W) tiled images -> (M, H, W, C), pure numpy.

    Vectorized over the whole stack — the host-side inverse of
    core/tiling.py's ``tile_channels`` without a per-request jnp dispatch.
    """
    rows, cols = tile_grid(c)
    m, th, tw = tiles.shape
    h, w = th // rows, tw // cols
    y = tiles.reshape(m, rows, h, cols, w)
    y = y.transpose(0, 1, 3, 2, 4).reshape(m, c, h, w)
    return np.ascontiguousarray(y.transpose(0, 2, 3, 1))


class CompressionPlan:
    """Executable coding pipeline for one operating point.

    Build via :func:`compile` (cached), not directly. The plan owns the
    resolved operating point; every stage reads configuration from it, so
    there is no loose ``(C, bits, backend)`` plumbing between stages.
    """

    def __init__(self, op: OperatingPoint, spec: ModelSpec, *,
                 fused: bool = True, consolidation: bool = True):
        self.op = op.resolve()
        self.spec = spec
        self.fused = fused
        self.consolidation = consolidation
        sel = np.asarray(spec.sel_idx)
        if sel.shape[0] != self.op.c:
            raise ValueError(
                f"operating point transmits C={self.op.c} channels but the "
                f"model spec selects {sel.shape[0]}")
        self._sel = jnp.asarray(sel, jnp.int32)
        # resolve the backend now: a typo'd backend fails at compile time,
        # not on the first request
        wire.backend_wants_tiling(self.op.wire_backend)

    # -- keys ---------------------------------------------------------------
    @property
    def trace_key(self) -> tuple:
        """What the jitted restore actually specializes on (plus the batch
        bucket shape supplied at call time)."""
        return (self.op.c, self.op.bits, self.fused, self.consolidation)

    # -- encode (edge side) -------------------------------------------------
    def _quantize(self, z) -> tuple[np.ndarray, "object"]:
        """Shared quantize stage -> (codes (B,H,W,C), QuantParams)."""
        z_sel = z[..., self._sel]
        qp = compute_quant_params(z_sel, self.op.bits, per_example=True)
        return np.asarray(quantize(z_sel, qp)), qp

    def quantize(self, z) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Quantize the split activation -> (codes, mins, maxs), no coding.

        The reference the round-trip property tests compare decode against —
        it shares the quantize stage with :meth:`encode` by construction.
        """
        codes, qp = self._quantize(z)
        b, c = codes.shape[0], codes.shape[-1]
        mins = np.asarray(qp.mins, np.float16).reshape(b, 1, 1, c)
        maxs = np.asarray(qp.maxs, np.float16).reshape(b, 1, 1, c)
        return codes, mins, maxs

    def encode_codes(self, codes: np.ndarray, qp,
                     raw_bits: int | None = None) -> WireBlob:
        """Tile + entropy-code an already-quantized code tensor (B, H, W, C).

        The coding half of :meth:`encode`, exposed so stateful callers can
        feed *derived* code tensors — the streaming session codec codes the
        temporal delta of two frames' codes through exactly this path, so
        P-frames ride the same backends, container format, and wire
        accounting as I-frames. ``qp`` carries the side info serialized with
        the stream (the current frame's quant params, not the reference's).
        """
        with hooks.timed("pipeline.encode", backend=self.op.wire_backend):
            if self.op.tiling == "tiled":
                # image-style codecs get the paper's tiled 2D image, one per
                # batch element, stacked vertically
                tiled = np.asarray(tile_batch(jnp.asarray(codes)))
                stream = tiled.reshape(-1, tiled.shape[-1])
            else:
                # direct backends (rANS) code the channel-last tensor as-is
                stream = codes
            enc = wire.encode(stream, qp, backend=self.op.wire_backend)
            if raw_bits is None:
                raw_bits = int(np.prod(codes.shape)) * 32
            stats = SplitStats(
                total_bits=enc.total_bits(),
                payload_bits=8 * len(enc.payload),
                side_info_bits=8 * len(enc.side_info),
                raw_bits=raw_bits,
                entropy_bits=wire.empirical_entropy_bits(codes, self.op.bits),
                wire_bits=enc.wire_bits(),
            )
            return WireBlob(data=enc.to_bytes(), op=self.op,
                            shape=tuple(codes.shape), stats=stats)

    def encode(self, z) -> WireBlob:
        """Quantize/tile/entropy-code the split activation ``z`` (B, H, W, P)
        and serialize the container; returns the blob with wire accounting."""
        codes, qp = self._quantize(z)
        return self.encode_codes(codes, qp,
                                 raw_bits=int(np.prod(z.shape)) * 32)

    # -- decode (cloud side, host) ------------------------------------------
    def _check_blob(self, blob: WireBlob, shape: tuple) -> None:
        if blob.op.resolve() != self.op:
            raise ValueError(
                f"blob was encoded at {blob.op.resolve()}, this plan "
                f"executes {self.op}")
        if tuple(blob.shape) != shape:
            raise ValueError(
                f"mixed shapes in one decode batch: {blob.shape} vs {shape}")

    def decode(self, blob: WireBlob) -> DecodedBatch:
        """Single-blob decode (= ``decode_batch([blob])``)."""
        return self.decode_batch([blob])

    def decode_batch(self, blobs: "list[WireBlob]") -> DecodedBatch:
        """Vectorized host decode across N same-bucket requests.

        All blobs must share this plan's operating point and one codes shape
        (the micro-batcher's bucket invariant). Payload entropy-decode is
        coalesced by the backend's batch decoder where registered and the
        untiling runs once over the whole stack; output rows are bit-exact
        with per-request decode, in input order.
        """
        if not blobs:
            raise ValueError("decode_batch needs at least one blob")
        with hooks.timed("pipeline.decode_batch",
                         backend=self.op.wire_backend):
            hooks.observe("pipeline_decode_batch_size", len(blobs))
            shape = tuple(blobs[0].shape)
            for blob in blobs:
                self._check_blob(blob, shape)
            encs = [wire.EncodedTensor.from_bytes(b.data) for b in blobs]
            streams, qps = wire.decode_many(encs)
            n = len(blobs)
            b, h, w, c = shape
            if self.op.tiling == "tiled":
                rows, cols = tile_grid(c)
                codes = _untile_np(
                    streams.reshape(n * b, rows * h, cols * w), c)
            else:
                codes = streams.reshape(n * b, h, w, c)
            mins = np.stack([np.asarray(qp.mins, np.float16) for qp in qps])
            maxs = np.stack([np.asarray(qp.maxs, np.float16) for qp in qps])
            return DecodedBatch(codes=codes,
                                mins=mins.reshape(n * b, 1, 1, c),
                                maxs=maxs.reshape(n * b, 1, 1, c))

    # -- restore (cloud side, device) ---------------------------------------
    def restore(self, decoded: DecodedBatch):
        """Dequantize + BaF restore; returns the full-width split activation.

        One jitted trace per ``(C, bits, bucket shape)`` — shared process-wide
        across plans and gateways via the module-level jit caches in
        core/split.py.
        """
        if self.spec.params is None or self.spec.baf_params is None:
            raise ValueError(
                "plan was compiled without model weights (encode/decode "
                "only); supply params and baf_params in the ModelSpec "
                "to restore")
        # timer covers trace/dispatch; device completion belongs to the
        # caller's compute measurement (the executor's wall_s blocks on it)
        with hooks.timed("pipeline.restore", fused=self.fused):
            split = self.spec.params["split"]
            codes = jnp.asarray(decoded.codes)
            mins = jnp.asarray(decoded.mins)
            maxs = jnp.asarray(decoded.maxs)
            if self.fused:
                return restore_codes_fused(self.spec.baf_params, split,
                                           self._sel, codes, mins, maxs,
                                           bits=self.op.bits)
            return restore_codes(self.spec.baf_params, split, self._sel,
                                 codes, mins, maxs, bits=self.op.bits,
                                 consolidation=self.consolidation)

    def __repr__(self) -> str:
        return (f"CompressionPlan(op={self.op}, fused={self.fused}, "
                f"consolidation={self.consolidation})")


def blob_from_tensor(enc: wire.EncodedTensor, op: OperatingPoint,
                     batch: int) -> WireBlob:
    """Wrap a parsed wire tensor as a plan blob (legacy-entry-point bridge).

    The container's ``shape`` field stores the coded *stream* shape — the
    tiled 2D image for image-style backends — so the codes shape is
    reconstructed from the operating point's tiling grid.
    """
    rop = op.resolve()
    if rop.tiling == "tiled":
        rows, cols = tile_grid(rop.c)
        th, tw = enc.shape
        shape = (batch, th // (batch * rows), tw // cols, rop.c)
    else:
        shape = tuple(enc.shape)
    return WireBlob(data=enc.to_bytes(), op=rop, shape=shape)


def compile(op: OperatingPoint, model_spec: ModelSpec, *,   # noqa: A001
            fused: bool = True,
            consolidation: bool = True) -> CompressionPlan:
    """Build (or fetch the cached) plan for ``op`` against ``model_spec``.

    Plans cache on the spec object per ``(op, flags)`` — the cache lives
    exactly as long as the spec does, so dropped specs free their weights.
    The underlying jit traces are cached independently per
    ``(C, bits, bucket)``, so even a fresh plan object re-traces nothing
    the process has already compiled.
    """
    # key on the *resolved* point: an auto-field op on the encode side and
    # its resolved twin from a decoded blob must share one cached plan
    op = op.resolve()
    key = (op, fused, consolidation)
    plan = model_spec._plans.get(key)
    if plan is None:
        plan = CompressionPlan(op, model_spec, fused=fused,
                               consolidation=consolidation)
        model_spec._plans[key] = plan
    return plan
