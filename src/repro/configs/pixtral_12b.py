"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409]: mistral-nemo-style decoder
backbone; pixtral-ViT vision frontend is a STUB (precomputed patch embeddings
mixed into the sequence per the assignment)."""
from repro.configs.base import ArchConfig


def full_config() -> ArchConfig:
    return ArchConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=131072, act="swiglu", qkv_bias=False,
        rope_theta=1_000_000.0, norm="rmsnorm", embed_inputs=False,
        note="backbone only; vision tower stubbed — inputs are precomputed "
             "(B, S, 5120) embeddings (patch+text), vocab used for the LM head",
    )


def smoke_config() -> ArchConfig:
    return full_config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab=512)
