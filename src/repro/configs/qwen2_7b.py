"""Qwen2-7B [arXiv:2407.10671]: dense GQA, QKV bias, SwiGLU."""
from repro.configs.base import ArchConfig


def full_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-7b", family="dense",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
        d_ff=18944, vocab=152064, act="swiglu", qkv_bias=True,
        rope_theta=1_000_000.0, norm="rmsnorm",
        note="GQA kv=4; QKV bias per Qwen2 report",
    )


def smoke_config() -> ArchConfig:
    return full_config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=160, vocab=512)
