"""Zamba2-1.2B [arXiv:2411.15242]: Mamba-2 backbone + shared attention block.

The shared full-attention+MLP block (weights reused at every application) runs
every ``shared_attn_every`` Mamba-2 layers; in long-context mode it switches to
windowed attention (window=4096) so the whole model stays sub-quadratic —
deviation from the paper noted in DESIGN.md §5."""
from repro.configs.base import ArchConfig, HybridConfig, SSMConfig


def full_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
        d_ff=8192, vocab=32000, act="gelu", qkv_bias=False,
        rope_theta=10_000.0, norm="rmsnorm",
        ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2,
                      conv_width=4, chunk=128),
        hybrid=HybridConfig(shared_attn_every=6, attn_window_long=4096),
        note="38 mamba2 layers; shared MHA(32h,d64)+MLP(8192) block every 6 "
             "layers; windowed attn in long-context mode",
    )


def smoke_config() -> ArchConfig:
    return full_config().with_(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512,
        ssm=SSMConfig(kind="mamba2", state_dim=16, head_dim=16, expand=2,
                      conv_width=4, chunk=8),
        hybrid=HybridConfig(shared_attn_every=2, attn_window_long=16))
