"""Qwen2-72B [arXiv:2407.10671]: dense GQA, QKV bias, SwiGLU."""
from repro.configs.base import ArchConfig


def full_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-72b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=29568, vocab=152064, act="swiglu", qkv_bias=True,
        rope_theta=1_000_000.0, norm="rmsnorm",
        serve_weight_sharding="2d",
        note="GQA kv=8; QKV bias per Qwen2 report",
    )


def smoke_config() -> ArchConfig:
    return full_config().with_(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=160, vocab=512)
