"""Whisper-tiny [arXiv:2212.04356]: 4+4 enc-dec, d=384, MHA, GELU.

Conv audio frontend is a STUB per the assignment: input_specs provide
precomputed frame embeddings (B, S_enc, 384)."""
from repro.configs.base import ArchConfig, EncDecConfig


def full_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny", family="audio",
        n_layers=8,  # 4 enc + 4 dec (see encdec)
        d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
        d_ff=1536, vocab=51865, act="gelu", qkv_bias=True,
        rope_theta=10_000.0, norm="layernorm", embed_inputs=False,
        encdec=EncDecConfig(enc_layers=4, dec_layers=4),
        note="enc-dec; conv frontend stubbed (precomputed frame embeddings); "
             "learned positions in decoder, none needed for stub encoder",
    )


def smoke_config() -> ArchConfig:
    return full_config().with_(
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
        encdec=EncDecConfig(enc_layers=2, dec_layers=2))
