"""Architecture registry: one module per assigned arch (+ the paper's CNN).

``get_config(arch_id)`` returns the full published config;
``get_smoke_config(arch_id)`` a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "rwkv6_3b", "qwen2_72b", "starcoder2_15b", "nemotron4_15b", "qwen2_7b",
    "whisper_tiny", "pixtral_12b", "olmoe_1b_7b", "arctic_480b", "zamba2_1p2b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIASES.update({
    "rwkv6-3b": "rwkv6_3b", "qwen2-72b": "qwen2_72b",
    "starcoder2-15b": "starcoder2_15b", "nemotron-4-15b": "nemotron4_15b",
    "qwen2-7b": "qwen2_7b", "whisper-tiny": "whisper_tiny",
    "pixtral-12b": "pixtral_12b", "olmoe-1b-7b": "olmoe_1b_7b",
    "arctic-480b": "arctic_480b", "zamba2-1.2b": "zamba2_1p2b",
})


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch)


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{canonical(arch)}")


def get_config(arch: str):
    return _module(arch).full_config()


def get_smoke_config(arch: str):
    return _module(arch).smoke_config()
