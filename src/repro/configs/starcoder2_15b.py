"""StarCoder2-15B [arXiv:2402.19173]: dense GQA, RoPE, GELU, LayerNorm, bias."""
from repro.configs.base import ArchConfig


def full_config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-15b", family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
        d_ff=24576, vocab=49152, act="gelu", qkv_bias=True,
        rope_theta=100_000.0, norm="layernorm",
        note="GQA kv=4; standard MLP w/ GELU; LayerNorm",
    )


def smoke_config() -> ArchConfig:
    return full_config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=512)
