"""Nemotron-4-15B [arXiv:2402.16819]: dense GQA, squared-ReLU MLP, huge vocab."""
from repro.configs.base import ArchConfig


def full_config() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-15b", family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab=256_000, act="sq_relu", qkv_bias=False,
        rope_theta=10_000.0, norm="layernorm",
        note="GQA kv=8; squared-ReLU; 256k SentencePiece vocab",
    )


def smoke_config() -> ArchConfig:
    return full_config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=1024)
