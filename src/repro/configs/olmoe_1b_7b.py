"""OLMoE-1B-7B [arXiv:2409.02060]: 64-expert top-8 MoE, tiny experts."""
from repro.configs.base import ArchConfig, MoEConfig


def full_config() -> ArchConfig:
    return ArchConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1024, vocab=50304, act="swiglu", qkv_bias=False,
        rope_theta=10_000.0, norm="rmsnorm",
        moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
        note="MHA (kv=16); 64 experts top-8, expert d_ff=1024",
    )


def smoke_config() -> ArchConfig:
    return full_config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=64, vocab=512,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64))
