"""RWKV-6 (Finch) 3B [arXiv:2404.05892]: attention-free, data-dependent decay."""
from repro.configs.base import ArchConfig, SSMConfig


def full_config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
        d_ff=8960, vocab=65536, act="sq_relu", qkv_bias=False,
        rope_theta=10_000.0, norm="layernorm",
        ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk=16, decay_lora=64),
        note="attention-free; wkv heads of dim 64; channel-mix d_ff=8960; "
             "chunk=16 keeps the factorized decay inside fp32 range",
    )


def smoke_config() -> ArchConfig:
    return full_config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        ssm=SSMConfig(kind="rwkv6", head_dim=16, chunk=8, decay_lora=8))
