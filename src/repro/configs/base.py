"""Unified architecture config.

One dataclass describes every assigned architecture; family-specific blocks
(MoE, SSM, enc-dec, hybrid schedule) are optional sub-configs. The model zoo
(repro.models.lm / encdec) interprets it; the launch layer reads the shape
table for input_specs.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Assigned input shapes (identical for every LM arch; see system brief)
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k":    dict(seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524_288, global_batch=1,   kind="long"),
}


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"           # 'mamba2' | 'rwkv6'
    state_dim: int = 64            # N (mamba2) / head key dim (rwkv6)
    head_dim: int = 64
    expand: int = 2                # mamba2 inner expansion
    conv_width: int = 4            # mamba2 depthwise conv
    chunk: int = 128               # chunked-scan block length
    decay_lora: int = 64           # rwkv6 data-dependent decay LoRA rank


@dataclass(frozen=True)
class HybridConfig:
    shared_attn_every: int = 6     # zamba2: shared attn block cadence
    attn_window_long: int = 4_096  # windowed attention in long-context mode


@dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int = 4
    dec_layers: int = 4
    cross_attention: bool = True
    enc_len_decode: int = 1_500    # encoder length used for decode cells


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    act: str = "swiglu"             # swiglu | gelu | sq_relu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = False
    embed_inputs: bool = True       # False: inputs are precomputed embeds (vlm/audio enc)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    # serving policy: '2d' (fsdp+tp weights, all-gather per layer) or 'tp'
    serve_weight_sharding: str = "tp"
    # attention backend for full-attention layers: 'full' is O(S^2);
    # 'window' enables banded attention (long-context mode for hybrids)
    attn_window: Optional[int] = None
    dtype: object = jnp.bfloat16
    param_dtype: object = jnp.float32
    # 'f32' keeps fp32 cotangents through the norm casts; 'bf16' uses the
    # low-memory custom-vjp rmsnorm (fp32 row stats, bf16 cotangents)
    norm_grad: str = "f32"
    note: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def supported_shapes(self) -> Tuple[str, ...]:
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.subquadratic:
            out.append("long_500k")
        return tuple(out)

    def with_(self, **kw) -> "ArchConfig":
        return replace(self, **kw)


def _attn_params(cfg: ArchConfig) -> int:
    hd = cfg.hd
    return cfg.d_model * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
        + cfg.n_heads * hd * cfg.d_model


def _ffn_params(cfg: ArchConfig, d_ff=None) -> int:
    d_ff = d_ff or cfg.d_ff
    return (3 if cfg.act == "swiglu" else 2) * cfg.d_model * d_ff


def _rwkv6_layer_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    # time mix (wr, wk, wv, wg, wo) + channel-mix receptance + 2 d_ff mats
    # + DDLerp/decay LoRAs
    s = cfg.ssm or SSMConfig()
    return 6 * d * d + 2 * d * cfg.d_ff + 2 * 5 * 32 * d \
        + 2 * s.decay_lora * d


def _mamba2_layer_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    s = cfg.ssm or SSMConfig()
    d_inner = s.expand * d
    n_heads = d_inner // s.head_dim
    proj_out = 2 * d_inner + 2 * s.state_dim + n_heads
    return d * (d_inner + proj_out - d_inner) + d * d_inner \
        + d_inner * d + s.conv_width * (d_inner + 2 * s.state_dim)


def param_count_dense(cfg: ArchConfig) -> int:
    """Analytic parameter count (used for roofline MODEL_FLOPS = 6*N*D).

    Family-aware: ssm counts RWKV-6 blocks, hybrid counts Mamba-2 blocks +
    ONE shared attention block (weights reused across applications)."""
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        return cfg.n_layers * _rwkv6_layer_params(cfg) + emb
    if cfg.family == "hybrid":
        shared = _attn_params(cfg) + _ffn_params(cfg)
        return cfg.n_layers * _mamba2_layer_params(cfg) + shared + emb
    per_layer = _attn_params(cfg) + _ffn_params(cfg) + 2 * cfg.d_model
    return cfg.n_layers * per_layer + emb


def active_param_count(cfg: ArchConfig) -> int:
    """Active (per-token) parameters — MoE counts only top_k experts; the
    hybrid shared attention block counts once per APPLICATION (it executes
    every ``shared_attn_every`` layers even though weights are reused)."""
    if cfg.family == "hybrid":
        emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
        n_apps = -(-cfg.n_layers // (cfg.hybrid.shared_attn_every
                                     if cfg.hybrid else 6))
        shared = _attn_params(cfg) + _ffn_params(cfg)
        return cfg.n_layers * _mamba2_layer_params(cfg) \
            + n_apps * shared + emb
    if cfg.moe is None:
        return param_count_dense(cfg)
    hd = cfg.hd
    attn = cfg.d_model * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
        + cfg.n_heads * hd * cfg.d_model
    ffn_factor = 3 if cfg.act == "swiglu" else 2
    expert = ffn_factor * cfg.d_model * cfg.moe.d_ff_expert
    active_ffn = cfg.moe.top_k * expert
    if cfg.moe.dense_residual:
        active_ffn += ffn_factor * cfg.d_model * cfg.d_ff
    router = cfg.d_model * cfg.moe.num_experts
    per_layer = attn + active_ffn + router + 2 * cfg.d_model
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return cfg.n_layers * per_layer + emb


def total_param_count(cfg: ArchConfig) -> int:
    if cfg.moe is None:
        return param_count_dense(cfg)
    hd = cfg.hd
    attn = cfg.d_model * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
        + cfg.n_heads * hd * cfg.d_model
    ffn_factor = 3 if cfg.act == "swiglu" else 2
    expert = ffn_factor * cfg.d_model * cfg.moe.d_ff_expert
    ffn = cfg.moe.num_experts * expert
    if cfg.moe.dense_residual:
        ffn += ffn_factor * cfg.d_model * cfg.d_ff
    router = cfg.d_model * cfg.moe.num_experts
    per_layer = attn + ffn + router + 2 * cfg.d_model
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return cfg.n_layers * per_layer + emb
