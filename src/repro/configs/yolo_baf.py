"""The paper's own configuration: YOLO-v3 @512x512, split at layer l=12
(tensor 64x64x256, Q=128), C in {8..128}, n in {2..8} — Tier A."""
from repro.data.synthetic import ShapesDatasetConfig
from repro.models.cnn import CNNConfig

PAPER_C_SWEEP = (8, 16, 32, 64, 128)
PAPER_N_SWEEP = (2, 3, 4, 5, 6, 7, 8)
PAPER_SPLIT_LAYER = 12
PAPER_TENSOR_SHAPE = (64, 64, 256)    # N x M x P at input 512x512


def full_config() -> CNNConfig:
    """Full paper geometry (used by kernels/dry-run; too big to train on CPU)."""
    return CNNConfig(width_mult=1.0, input_size=512, num_classes=80,
                     tail_res_blocks=2)


def smoke_config() -> CNNConfig:
    """Reduced-width, same topology — what the CPU experiments train."""
    return CNNConfig(width_mult=0.25, input_size=128, num_classes=8,
                     tail_res_blocks=1)


def smoke_data_config() -> ShapesDatasetConfig:
    return ShapesDatasetConfig(image_size=128, num_classes=8, batch_size=16)
