"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base]: dense-MoE
hybrid — 128-expert top-2 MoE in parallel with a dense residual FFN."""
from repro.configs.base import ArchConfig, MoEConfig


def full_config() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b", family="moe",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=4864, vocab=32000, act="swiglu", qkv_bias=False,
        rope_theta=10_000.0, norm="rmsnorm",
        moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                      dense_residual=True),
        serve_weight_sharding="2d",
        note="GQA kv=8; 128e top-2 + parallel dense residual FFN (d_ff=4864)",
    )


def smoke_config() -> ArchConfig:
    return full_config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=512,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                      dense_residual=True))
