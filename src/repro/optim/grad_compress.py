"""Compressed cross-pod gradient all-reduce — the paper's quantizer (eq. 4)
applied to the slowest link in multi-pod training (DESIGN.md §2 Tier C).

Within a pod, gradients reduce over the ``data`` axis in full precision (ICI
is fast). Across pods (DCN), each pod quantizes its partial gradient with a
SHARED per-tensor scale (agreed via a tiny fp32 max all-reduce), integer-sums
the int8 codes (the only bulk DCN traffic — 4x fewer wire bytes than fp32,
2x fewer than bf16, visible in the compiled collective bytes), and
dequantizes. Error feedback carries the quantization residual to the next
step — the training-time analogue of the paper's consolidation (eq. 6), which
has no gradient meaning (DESIGN.md §6).

Implemented as jax.shard_map mapped over ONLY the ``pod`` axis
(axis_names={'pod'}); data/model axes stay automatic, so this composes with
the surrounding pjit partitioning of the gradient tree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def _quantized_psum_one(g: jax.Array, bits: int, axis: str, npod: int):
    levels = (1 << (bits - 1)) - 1            # signed symmetric codes
    amax = jax.lax.pmax(jnp.max(jnp.abs(g)).astype(jnp.float32), axis)
    scale = jnp.maximum(amax, 1e-30) / levels
    codes = jnp.clip(jnp.round(g.astype(jnp.float32) / scale),
                     -levels, levels)
    codes = codes.astype(jnp.int8) if bits <= 8 else codes.astype(jnp.int16)
    # bulk wire traffic: ring exchange of the NARROW codes (npod-1 ppermutes
    # of int8/int16 = bits/32 of the fp32 bytes), local int32 accumulation.
    # (a psum of int32-upcast codes would move 4 B/elem — no saving at all;
    # measured and fixed in EXPERIMENTS.md §Tier-C.)
    perm = [(i, (i + 1) % npod) for i in range(npod)]
    acc = codes.astype(jnp.int32)
    buf = codes
    for _ in range(npod - 1):
        buf = jax.lax.ppermute(buf, axis, perm)
        acc = acc + buf.astype(jnp.int32)
    mean = acc.astype(jnp.float32) * scale / npod
    local = codes.astype(jnp.float32) * scale  # what this pod contributed
    return mean.astype(g.dtype), (g.astype(jnp.float32) - local)


def quantized_pod_mean(grads, mesh, *, bits: int = 8, pod_axis: str = "pod"):
    """Mean-reduce a gradient pytree across pods with n-bit codes.

    grads: per-pod partial means (pod-varying). Returns (mean_grads,
    residuals) where residuals are this pod's quantization error (feed back
    into the next step's grads for error-feedback compression).
    """
    npod = mesh.shape[pod_axis]
    flat, treedef = jax.tree.flatten(grads)

    def f(*leaves):
        outs = [_quantized_psum_one(g, bits, pod_axis, npod) for g in leaves]
        return tuple(o[0] for o in outs), tuple(o[1] for o in outs)

    means, residuals = shard_map(
        f, mesh=mesh, in_specs=P(), out_specs=(P(), P()),
        axis_names={pod_axis}, check_vma=False)(*flat)
    return jax.tree.unflatten(treedef, means), jax.tree.unflatten(treedef, residuals)
