"""AdamW, implemented directly on pytrees (no optax).

State is a pytree congruent with the params, so ZeRO-style sharding is just
"shard the state with the same PartitionSpec as the param" — the distributed
layer (distributed/sharding.py) relies on this congruence.

Moments are kept in fp32 regardless of param dtype (mixed-precision master
strategy lives in train/trainer.py, which keeps fp32 master params and casts
to bf16 for the forward).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    # params matching this predicate (path, leaf) are excluded from decay
    decay_mask: Optional[Callable[[tuple, Any], bool]] = None


class AdamWState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def _default_decay_mask(path, leaf) -> bool:
    """Decay matrices; skip vectors/scalars (norms, biases, BN, PReLU)."""
    return leaf.ndim >= 2


def adamw_update(grads, state: AdamWState, params, lr, cfg: AdamWConfig = AdamWConfig()):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = gnorm
    count = state.count + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd_mu(g, mu):
        return cfg.b1 * mu + (1 - cfg.b1) * g.astype(jnp.float32)

    def upd_nu(g, nu):
        return cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32))

    mu = jax.tree.map(upd_mu, grads, state.mu)
    nu = jax.tree.map(upd_nu, grads, state.nu)

    mask_fn = cfg.decay_mask or _default_decay_mask
    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    decay_flags = [mask_fn(p, leaf) for p, leaf in paths]
    flags_tree = jax.tree.unflatten(jax.tree.structure(params), decay_flags)

    def upd_p(p, m, v, decay):
        step = m / b1c / (jnp.sqrt(v / b2c) + cfg.eps)
        if decay and cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd_p, params, mu, nu, flags_tree)
    return new_params, AdamWState(count=count, mu=mu, nu=nu), metrics
