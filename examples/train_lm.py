"""End-to-end LM training driver: train a ~25M-param qwen2-family model for a
few hundred steps on the synthetic token stream, with checkpointing and a
simulated preemption + resume in the middle.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--fast]

This is the single-host face of launch/train.py: same TrainState, same
checkpoint protocol, same data determinism — scaled to CPU.
"""
import argparse
import os
import shutil
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.synthetic import TokenDatasetConfig, token_batch_iterator
from repro.models.lm import init_lm
from repro.nn import count_params
from repro.train import checkpoint as ckpt
from repro.train.trainer import TrainConfig, init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--fast", action="store_true")
args = ap.parse_args()
steps = 40 if args.fast else args.steps

# ~25M params: scale the qwen2 smoke family up
cfg = get_smoke_config("qwen2_7b").with_(
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
    d_ff=704, vocab=32_000)
params = init_lm(jax.random.PRNGKey(0), cfg)
print(f"model: {cfg.name}-family, {count_params(params)/1e6:.1f}M params")

tcfg = TrainConfig(num_microbatches=2, peak_lr=1e-3,
                   warmup_steps=max(steps // 10, 5), total_steps=steps)
state = init_train_state(params, tcfg)
step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
data = TokenDatasetConfig(vocab_size=cfg.vocab, seq_len=128, batch_size=8)

ckpt_dir = "/tmp/repro_train_lm_ck"
shutil.rmtree(ckpt_dir, ignore_errors=True)

half = steps // 2
it = token_batch_iterator(data, seed=0)
t0 = time.time()
first = None
for s in range(half):
    state, m = step_fn(state, next(it))
    first = first if first is not None else float(m["loss"])
    if s % 20 == 0:
        print(f"step {s:4d}  loss {float(m['loss']):.4f}  "
              f"({(time.time()-t0)/(s+1):.2f}s/step)", flush=True)

print(f"== simulated preemption at step {half}: checkpoint + discard state ==")
ckpt.save(ckpt_dir, half, state)
del state

restored, at = ckpt.restore(
    ckpt_dir, like=init_train_state(init_lm(jax.random.PRNGKey(0), cfg), tcfg))
print(f"== resumed from step {at} ==")
state = restored
it = token_batch_iterator(data, seed=0, start_step=at)   # exact replay
for s in range(at, steps):
    state, m = step_fn(state, next(it))
    if s % 20 == 0 or s == steps - 1:
        print(f"step {s:4d}  loss {float(m['loss']):.4f}", flush=True)

final = float(m["loss"])
print(f"loss {first:.3f} -> {final:.3f} over {steps} steps "
      f"({time.time()-t0:.0f}s total); checkpoint protocol exercised "
      f"(atomic save, newest-complete restore, deterministic data replay)")
assert final < first, "loss should decrease"
