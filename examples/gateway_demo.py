"""Compression-plan + adaptive rate control demo.

    PYTHONPATH=src python examples/gateway_demo.py [--fast]

1. pretrain the tiny Tier-A CNN and train one BaF predictor per C,
2. compile a CompressionPlan from a declarative OperatingPoint and run one
   request through encode -> decode_batch -> restore by hand (the staged
   API everything below is built on),
3. build the offline rate-distortion table by sweeping operating points with
   the repo's fidelity metrics (serve/rate_control.py),
4. set a PSNR quality floor and serve the same traffic through gateways whose
   channels grant a full and a HALVED per-tick bit budget — the controller
   moves to a cheaper operating point while staying at/above the floor,
5. multi-tenant serving over one shared uplink (premium + best effort
   through the DRR scheduler),
6. capability negotiation: a gateway that does not speak rANS downgrades
   the operating point to zlib instead of failing on the cloud side,
7. overload: a 3x burst against a multi-queue cloud executor with
   priority-tiered admission — best effort browns out first, every
   rejection is an explicit RequestShed, and telemetry keeps the shed
   series apart from the served-latency percentiles.
"""
import argparse

import numpy as np

from repro import pipeline
from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.data.synthetic import shapes_batch_iterator
from repro.serve import (Capabilities, ChannelConfig, ContentKeyedController,
                         LinearCostModel, MultiQueueExecutor,
                         MultiTenantGateway, QueueDepthAdmission,
                         RateController, RequestShed, ServingGateway,
                         SimulatedChannel, TenantRequest, TenantSpec,
                         build_rd_table, priority_depth_limits)
from repro.train.baf_trainer import compute_channel_order, pretrain_cnn, train_baf

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true")
args = ap.parse_args()

cnn_cfg = smoke_config()._replace(input_size=32)
data_cfg = smoke_data_config()._replace(image_size=32, batch_size=8)

print("== 1. train tiny CNN + per-C BaF bank ==")
params, _ = pretrain_cnn(cnn_cfg, data_cfg,
                         steps=40 if args.fast else 150, verbose=False)
order = compute_channel_order(params, data_cfg, batches=4).order
bank = {}
for c in (4, 8, 16):
    res = train_baf(params, cnn_cfg, data_cfg, order[:c], bits=8, hidden=8,
                    steps=40 if args.fast else 150, verbose=False)
    bank[c] = (res.baf_params, res.sel_idx)
    print(f"  BaF trained for C={c}")

print("== 1b. the plan API: one operating point, end to end ==")
op = pipeline.OperatingPoint(c=8, bits=6, backend="rans")
spec = pipeline.ModelSpec(sel_idx=np.asarray(bank[8][1]), params=params,
                          baf_params=bank[8][0])
plan = pipeline.compile(op, spec)
from repro.core.split import _jitted_cnn_fns
edge_fn, cloud_fn = _jitted_cnn_fns()
demo_imgs, _ = next(shapes_batch_iterator(
    data_cfg._replace(batch_size=1), seed=1))
blobs = [plan.encode(edge_fn(params, np.asarray(demo_imgs))) for _ in range(4)]
decoded = plan.decode_batch(blobs)          # one vectorized host decode
z_tilde = plan.restore(decoded)             # one jitted BaF restore
logits = cloud_fn(params, z_tilde)
print(f"  op {op.resolve()}")
print(f"  4 requests -> {sum(b.nbytes for b in blobs)} wire bytes, "
      f"decode_batch {decoded.codes.shape}, logits {np.asarray(logits).shape}")

print("== 2. offline rate-distortion table (C x bits sweep) ==")
imgs, _ = next(shapes_batch_iterator(data_cfg, seed=99))
table = build_rd_table(params, bank, imgs, bits_sweep=(2, 4, 8))
print(f"{'C':>4} {'bits':>5} {'wire bits/img':>14} {'psnr_db':>8} {'kl':>8}")
for p in sorted(table, key=lambda p: p.bits_per_example):
    print(f"{p.op.c:>4} {p.op.bits:>5} {p.bits_per_example:>14.0f} "
          f"{p.psnr_db:>8.2f} {p.kl:>8.4f}")

floor_db = float(np.median([p.psnr_db for p in table]))
rc = RateController(table, quality_floor_db=floor_db)
print(f"quality floor: {floor_db:.2f} dB "
      f"(cheapest point meeting it: {rc.cheapest_meeting_floor().op})")

print("== 3. serve under full vs halved channel bit budget ==")
meeting = [p for p in table if p.psnr_db >= floor_db]
budget_full = int(1.05 * max(p.bits_per_example for p in meeting))
budget_half = budget_full // 2
traffic, _ = next(shapes_batch_iterator(data_cfg._replace(batch_size=4),
                                        seed=2024))
traffic = np.asarray(traffic)

chosen = {}
for label, budget in (("full", budget_full), ("half", budget_half)):
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=2e6, base_latency_s=0.01,
                                        tick_s=10.0,
                                        budget_bits_per_tick=budget))
    gw = ServingGateway(params, bank, controller=rc, channel=ch, max_batch=4)
    # the first request of each tick sees the full budget: that choice is the
    # operating point the controller assigns to this channel condition
    responses, tel = gw.serve(traffic[:1])
    chosen[label] = responses[0]
    print(f"budget {label:>4} ({budget:>7} bits/tick) -> "
          f"op {responses[0].op}, wire bits {responses[0].stats.total_bits}")

full_op, half_op = chosen["full"].op, chosen["half"].op
full_pt = next(p for p in table if p.op == full_op)
half_pt = next(p for p in table if p.op == half_op)
print(f"\nfull-budget op {full_op}: psnr {full_pt.psnr_db:.2f} dB")
print(f"half-budget op {half_op}: psnr {half_pt.psnr_db:.2f} dB")
assert full_op != half_op, "halving the budget should change the op point"
assert full_pt.psnr_db >= floor_db and half_pt.psnr_db >= floor_db, \
    "both operating points must respect the quality floor"
print("OK: halved budget moved to a cheaper operating point, floor respected")

print("\n== 4. mixed traffic on the half-budget channel ==")
ch = SimulatedChannel(ChannelConfig(bandwidth_bps=2e6, base_latency_s=0.01,
                                    tick_s=0.05,
                                    budget_bits_per_tick=budget_half))
gw = ServingGateway(params, bank, controller=rc, channel=ch, max_batch=4)
responses, tel = gw.serve(traffic)
print(tel.format_summary())

print("\n== 5. multi-tenant: premium + best-effort share one uplink ==")
# Two tenants compete for a shared per-tick bit budget through the DRR
# scheduler: "premium" carries 3x the weight and a strict PSNR floor,
# "besteffort" takes what is left. The content-keyed controller shifts each
# request's RD estimates by its own activation statistics before choosing
# (C, bits), so operating points are per request, not per calibration run.
ck = ContentKeyedController(table, quality_floor_db=floor_db)
tenants = [TenantSpec("premium", weight=3.0, quality_floor_db=floor_db),
           TenantSpec("besteffort", weight=1.0, quality_floor_db=0.0)]
mt = MultiTenantGateway(
    params, bank, tenants=tenants, controller=ck,
    channel_cfg=ChannelConfig(bandwidth_bps=2e6, base_latency_s=0.01),
    budget_bits_per_tick=budget_full, tick_s=0.05,
    max_batch=4, batch_window_s=0.02)
stream, _ = next(shapes_batch_iterator(data_cfg, seed=7))
stream = np.asarray(stream)
work = [TenantRequest(tenant=("premium", "besteffort")[i % 2],
                      img=stream[i % len(stream)], t_submit=0.004 * i)
        for i in range(12)]
mt_resp, mt_tel = mt.serve_tenants(work)
print(mt_tel.format_summary())
shares = mt.last_scheduler.grant_shares()
print(f"uplink grant shares : premium {shares['premium']:.2f}, "
      f"besteffort {shares['besteffort']:.2f}")
assert len(mt_resp["premium"]) == 6 and len(mt_resp["besteffort"]) == 6
print("OK: both tenants fully served over the shared budget")

print("\n== 6. capability negotiation: a zlib-only gateway meets rANS ==")
rans_op = pipeline.OperatingPoint(c=8, bits=8, backend="rans")
legacy = ServingGateway(params, bank, default_op=rans_op,
                        capabilities=Capabilities(backends=("zlib",)),
                        max_batch=4)
resp, _ = legacy.serve(traffic[:2])
print(f"requested {rans_op.backend!r} -> served on "
      f"{resp[0].op.wire_backend!r} (downgraded, not refused)")
try:
    ServingGateway(params, bank, default_op=rans_op,
                   capabilities=Capabilities(backends=("zlib",),
                                             downgrade=False))
except pipeline.NegotiationError as e:
    print(f"strict gateway refuses instead: {e}")
print("OK: negotiation decided before any bytes were encoded")

print("\n== 7. overload: 3x burst through priority tiers, explicit shed ==")
# The cloud is 2 parallel queues on a deterministic cost model: capacity is
# 2 queues * 4 req / (4 ms + 4 * 1 ms) = 1000 req/s. The burst offers 3x
# that. Queue-depth admission holds a tier ladder — bronze sheds at
# backlog 2, silver at 4, gold at 6 — so the brown-out eats best effort
# first while gold keeps flowing.
cost = LinearCostModel(base_s=0.004, per_item_s=0.001)
tiers = [TenantSpec("gold", weight=2.0, priority=2),
         TenantSpec("silver", priority=1),
         TenantSpec("bronze", priority=0)]
ov = MultiTenantGateway(
    params, bank, tenants=tiers,
    channel_cfg=ChannelConfig(bandwidth_bps=50e6, base_latency_s=0.001),
    default_op=pipeline.OperatingPoint(c=8, bits=8), max_batch=4,
    batch_window_s=0.002,
    executor=MultiQueueExecutor(2, cost=cost),
    admission=QueueDepthAdmission(
        2, per_priority=priority_depth_limits(2, [0, 1, 2], headroom=2)))
burst = [TenantRequest(("gold", "silver", "bronze")[i % 3],
                       stream[i % len(stream)], t_submit=i / 3000.0)
         for i in range(48)]
ov_resp, ov_tel = ov.serve_tenants(burst)
print(ov_tel.format_summary())
served = {t: sum(not isinstance(r, RequestShed) for r in rs)
          for t, rs in ov_resp.items()}
shed = ov_tel.shed_by_tenant()
for t in ("gold", "silver", "bronze"):
    print(f"  {t:<7}: served {served[t]:>2}, shed {shed.get(t, 0):>2}")
assert sum(served.values()) + len(ov_tel.shed) == len(burst), "silent drop!"
assert shed.get("bronze", 0) >= shed.get("gold", 0)
if ov_tel.shed:
    print(f"example shed reason: {ov_tel.shed[0].reason!r}")
print("OK: 3x burst browned out low tiers first; every request ended as a "
      "response or an explicit RequestShed")
