"""Tier-C example: the paper's scheme on a multi-pod pipeline boundary.

    PYTHONPATH=src python examples/pod_boundary_compression.py

Runs on 8 fake devices arranged as (pod=2, data=2, model=2). The hidden
stream crossing the pod axis is (a) full-tensor-quantized (eq. 4) or
(b) subset-transmitted + BaF-restored (§3.3), and we report wire bytes and
restoration error vs the uncompressed bf16 transfer.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import set_mesh
from repro.core.baf import BaFStreamConfig, init_baf_stream
from repro.distributed.pipeline import (compressed_pod_transfer,
                                        subset_pod_transfer, wire_bytes)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
B, S, D, C = 4, 64, 256, 64

key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (B, S, D), jnp.float32)
with set_mesh(mesh):
    xs = jax.device_put(x, NamedSharding(mesh, P()))

    # (a) full-tensor n-bit transfer
    for bits in (8, 4):
        y = jax.jit(lambda t: compressed_pod_transfer(
            t, mesh, bits=bits, dtype=jnp.float32))(xs)
        comp, raw = wire_bytes(x, bits)
        err = float(jnp.max(jnp.abs(y - x)))  # both pods hold the same x here
        print(f"[full  n={bits}] wire {comp:>8,} B vs bf16 {raw:>8,} B "
              f"({raw/comp:.1f}x less)  max dequant err {err:.4f}")

    # (b) the paper's subset + BaF restore: transmit C of D channels
    sel = jnp.arange(C)                      # offline order (eqs. 2-3)
    baf = init_baf_stream(jax.random.PRNGKey(1),
                          BaFStreamConfig(c=C, d_in=D, hidden=128))
    w_block = jax.random.normal(jax.random.PRNGKey(2), (D, D)) * 0.05
    frozen_block = lambda t: t @ w_block     # receiver's boundary block

    y = jax.jit(lambda t: subset_pod_transfer(
        t, mesh, sel_idx=sel, baf_params=baf, forward_fn=frozen_block,
        bits=8, dtype=jnp.float32))(xs)
    comp, raw = wire_bytes(x[..., :C], 8)
    print(f"[subset C={C}/{D} n=8] wire {comp:>8,} B vs bf16 full "
          f"{x.size*2:>8,} B ({x.size*2/comp:.1f}x less); restored "
          f"{y.shape} (predictor untrained here; Tier-A trains it)")
print("wire-byte accounting matches the paper's: payload + C*32-bit side info")
