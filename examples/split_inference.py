"""Tier-A end-to-end: the paper's full experiment at reduced scale.

    PYTHONPATH=src python examples/split_inference.py [--fast]

1. pretrain the YOLO-front CNN on the synthetic detection-proxy task
   (stand-in for darknet COCO weights — DESIGN.md §6),
2. offline channel selection from 1k-image-equivalent statistics (eqs. 2-3),
3. train BaF predictors for a sweep of C with the original network frozen
   (Charbonnier loss, eq. 7, quantization in the loop),
4. run real split inference through the wire codec and report
   accuracy + bits-per-image vs the cloud-only baseline (Figs. 3-4).
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.split import SplitInferenceEngine
from repro.data.synthetic import shapes_batch_iterator
from repro.train.baf_trainer import (compute_channel_order, eval_cnn,
                                     pretrain_cnn, train_baf)

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true")
args = ap.parse_args()

cnn_cfg = smoke_config()._replace(input_size=64)
data_cfg = smoke_data_config()._replace(image_size=64, batch_size=16)
P = cnn_cfg.split_p

print(f"== 1. pretrain CNN (split layer: {P} channels) ==")
t0 = time.time()
params, _ = pretrain_cnn(cnn_cfg, data_cfg,
                         steps=150 if args.fast else 800, verbose=True)
cloud_acc = eval_cnn(params, data_cfg, batches=20)
print(f"cloud-only accuracy: {cloud_acc:.3f}  ({time.time()-t0:.0f}s)")

print("== 2. offline channel selection (eqs. 2-3) ==")
order = compute_channel_order(params, data_cfg,
                              batches=4 if args.fast else 12).order
print(f"channel order (best-first): {order[:10]}...")

print("== 3-4. BaF sweep over C (n=8), real wire ==")
print(f"{'C':>4} {'acc':>7} {'Δacc':>7} {'bits/img':>10} {'vs raw':>8}")
for c in (4, 8, 16, 32, 64):
    if c > P:
        break
    res = train_baf(params, cnn_cfg, data_cfg, order[:c], bits=8, hidden=16,
                    steps=100 if args.fast else 400, verbose=False)
    eng = SplitInferenceEngine(params, res.baf_params, res.sel_idx, bits=8)
    it = shapes_batch_iterator(data_cfg, seed=10_000)
    accs, bits = [], []
    for _ in range(4 if args.fast else 15):
        img, labels = next(it)
        logits, stats = eng(img)
        accs.append(float(jnp.mean(jnp.argmax(logits, -1) == labels)))
        bits.append(stats.total_bits / img.shape[0])
    acc = float(np.mean(accs))
    print(f"{c:>4} {acc:>7.3f} {cloud_acc-acc:>+7.3f} {np.mean(bits):>10.0f} "
          f"{1 - np.mean(bits)/stats.raw_bits*img.shape[0]:>8.1%}")
print("(paper: C=P/4 with <1% accuracy loss at ~62% bit reduction; the "
      "reduced-scale trend reproduces that shape — see EXPERIMENTS.md)")
