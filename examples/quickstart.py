"""Quickstart: the paper's pipeline on one tensor, in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

Takes a feature tensor, selects the most-correlated channel subset (eqs. 2-3),
quantizes + tiles + entropy-codes it (eqs. 4-5, §3.2), restores the full
tensor with an (untrained) BaF predictor (§3.3) and consolidates the
transmitted channels (eq. 6), printing real wire bits at every stage.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.core import codec as wire
from repro.core.baf import BaFConvConfig, baf_conv_predict, init_baf_conv
from repro.core.quant import QuantParams, compute_quant_params, dequantize, quantize
from repro.core.selection import correlation_matrix_conv, select_channels
from repro.core.tiling import tile_batch, untile_batch

B, H, W, P, Q, C, BITS = 2, 16, 16, 64, 32, 16, 8

key = jax.random.PRNGKey(0)
# a stand-in split layer: X (B, 2H, 2W, Q) --conv s2 + BN--> Z (B, H, W, P)
x = jax.random.normal(key, (B, 2 * H, 2 * W, Q))
conv = nn.init_conv(jax.random.PRNGKey(1), Q, P, 3, bias=False)
bn = nn.init_batchnorm(P)
z = nn.batchnorm_apply(bn, nn.conv_apply(conv, x, stride=2))
print(f"split tensor Z: {z.shape}, raw fp32 = {z.size * 32:,} bits")

# 1. channel selection (offline, eqs. 2-3)
rho = correlation_matrix_conv(z, x)
order = select_channels(rho).order
sel = jnp.asarray(order[:C])
print(f"selected C={C} of P={P} channels: {np.asarray(sel)[:8]}...")

# 2. quantize (eq. 4) + tile (§3.2) + entropy-code
z_sel = z[..., sel]
qp = compute_quant_params(z_sel, BITS, per_example=True)
codes = quantize(z_sel, qp)
tiled = np.asarray(tile_batch(codes)).reshape(-1, 4 * W)  # 4x4 grid for C=16
enc = wire.encode(tiled, qp, backend="zlib")
blob = enc.to_bytes()
print(f"wire: {enc.total_bits():,} bits "
      f"({8 * len(enc.side_info):,} side info) -> "
      f"{1 - enc.total_bits() / (z.size * 32):.1%} smaller than raw fp32")

# 3. cloud: decode (eq. 5) + BaF restore (§3.3) + consolidation (eq. 6)
dec = wire.EncodedTensor.from_bytes(blob)
stream, qp_rx = wire.decode(dec)
codes_rx = untile_batch(jnp.asarray(stream.reshape(B, -1, 4 * W)), C)
qp_rx = QuantParams(mins=jnp.asarray(qp_rx.mins).reshape(B, 1, 1, C),
                    maxs=jnp.asarray(qp_rx.maxs).reshape(B, 1, 1, C),
                    bits=BITS)
z_hat_sel = dequantize(codes_rx, qp_rx)
print(f"decode exact: {bool(jnp.all(codes_rx == codes))}, "
      f"dequant err <= step/2: "
      f"{float(jnp.max(jnp.abs(z_hat_sel - z_sel))):.4f}")

baf = init_baf_conv(jax.random.PRNGKey(2), BaFConvConfig(c=C, q=Q, hidden=32))
z_tilde = baf_conv_predict(baf, conv, bn, sel, z_hat_sel,
                           codes=codes_rx, qp=qp_rx)
print(f"restored all-P tensor: {z_tilde.shape} (untrained predictor; "
      f"examples/split_inference.py trains it end to end)")
# the transmitted channels are consolidated: they sit inside their bins
from repro.core.quant import bin_bounds
lo, hi = bin_bounds(codes_rx, qp_rx)
inside = bool(jnp.all((z_tilde[..., sel] >= lo - 1e-4)
                      & (z_tilde[..., sel] <= hi + 1e-4)))
print(f"eq. (6) consolidation holds on transmitted channels: {inside}")
