"""Attention backend equivalence: the Pallas flash kernel behind
models.attention.attention_apply must match the jnp path through the full
layer (projections + RoPE + GQA + output proj), at train and windowed modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


@pytest.fixture(autouse=True)
def _restore_backend():
    yield
    A.set_backend(None)


def _layer(seed=0, d=64, h=4, kh=2, hd=16):
    p = A.init_attention(jax.random.PRNGKey(seed), d, h, kh, hd, qkv_bias=True)
    return p, dict(n_heads=h, n_kv_heads=kh, head_dim=hd, rope_theta=1e4)


@pytest.mark.parametrize("s", [128, 256])
@pytest.mark.parametrize("window", [None, 128])
def test_backends_agree(s, window):
    p, kw = _layer()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s, 64))
    A.set_backend("jnp")
    y1 = A.attention_apply(p, x, causal=True, window=window, **kw)
    A.set_backend("pallas")
    y2 = A.attention_apply(p, x, causal=True, window=window, **kw)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


def test_pallas_backend_falls_back_on_unaligned_seq():
    """Non-128-multiple sequences route to the jnp path (no crash)."""
    p, kw = _layer()
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 96, 64))
    A.set_backend("pallas")
    y = A.attention_apply(p, x, causal=True, **kw)
    assert y.shape == (1, 96, 64)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_lm_forward_under_pallas_backend():
    """A whole smoke model forwards identically under both backends."""
    from repro.configs import get_smoke_config
    from repro.models.lm import init_lm, lm_forward
    cfg = get_smoke_config("qwen2_7b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0, cfg.vocab)
    A.set_backend("jnp")
    l1, _ = lm_forward(params, cfg, tokens=tokens, remat=False)
    A.set_backend("pallas")
    l2, _ = lm_forward(params, cfg, tokens=tokens, remat=False)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_linear_scan_backend_equivalence():
    """RWKV-6 block through jnp vs pallas scan backends."""
    from repro.models import linear_attention as L
    from repro.models.rwkv6 import init_rwkv6_block, rwkv6_block
    p = init_rwkv6_block(jax.random.PRNGKey(0), 32, 8, lora_rank=8, d_ff=64)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    L.set_backend("jnp")
    y1 = rwkv6_block(p, x, head_dim=8, chunk=16)
    L.set_backend("pallas")
    y2 = rwkv6_block(p, x, head_dim=8, chunk=16)
    L.set_backend(None)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
