"""Streaming session subsystem: temporal delta codec, wire-format hardening,
desync/NACK recovery, and the QoS'd session manager on the virtual clock.
"""
import time

import jax
import numpy as np
import pytest

from repro.analysis import ReplaySanitizerError, replay_sanitizer

from repro.codec.rans import CorruptStream
from repro.configs.yolo_baf import smoke_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import correlated_frames
from repro.models.cnn import init_cnn
from repro.pipeline import (Capabilities, ModelSpec, NegotiationError,
                            OperatingPoint)
from repro.pipeline import compile as pcompile
from repro.serve import (AdmissionDecision, AdmissionPolicy, ChannelConfig,
                         LinearCostModel, MultiQueueExecutor,
                         MultiTenantGateway, TenantSpec)
from repro.session import (QosLevel, SessionConfig, SessionDecoder,
                           SessionDesync, SessionEncoder, SessionFrame,
                           SessionManager, SessionSpec)
from repro.session.recovery import (RecoveryConfig, RecoveryTracker,
                                    recovery_bound_s)

OP = OperatingPoint(c=8, bits=6, backend="rans")


@pytest.fixture(scope="module")
def plan_for():
    spec = ModelSpec(sel_idx=np.arange(8))
    cache = {}

    def get(op):
        op = op.resolve()
        if op not in cache:
            cache[op] = pcompile(op, spec)
        return cache[op]
    return get


def _z_stream(n, *, shape=(1, 8, 8, 8), drift=0.01, seed=0):
    """Temporally correlated split activations (frame t ~ frame t-1)."""
    rng = np.random.default_rng(seed)
    z = rng.normal(size=shape).astype(np.float32)
    out = [z]
    for _ in range(n - 1):
        z = z + drift * rng.normal(size=shape).astype(np.float32)
        out.append(z)
    return out


def _pair(plan_for, **cfg_kw):
    cfg = SessionConfig(session_id=1, levels=(OP,), **cfg_kw)
    return (SessionEncoder(cfg, plan_for), SessionDecoder(cfg, plan_for))


# ---------------------------------------------------------------------------
# Codec: I/P round trips
# ---------------------------------------------------------------------------

def test_i_frame_payload_is_the_stateless_container(plan_for):
    """An I-frame's payload is byte-identical to plan.encode — a keyframe
    stream is the stateless wire format, just framed."""
    enc, _ = _pair(plan_for)
    z = _z_stream(1)[0]
    blob, meta = enc.encode(z)
    assert meta.intra
    frame = SessionFrame.parse(blob)
    assert frame.payload == plan_for(OP).encode(z).data


def test_p_chain_reconstructs_codes_exactly(plan_for):
    """Temporal prediction is lossless on top of quantization: every frame
    of a long P-chain decodes to the exact codes the encoder quantized —
    zero drift at any chain length."""
    enc, dec = _pair(plan_for)
    plan = plan_for(OP)
    for i, z in enumerate(_z_stream(12)):
        blob, meta = enc.encode(z)
        assert meta.intra == (i == 0)
        decoded, frame = dec.decode(blob)
        want, _ = plan._quantize(z)
        assert np.array_equal(decoded.codes, np.asarray(want))
        assert frame.seq == i


def test_p_frames_code_far_below_i_frames_on_correlated_stream(plan_for):
    """The wire-bit win the subsystem exists for: on temporally correlated
    activations the P-frame delta entropy-codes well under 0.7x the
    I-frame, and the whole session beats I-only by >= 1.4x."""
    enc, _ = _pair(plan_for)
    i_bits, p_bits = [], []
    for z in _z_stream(16):
        _, meta = enc.encode(z)
        (i_bits if meta.intra else p_bits).append(meta.wire_bits)
    assert len(i_bits) == 1 and len(p_bits) == 15
    assert np.mean(p_bits) <= 0.7 * np.mean(i_bits)
    i_only = len(p_bits + i_bits) * np.mean(i_bits)
    assert i_only / (sum(i_bits) + sum(p_bits)) >= 1.4


def test_keyframe_interval_forces_periodic_i(plan_for):
    enc, _ = _pair(plan_for, keyframe_interval=4)
    intras = [enc.encode(z)[1].intra for z in _z_stream(9)]
    assert intras == [True, False, False, False,
                      True, False, False, False, True]


def test_nack_forces_intra_refresh(plan_for):
    enc, _ = _pair(plan_for)
    zs = _z_stream(3)
    enc.encode(zs[0])
    assert not enc.encode(zs[1])[1].intra
    enc.nack()
    assert enc.force_intra_pending
    assert enc.encode(zs[2])[1].intra
    assert not enc.force_intra_pending


def test_level_change_forces_i_frame(plan_for):
    """A delta across operating points is meaningless — switching QoS rung
    must restart the chain."""
    coarse = OperatingPoint(c=8, bits=4, backend="rans")
    cfg = SessionConfig(session_id=2, levels=(OP, coarse))
    enc = SessionEncoder(cfg, plan_for)
    dec = SessionDecoder(cfg, plan_for)
    zs = _z_stream(4)
    dec.decode(enc.encode(zs[0], level=0)[0])
    dec.decode(enc.encode(zs[1], level=0)[0])
    blob, meta = enc.encode(zs[2], level=1)
    assert meta.intra and meta.level == 1
    decoded, _ = dec.decode(blob)
    want, _ = plan_for(coarse)._quantize(zs[2])
    assert np.array_equal(decoded.codes, np.asarray(want))
    # and back down the ladder: another forced I
    assert enc.encode(zs[3], level=0)[1].intra


def test_session_without_temporal_capability_streams_i_only(plan_for):
    """A decode side that never negotiated the session profile still works —
    every frame is an I-frame (graceful fallback, not an error)."""
    cfg = SessionConfig(session_id=3, levels=(OP,))
    caps = Capabilities(session_profiles=(), downgrade=True)
    enc = SessionEncoder(cfg, plan_for, capabilities=caps)
    assert not enc.temporal
    assert all(enc.encode(z)[1].intra for z in _z_stream(4))
    with pytest.raises(NegotiationError):
        SessionEncoder(cfg, plan_for,
                       capabilities=Capabilities(session_profiles=(),
                                                 downgrade=False))


# ---------------------------------------------------------------------------
# Codec: desync + wire hardening
# ---------------------------------------------------------------------------

def test_p_frame_after_a_lost_frame_desyncs_never_restores(plan_for):
    enc, dec = _pair(plan_for)
    zs = _z_stream(3)
    dec.decode(enc.encode(zs[0])[0])
    enc.encode(zs[1])                      # lost in flight
    blob, _ = enc.encode(zs[2])
    with pytest.raises(SessionDesync):
        dec.decode(blob)
    assert dec.last_decoded_seq == 0       # nothing after frame 0 restored
    # the failed frame must not poison recovery: a fresh I resyncs
    enc.nack()
    decoded, frame = dec.decode(enc.encode(zs[2])[0])
    assert frame.intra and dec.synced
    want, _ = plan_for(OP)._quantize(zs[2])
    assert np.array_equal(decoded.codes, np.asarray(want))


def test_p_frame_into_fresh_decoder_desyncs(plan_for):
    enc, _ = _pair(plan_for)
    dec_late = SessionDecoder(SessionConfig(session_id=1, levels=(OP,)),
                              plan_for)
    zs = _z_stream(2)
    enc.encode(zs[0])
    blob, _ = enc.encode(zs[1])            # P, but dec_late joined late
    with pytest.raises(SessionDesync):
        dec_late.decode(blob)


def test_frame_for_wrong_session_is_rejected(plan_for):
    enc, _ = _pair(plan_for)
    other = SessionDecoder(SessionConfig(session_id=99, levels=(OP,)),
                           plan_for)
    blob, _ = enc.encode(_z_stream(1)[0])
    with pytest.raises(CorruptStream, match="session 1"):
        other.decode(blob)


def test_wire_format_rejects_damage_with_distinct_errors(plan_for):
    enc, _ = _pair(plan_for)
    blob = bytearray(enc.encode(_z_stream(1)[0])[0])

    def expect(msg, mutate):
        bad = bytearray(blob)
        mutate(bad)
        with pytest.raises(CorruptStream, match=msg):
            SessionFrame.parse(bytes(bad))

    expect("truncated session frame header", lambda b: b.__imul__(0))
    expect("bad session frame magic",
           lambda b: b.__setitem__(0, b[0] ^ 0xFF))
    expect("unsupported session wire version",
           lambda b: b.__setitem__(slice(4, 5), b"\x7f"))
    # flips inside the CRC-protected header (past magic/version, which fail
    # their own checks first)
    expect("header CRC mismatch", lambda b: b.__setitem__(9, b[9] ^ 0x01))
    expect("truncated session frame payload",
           lambda b: b.__delitem__(slice(len(b) // 2, len(b))))
    expect("trailing garbage", lambda b: b.extend(b"\x00"))
    expect("payload CRC mismatch",
           lambda b: b.__setitem__(30, b[30] ^ 0x10))


def test_unknown_frame_type_and_ladder_overflow_rejected(plan_for):
    import struct
    import zlib
    enc, dec = _pair(plan_for)
    blob = bytearray(enc.encode(_z_stream(1)[0])[0])

    def rewrite(offset, value):
        bad = bytearray(blob)
        bad[offset] = value
        bad[24:28] = struct.pack("<I", zlib.crc32(bytes(bad[:24])))
        return bytes(bad)

    with pytest.raises(CorruptStream, match="unknown session frame type"):
        SessionFrame.parse(rewrite(5, 7))
    with pytest.raises(CorruptStream, match="outside the agreed ladder"):
        dec.decode(rewrite(6, 200))        # level byte past the rung count


# ---------------------------------------------------------------------------
# Recovery primitives
# ---------------------------------------------------------------------------

def test_recovery_tracker_measures_episodes_not_events():
    tr = RecoveryTracker()
    assert tr.on_desync(1.0)               # opens the episode -> NACK
    assert not tr.on_desync(1.1)           # still down: no second NACK
    tr.on_resync(1.5)
    assert tr.episodes == 1 and tr.desync_events == 2
    assert tr.recovery_times == [pytest.approx(0.5)]
    tr.on_resync(2.0)                      # resync while up: no-op
    assert tr.max_recovery_s == pytest.approx(0.5)


def test_recovery_config_rejects_unrecoverable_sessions():
    with pytest.raises(ValueError, match="unrecoverable"):
        RecoveryConfig(nack=False, keyframe_interval=0)
    RecoveryConfig(nack=False, keyframe_interval=8)     # broadcast mode: ok


def test_recovery_bound_scales_with_frame_interval():
    tight = recovery_bound_s(fps=30, uplink_latency_s=0.01,
                             nack_latency_s=0.02)
    loose = recovery_bound_s(fps=10, uplink_latency_s=0.01,
                             nack_latency_s=0.02)
    assert loose > tight > 0.03


# ---------------------------------------------------------------------------
# Session manager on a real gateway
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_gateway_parts():
    cnn_cfg = smoke_config()._replace(input_size=32)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    bank = {c: (init_baf_conv(jax.random.PRNGKey(c),
                              BaFConvConfig(c=c, q=cnn_cfg.split_q,
                                            hidden=8)),
                np.arange(c)) for c in (4, 8)}
    return params, bank


LADDER = (QosLevel(OperatingPoint(c=8, bits=6, backend="rans")),
          QosLevel(OperatingPoint(c=8, bits=4, backend="rans"),
                   keyframe_interval=8),
          QosLevel(OperatingPoint(c=4, bits=4, backend="rans"),
                   keyframe_interval=8, frame_stride=2))


def _gateway(params, bank, *, admission=None):
    tenants = [TenantSpec(name=f"cam{i}", priority=i % 2) for i in range(3)]
    return MultiTenantGateway(
        params, bank, tenants=tenants,
        executor=MultiQueueExecutor(2, cost=LinearCostModel(0.002, 0.0005)),
        admission=admission, max_batch=4, batch_window_s=0.01)


def _manager(gw, *, loss=0.0, corrupt=0.0, seed=3, fps=20.0):
    sessions = [SessionSpec(name=f"cam{i}", fps=fps, start_s=0.002 * i)
                for i in range(3)]
    cfg = ChannelConfig(bandwidth_bps=20e6, base_latency_s=0.005,
                        loss_p=loss, corrupt_p=corrupt, mtu_bytes=256)
    return SessionManager(gw, sessions, ladder=LADDER, channel_cfg=cfg,
                          recovery=RecoveryConfig(nack_latency_s=0.01),
                          seed=seed)


def _frames(n=24):
    return {f"cam{i}": correlated_frames(n, image_size=32, seed=10 + i)
            for i in range(3)}


def test_clean_channels_stream_every_frame_mostly_p(tiny_gateway_parts):
    params, bank = tiny_gateway_parts
    mgr = _manager(_gateway(params, bank))
    frames = _frames(16)
    responses, report = mgr.run(frames)
    for name in frames:
        assert report.counts(name) == {"served": 16}
        assert report.nacks[name] == 0
        assert report.recovery[name].episodes == 0
        # exactly one keyframe; the rest rode the temporal chain
        assert sum(f.intra for f in report.frames[name]) == 1
        assert set(responses[name]) == set(range(16))
        assert all(np.all(np.isfinite(v)) for v in responses[name].values())
    assert len(report.telemetry) == 48 and not report.telemetry.shed


def test_lossy_run_recovers_bounded_ends_in_sync_and_replays(
        tiny_gateway_parts):
    """The acceptance scenario: 5% loss + corruption; desyncs happen, every
    recovery is bounded, every session ends in sync (run() asserts it), and
    a second run is bit-identical under the deterministic cost model."""
    params, bank = tiny_gateway_parts
    mgr = _manager(_gateway(params, bank), loss=0.05, corrupt=0.02)
    frames = _frames(24)
    _, report = mgr.run(frames)
    impaired = sum(n for name in frames
                   for o, n in report.counts(name).items()
                   if o in ("lost", "corrupt", "desync"))
    assert impaired > 0, "seeded run must actually exercise loss"
    assert sum(report.nacks.values()) > 0
    bound = recovery_bound_s(fps=20.0, uplink_latency_s=0.02,
                             nack_latency_s=0.01, margin_frames=2)
    for name in frames:
        tr = report.recovery[name]
        assert not tr.in_desync
        # repeated loss can chain cycles; 2x single-cycle bound holds at 5%
        assert tr.max_recovery_s <= 2 * bound
    # the replay runs under the sanitizer: any wall-clock / global-RNG read
    # on the replay path would raise instead of silently skewing state
    with replay_sanitizer():
        _, report2 = mgr.run(frames)
    assert report.signature() == report2.signature()


def test_replay_sanitizer_clean_run_and_injected_leak(tiny_gateway_parts):
    """Dynamic coverage behind the static RA01/RA02 rules: a clean
    SessionManager run executes fully sanitized (and stays bit-identical),
    while a wall-clock read smuggled into the serving path raises
    ReplaySanitizerError instead of desynchronizing the replay."""
    params, bank = tiny_gateway_parts
    mgr = _manager(_gateway(params, bank))
    frames = _frames(8)
    _, report = mgr.run(frames)
    with replay_sanitizer():
        _, report2 = mgr.run(frames)
    assert report.signature() == report2.signature()

    gw = _gateway(params, bank)
    leaky_mgr = _manager(gw)
    inner = gw._cloud_fn

    def leaky_cloud_fn(params, z_tilde):
        time.time()                        # the smuggled wall-clock read
        return inner(params, z_tilde)

    gw._cloud_fn = leaky_cloud_fn
    with replay_sanitizer():
        with pytest.raises(ReplaySanitizerError, match="time.time"):
            leaky_mgr.run(frames)


def test_overload_degrades_down_the_ladder_before_shedding(
        tiny_gateway_parts):
    """Degrade-before-shed: with admission refusing everything, each session
    walks rung 0 -> 1 -> 2 (two DegradeRecords), and only frames already at
    the floor are shed. The floor rung's stride also thins offered load."""
    params, bank = tiny_gateway_parts

    class RefuseAll(AdmissionPolicy):
        def admit(self, *, tenant, priority, t, executor):
            return AdmissionDecision(False, reason="saturated")

    gw = _gateway(params, bank, admission=RefuseAll())
    mgr = _manager(gw)
    frames = _frames(12)
    _, report = mgr.run(frames)
    degrades = report.telemetry.degrade_by_tenant()
    for name in frames:
        assert degrades[name] == 2          # one step per rung below 0
        assert report.final_levels[name] == 2
        steps = [(d.from_level, d.to_level)
                 for d in report.telemetry.degraded if d.tenant == name]
        assert steps == [(0, 1), (1, 2)]
        counts = report.counts(name)
        assert counts.get("shed", 0) > 0
        assert counts.get("skipped", 0) > 0      # floor stride at work
        # shed only ever happens at the floor
        assert all(f.level == len(LADDER) - 1
                   for f in report.frames[name] if f.outcome == "shed")
    assert len(report.telemetry.degraded) == 6


def test_pressure_release_steps_back_up(tiny_gateway_parts):
    """Quality recovers: once admission stops refusing, a session climbs
    back toward rung 0 after upgrade_hold clean admissions."""
    params, bank = tiny_gateway_parts

    class PulsedAdmission:
        """Refuse the first two asks per tenant, admit everything after."""

        def __init__(self):
            self.asked = {}

        def reset(self):
            self.asked = {}

        def admit(self, *, tenant, priority, t, executor):
            n = self.asked.get(tenant, 0)
            self.asked[tenant] = n + 1
            if n < 2:
                return AdmissionDecision(False, reason="pulse")
            return AdmissionDecision(True)

    gw = _gateway(params, bank, admission=PulsedAdmission())
    sessions = [SessionSpec(name="cam0", fps=20.0)]
    mgr = SessionManager(
        gw, sessions, ladder=LADDER,
        channel_cfg=ChannelConfig(bandwidth_bps=20e6, base_latency_s=0.005),
        recovery=RecoveryConfig(nack_latency_s=0.01), upgrade_hold=4)
    _, report = mgr.run({"cam0": correlated_frames(20, image_size=32,
                                                   seed=11)})
    assert report.telemetry.degrade_by_tenant() == {"cam0": 2}
    assert report.final_levels["cam0"] < 2   # climbed back off the floor


# ---------------------------------------------------------------------------
# P-frame-aware initial level selection (rd_table + frame_budget_bits)
# ---------------------------------------------------------------------------

def _priced_table():
    """The test ladder's ops priced with measured P/I ratios.

    Per-frame session price (serve.session_bits_per_frame):
      level 0: k=0  all-P      -> 10_000 * 0.5          = 5_000
      level 1: k=8             ->  8_000 * (1+7/4)/8    = 2_750
      level 2: k=8, stride=2   ->  6_000 * (1+7/4)/8/2  ~= 1_031
    """
    from repro.serve import RDPoint
    return [RDPoint(LADDER[0].op, 10_000.0, 30.0, p_over_i=0.5),
            RDPoint(LADDER[1].op, 8_000.0, 26.0, p_over_i=0.25),
            RDPoint(LADDER[2].op, 6_000.0, 22.0, p_over_i=0.25)]


def test_manager_prices_initial_level_with_p_frame_savings(
        tiny_gateway_parts):
    params, bank = tiny_gateway_parts
    mgr = _manager(_gateway(params, bank))
    mgr_priced = SessionManager(
        _gateway(params, bank),
        [SessionSpec(name=f"cam{i}", fps=20.0, start_s=0.002 * i)
         for i in range(3)],
        ladder=LADDER,
        channel_cfg=ChannelConfig(bandwidth_bps=20e6, base_latency_s=0.005,
                                  mtu_bytes=256),
        recovery=RecoveryConfig(nack_latency_s=0.01), seed=3,
        rd_table=_priced_table(), frame_budget_bits=3_000.0)
    assert mgr._initial_level == 0          # default: best rung
    assert mgr_priced._initial_level == 1   # 5_000 > budget, 2_750 fits
    _, report = mgr_priced.run(_frames(8))
    for name in report.frames:
        assert report.frames[name][0].level == 1
    # I-only pricing would have sent sessions to the floor: rung 1's
    # I-frame price (8_000) busts the budget, its session price does not
    assert _priced_table()[1].bits_per_example > 3_000.0


def test_manager_priced_level_skips_unpriced_rungs_and_floors_out(
        tiny_gateway_parts):
    params, bank = tiny_gateway_parts

    def priced(table, budget):
        return SessionManager(
            _gateway(params, bank), [SessionSpec(name="cam0", fps=20.0)],
            ladder=LADDER,
            channel_cfg=ChannelConfig(bandwidth_bps=20e6,
                                      base_latency_s=0.005, mtu_bytes=256),
            recovery=RecoveryConfig(nack_latency_s=0.01),
            rd_table=table, frame_budget_bits=budget)

    # only the floor rung is priced; rungs without an entry are skipped
    assert priced(_priced_table()[2:], 2_000.0)._initial_level == 2
    # nothing fits the budget -> the floor rung, never an error
    assert priced(_priced_table(), 10.0)._initial_level == 2


def test_manager_pricing_with_ample_budget_replays_default_exactly(
        tiny_gateway_parts):
    """The satellite's regression gate: the priced path with a budget no
    rung busts starts at rung 0 and reproduces the committed default-path
    behaviour bit for bit."""
    params, bank = tiny_gateway_parts
    frames = _frames(12)
    _, base = _manager(_gateway(params, bank)).run(frames)
    priced = SessionManager(
        _gateway(params, bank),
        [SessionSpec(name=f"cam{i}", fps=20.0, start_s=0.002 * i)
         for i in range(3)],
        ladder=LADDER,
        channel_cfg=ChannelConfig(bandwidth_bps=20e6, base_latency_s=0.005,
                                  mtu_bytes=256),
        recovery=RecoveryConfig(nack_latency_s=0.01), seed=3,
        rd_table=_priced_table(), frame_budget_bits=1e9)
    assert priced._initial_level == 0
    _, rep = priced.run(frames)
    assert rep.signature() == base.signature()
