"""Trip-count-aware HLO cost model vs hand counts (DESIGN.md §4.1)."""
import jax
import jax.numpy as jnp

from repro.compat import cost_analysis_dict
from repro.launch.hlo_cost import analyze_compiled, analyze_hlo_text


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_scaled_by_trip_count():
    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=12)
        return y
    c = _compile(f, jnp.zeros((128, 128)))
    r = analyze_compiled(c)
    assert r["flops"] == 12 * 2 * 128 ** 3
    # XLA's own analysis counts the body once — ours must exceed it
    assert r["flops"] > (cost_analysis_dict(c).get("flops") or 0)


def test_nested_scan():
    def g(x):
        def outer(c, _):
            c2, _ = jax.lax.scan(lambda a, _: (a @ a, None), c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y
    r = analyze_compiled(_compile(g, jnp.zeros((64, 64))))
    assert r["flops"] == 4 * 3 * 2 * 64 ** 3


def test_dot_general_batched_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    a = jnp.zeros((4, 32, 16))
    b = jnp.zeros((4, 16, 8))
    r = analyze_compiled(_compile(f, a, b))
    assert r["flops"] == 2 * 4 * 32 * 8 * 16


def test_collective_bytes_trip_scaled():
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    if len(jax.devices()) < 2:        # single real device: parse a synthetic HLO
        txt = """
%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %gte = f32[64]{0} get-tuple-element(%p), index=1
  %ar = f32[64]{0} all-reduce(%gte), replica_groups={}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[64]{0}) tuple(%i, %ar)
}
%cond (p.1: (s32[], f32[64])) -> pred[] {
  ROOT %lt = pred[] compare(%x, %y), direction=LT
}
ENTRY %main (a: f32[64]) -> f32[64] {
  %w = (s32[], f32[64]{0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[64]{0} get-tuple-element(%w), index=1
}
"""
        r = analyze_hlo_text(txt)
        assert r["collective_bytes"].get("all-reduce") == 7 * 64 * 4
        return


def test_bytes_written_buffer_model():
    def f(a, b):
        return a @ b
    a = jnp.zeros((128, 64))
    b = jnp.zeros((64, 32))
    r = analyze_compiled(_compile(f, a, b))
    # at least write+read of the (128, 32) result through the dot
    assert r["bytes"] >= 2 * 128 * 32 * 4
    assert "dot" in r["bytes_by_op"]
