"""Serving gateway: channel model, rate control, micro-batcher, end to end."""
import jax
import numpy as np
import pytest

from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import shapes_batch_iterator
from repro.models.cnn import init_cnn
from repro.serve import (Capabilities, ChannelConfig, DecodedRequest,
                         MicroBatcher, MultiTenantGateway, NegotiationError,
                         OperatingPoint, RateController, RDPoint,
                         ServingGateway, SimulatedChannel, TenantRequest,
                         TenantSpec, bucket_sizes)


# ---------------------------------------------------------------------------
# Channel model
# ---------------------------------------------------------------------------

def test_channel_latency_is_serialization_plus_propagation():
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=1000, base_latency_s=0.5))
    tx = ch.transmit(1000, t_submit=0.0)
    assert tx.t_start == 0.0
    assert tx.t_arrive == pytest.approx(1.0 + 0.5)


def test_channel_serializes_back_to_back_transmissions():
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=1000, base_latency_s=0.0))
    a = ch.transmit(1000, t_submit=0.0)     # occupies the wire until t=1
    b = ch.transmit(1000, t_submit=0.0)     # must wait for a
    assert b.t_start == pytest.approx(a.t_submit + 1.0)
    assert b.queue_wait_s == pytest.approx(1.0)


def test_channel_is_deterministic_under_seed():
    cfg = ChannelConfig(bandwidth_bps=5000, base_latency_s=0.01, jitter_s=0.02)
    runs = []
    for _ in range(2):
        ch = SimulatedChannel(cfg, seed=42)
        runs.append([ch.transmit(512).t_arrive for _ in range(5)])
    assert runs[0] == runs[1]
    ch = SimulatedChannel(cfg, seed=7)
    assert [ch.transmit(512).t_arrive for _ in range(5)] != runs[0]


def test_channel_tick_budget_defers_transmission():
    cfg = ChannelConfig(bandwidth_bps=1e9, base_latency_s=0.0, tick_s=1.0,
                        budget_bits_per_tick=1000)
    ch = SimulatedChannel(cfg)
    assert ch.budget_remaining() == 1000
    ch.transmit(900, t_submit=0.0)
    assert ch.budget_remaining(at=0.0) == 100
    late = ch.transmit(500, t_submit=0.0)   # does not fit tick 0's remainder
    assert late.t_start >= 1.0              # deferred to the next tick


def test_channel_spanning_packet_waits_for_budget_grants():
    """A packet bigger than a whole tick budget drains several ticks and can
    only finish once the tick granting its last bits opens — fast wires do
    not let it tunnel through the cap."""
    cfg = ChannelConfig(bandwidth_bps=1e9, base_latency_s=0.0, tick_s=1.0,
                        budget_bits_per_tick=1000)
    ch = SimulatedChannel(cfg)
    big = ch.transmit(2500, t_submit=0.0)   # spans ticks 0, 1, 2
    assert big.t_arrive >= 2.0
    # ticks 0-2 are spent: the next packet waits for tick 3
    nxt = ch.transmit(1000, t_submit=0.0)
    assert nxt.t_start >= 3.0


# ---------------------------------------------------------------------------
# Rate controller on a fixed, documented RD table
# ---------------------------------------------------------------------------

FIXED_TABLE = [
    RDPoint(OperatingPoint(c=4, bits=2), bits_per_example=1_000, psnr_db=12.0),
    RDPoint(OperatingPoint(c=8, bits=4), bits_per_example=4_000, psnr_db=20.0),
    RDPoint(OperatingPoint(c=8, bits=8), bits_per_example=8_000, psnr_db=26.0),
    RDPoint(OperatingPoint(c=16, bits=8), bits_per_example=16_000, psnr_db=30.0),
]


def test_controller_cheapest_meeting_floor():
    rc = RateController(FIXED_TABLE, quality_floor_db=19.0)
    assert rc.cheapest_meeting_floor().op == OperatingPoint(c=8, bits=4)
    # floor above every point -> best available quality
    rc = RateController(FIXED_TABLE, quality_floor_db=99.0)
    assert rc.cheapest_meeting_floor().op == OperatingPoint(c=16, bits=8)


def test_controller_spends_the_budget_for_quality():
    rc = RateController(FIXED_TABLE, quality_floor_db=19.0)
    # unmetered: best quality point overall
    assert rc.select(None).op == OperatingPoint(c=16, bits=8)
    # generous budget: same
    assert rc.select(20_000).op == OperatingPoint(c=16, bits=8)
    # halved budget: best floor-meeting point that still fits
    assert rc.select(10_000).op == OperatingPoint(c=8, bits=8)
    assert rc.select(5_000).op == OperatingPoint(c=8, bits=4)


def test_controller_degrades_below_floor_rather_than_dropping():
    rc = RateController(FIXED_TABLE, quality_floor_db=19.0)
    # only the sub-floor point fits -> serve it (flagged by its psnr)
    pick = rc.select(2_000)
    assert pick.op == OperatingPoint(c=4, bits=2)
    assert pick.psnr_db < rc.quality_floor_db
    # nothing fits at all -> cheapest overall, never a drop
    assert rc.select(10).op == OperatingPoint(c=4, bits=2)


# ---------------------------------------------------------------------------
# Micro-batcher
# ---------------------------------------------------------------------------

def _req(req_id, c=8, bits=8, h=4, w=4, fill=None):
    fill = req_id if fill is None else fill
    return DecodedRequest(
        req_id=req_id,
        codes=np.full((1, h, w, c), fill % 251, np.uint8),
        mins=np.zeros((1, 1, 1, c), np.float16),
        maxs=np.ones((1, 1, 1, c), np.float16),
        c=c, bits=bits)


def test_bucket_sizes_are_powers_of_two_up_to_cap():
    assert bucket_sizes(8) == (1, 2, 4, 8)
    assert bucket_sizes(6) == (1, 2, 4, 6)
    assert bucket_sizes(1) == (1,)


def test_batcher_flushes_full_groups_and_pads_remainders():
    mb = MicroBatcher(max_batch=4)
    flushed = []
    for i in range(6):
        flushed += mb.add(_req(i))
    assert len(flushed) == 1 and flushed[0].padded_size == 4
    assert flushed[0].pad == 0
    rest = mb.flush()
    assert len(rest) == 1
    assert [r.req_id for r in rest[0].requests] == [4, 5]
    assert rest[0].padded_size == 2 and rest[0].pad == 0
    assert len(mb) == 0


def test_batcher_pads_to_next_bucket():
    mb = MicroBatcher(max_batch=8)
    for i in range(3):
        mb.add(_req(i))
    (b,) = mb.flush()
    assert b.padded_size == 4 and b.pad == 1
    # padding repeats the last row, so restore shapes stay bucketed
    assert np.array_equal(b.codes[3], b.codes[2])


def test_batcher_groups_by_operating_point():
    mb = MicroBatcher(max_batch=8)
    mb.add(_req(0, c=8, bits=8))
    mb.add(_req(1, c=8, bits=4))
    mb.add(_req(2, c=4, bits=8))
    batches = mb.flush()
    assert len(batches) == 3
    assert {b.key.c for b in batches} == {4, 8}


def test_batcher_preserves_request_identity_under_shuffled_arrival(rng):
    mb = MicroBatcher(max_batch=4)
    order = rng.permutation(12)
    batches = []
    for i in order:
        batches += mb.add(_req(int(i)))
    batches += mb.flush()
    seen = {}
    for b in batches:
        for row, req in enumerate(b.requests):
            # each row of the batch is that request's own payload
            assert int(b.codes[row, 0, 0, 0]) == req.req_id % 251
            seen[req.req_id] = True
    assert sorted(seen) == list(range(12))


# ---------------------------------------------------------------------------
# Burst-aware batch windows (EWMA of per-bucket arrival rate)
# ---------------------------------------------------------------------------

def _feed(mb, n, gap, start=0.0):
    """Feed n same-bucket requests spaced ``gap`` apart; returns the open
    group's effective window (deadline - first arrival)."""
    t = start
    for i in range(n):
        mb.add(_req(i), now=t)
        t += gap
    key = _req(0).key
    due, _gen = mb.deadline(key)
    t_first, _ = mb._opened[key]
    return due - t_first


def test_adaptive_window_shrinks_for_bursty_traffic():
    fixed = 0.1
    bursty = MicroBatcher(max_batch=8, window_s=fixed, adaptive=True,
                          min_window_s=0.002)
    steady = MicroBatcher(max_batch=8, window_s=fixed, adaptive=True,
                          min_window_s=0.002)
    w_bursty = _feed(bursty, 3, gap=0.001)
    w_steady = _feed(steady, 3, gap=0.05)
    # burst: the remaining 5 slots are expected within ~5 ms, so the group
    # does not camp on the full 100 ms window
    assert w_bursty < w_steady
    assert w_bursty < fixed / 2
    # sparse-but-steady traffic can never exceed the configured cap
    assert w_steady <= fixed
    assert w_bursty >= 0.002


def test_adaptive_window_tracks_rate_changes_across_groups():
    mb = MicroBatcher(max_batch=8, window_s=1.0, adaptive=True)
    # slow phase: EWMA learns a 0.2 s gap
    w_slow = _feed(mb, 5, gap=0.2)
    mb.flush()
    # fast phase reuses the key's EWMA state and sharpens it downward; the
    # long idle stretch in between is clamped to the window cap, so it
    # cannot swamp the estimate
    w_fast = _feed(mb, 5, gap=0.001, start=10.0)
    assert w_fast < w_slow


def test_adaptive_window_deadline_can_drift_later_within_cap():
    """When traffic decelerates mid-group the deadline moves later (same
    generation) up to the window cap — the gateway re-pushes its flush event
    rather than flushing undersized."""
    mb = MicroBatcher(max_batch=8, window_s=1.0, adaptive=True)
    key = _req(0).key
    # fast opener: two arrivals 1 ms apart -> short expected fill time
    mb.add(_req(0), now=0.0)
    mb.add(_req(1), now=0.001)
    due_fast, gen = mb.deadline(key)
    # then the stream decelerates: 0.1 s gaps dominate the EWMA
    mb.add(_req(2), now=0.101)
    mb.add(_req(3), now=0.201)
    due_slow, gen2 = mb.deadline(key)
    assert gen2 == gen                     # same group, same generation
    assert due_slow > due_fast             # deadline drifted later
    t_first, _ = mb._opened[key]
    assert due_slow <= t_first + 1.0       # never past the hard cap


def test_adaptive_window_needs_cap_and_first_group_uses_it():
    with pytest.raises(ValueError, match="window_s"):
        MicroBatcher(max_batch=4, adaptive=True)
    mb = MicroBatcher(max_batch=4, window_s=0.05, adaptive=True)
    mb.add(_req(0), now=0.0)                   # no gap observed yet
    due, _ = mb.deadline(_req(0).key)
    assert due == pytest.approx(0.05)          # falls back to the fixed cap


def test_fixed_window_behaviour_unchanged():
    mb = MicroBatcher(max_batch=8, window_s=0.1)
    assert _feed(mb, 3, gap=0.001) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# Gateway end to end (tiny system)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_bank():
    cnn_cfg = smoke_config()._replace(input_size=32)
    data_cfg = smoke_data_config()._replace(image_size=32, batch_size=8)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    bank = {}
    for c in (4, 8):
        baf = init_baf_conv(jax.random.PRNGKey(c),
                            BaFConvConfig(c=c, q=cnn_cfg.split_q, hidden=8))
        bank[c] = (baf, np.arange(c))
    imgs, _ = next(shapes_batch_iterator(data_cfg, seed=5))
    return params, bank, np.asarray(imgs)


def test_gateway_round_trips_and_orders_responses(tiny_bank):
    params, bank, imgs = tiny_bank
    gw = ServingGateway(params, bank, default_op=OperatingPoint(c=8, bits=8),
                        max_batch=4)
    responses, tel = gw.serve(imgs)
    assert [r.req_id for r in responses] == list(range(len(imgs)))
    assert all(np.isfinite(r.logits).all() for r in responses)
    assert len(tel) == len(imgs)
    assert tel.summary()["mean_batch_size"] == 4.0


def test_gateway_batched_matches_one_at_a_time(tiny_bank):
    """Micro-batching is an execution detail: logits must match naive serving."""
    params, bank, imgs = tiny_bank
    op = OperatingPoint(c=8, bits=8)
    batched = ServingGateway(params, bank, default_op=op, max_batch=4)
    naive = ServingGateway(params, bank, default_op=op, max_batch=1)
    r_b, _ = batched.serve(imgs)
    r_n, _ = naive.serve(imgs)
    for a, b in zip(r_b, r_n):
        np.testing.assert_allclose(a.logits, b.logits, atol=1e-5, rtol=1e-5)


def test_gateway_fused_restore_matches_reference(tiny_bank):
    params, bank, imgs = tiny_bank
    op = OperatingPoint(c=8, bits=4)
    fused = ServingGateway(params, bank, default_op=op, max_batch=4, fused=True)
    ref = ServingGateway(params, bank, default_op=op, max_batch=4, fused=False)
    r_f, _ = fused.serve(imgs)
    r_r, _ = ref.serve(imgs)
    for a, b in zip(r_f, r_r):
        np.testing.assert_allclose(a.logits, b.logits, atol=1e-5, rtol=1e-5)


def test_gateway_adapts_operating_point_to_channel_budget(tiny_bank):
    """Tight per-tick budget must push the controller to a cheaper (C, bits)."""
    params, bank, imgs = tiny_bank
    table = [
        RDPoint(OperatingPoint(c=4, bits=2), bits_per_example=600, psnr_db=12.0),
        RDPoint(OperatingPoint(c=8, bits=8), bits_per_example=3_000, psnr_db=25.0),
    ]
    rc = RateController(table, quality_floor_db=10.0)
    wide = ServingGateway(
        params, bank, controller=rc,
        channel=SimulatedChannel(ChannelConfig(budget_bits_per_tick=100_000)))
    tight = ServingGateway(
        params, bank, controller=rc,
        channel=SimulatedChannel(ChannelConfig(budget_bits_per_tick=2_000)))
    r_wide, _ = wide.serve(imgs[:2])
    r_tight, _ = tight.serve(imgs[:2])
    assert r_wide[0].op == OperatingPoint(c=8, bits=8)
    assert r_tight[0].op == OperatingPoint(c=4, bits=2)


def test_gateway_telemetry_accounts_wire_and_queue(tiny_bank):
    params, bank, imgs = tiny_bank
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=1e5, base_latency_s=0.01))
    gw = ServingGateway(params, bank, default_op=OperatingPoint(c=8, bits=8),
                        channel=ch, max_batch=4)
    _, tel = gw.serve(imgs[:4])
    for rec in tel.records:
        assert rec.wire_latency_s > 0.01          # serialization happened
        assert rec.queue_wait_s >= 0.0
        assert rec.total_latency_s >= rec.wire_latency_s + rec.compute_s
    # the shared uplink serializes: later requests waited longer on the wire
    lat = [r.wire_latency_s for r in sorted(tel.records, key=lambda r: r.req_id)]
    assert lat[-1] > lat[0]


# ---------------------------------------------------------------------------
# Entropy-coded serving (rANS backends) + true-byte accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["rans", "rans-ctx"])
def test_gateway_rans_backend_matches_zlib_logits(tiny_bank, backend):
    """The entropy coder is lossless: logits must be identical across
    backends at the same operating point."""
    params, bank, imgs = tiny_bank
    op = OperatingPoint(c=8, bits=8)
    ref = ServingGateway(params, bank, default_op=op, max_batch=4,
                         backend="zlib")
    gw = ServingGateway(params, bank, default_op=op, max_batch=4,
                        backend=backend)
    r_ref, _ = ref.serve(imgs[:4])
    r_gw, _ = gw.serve(imgs[:4])
    for a, b in zip(r_gw, r_ref):
        np.testing.assert_allclose(a.logits, b.logits, atol=1e-5, rtol=1e-5)


def test_gateway_downgrades_unsupported_backend(tiny_bank):
    """A gateway that only speaks zlib re-bases a rans operating point onto
    zlib at negotiation time — before any bytes are encoded."""
    params, bank, imgs = tiny_bank
    gw = ServingGateway(
        params, bank,
        default_op=OperatingPoint(c=8, bits=8, backend="rans"),
        capabilities=Capabilities(backends=("zlib",)), max_batch=2)
    responses, _ = gw.serve(imgs[:2])
    assert responses[0].op.wire_backend == "zlib"
    # and the served logits still match an all-zlib gateway bit-for-bit
    ref = ServingGateway(params, bank,
                         default_op=OperatingPoint(c=8, bits=8), max_batch=2)
    r_ref, _ = ref.serve(imgs[:2])
    for a, b in zip(responses, r_ref):
        np.testing.assert_allclose(a.logits, b.logits, atol=1e-5, rtol=1e-5)


def test_gateway_refuses_without_downgrade(tiny_bank):
    params, bank, imgs = tiny_bank
    with pytest.raises(NegotiationError):
        ServingGateway(
            params, bank,
            default_op=OperatingPoint(c=8, bits=8, backend="rans"),
            capabilities=Capabilities(backends=("zlib",), downgrade=False))
    with pytest.raises(NegotiationError, match="profile"):
        ServingGateway(params, bank,
                       default_op=OperatingPoint(c=8, bits=8, profile=1),
                       capabilities=Capabilities())


def test_multi_tenant_adaptive_window_serves_bursts(tiny_bank):
    """Burst-aware windows must not drop or reorder anything; a bursty
    workload under adaptive windows serves bit-identically to fixed."""
    params, bank, imgs = tiny_bank

    def make(adaptive):
        return MultiTenantGateway(
            params, bank, tenants=[TenantSpec("a"), TenantSpec("b")],
            channel_cfg=ChannelConfig(bandwidth_bps=20e6,
                                      base_latency_s=0.002),
            default_op=OperatingPoint(c=8, bits=8), max_batch=4,
            tick_s=0.01, batch_window_s=0.05, adaptive_window=adaptive)

    # two bursts then a straggler
    work = [TenantRequest("ab"[i % 2], imgs[i % len(imgs)],
                          t_submit=0.0005 * i) for i in range(6)]
    work += [TenantRequest("a", imgs[0], t_submit=2.0)]
    r_ad, tel_ad = make(True).serve_tenants(work)
    r_fx, _ = make(False).serve_tenants(work)
    assert len(r_ad["a"]) == 4 and len(r_ad["b"]) == 3
    for t in ("a", "b"):
        for x, y in zip(r_ad[t], r_fx[t]):
            np.testing.assert_allclose(x.logits, y.logits,
                                       atol=1e-5, rtol=1e-5)


def test_gateway_meters_actual_container_bytes(tiny_bank):
    """Channel occupancy and telemetry must reflect the serialized container
    length exactly — not the payload+side-info estimate."""
    params, bank, imgs = tiny_bank
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=1e6))
    gw = ServingGateway(params, bank, default_op=OperatingPoint(c=8, bits=8),
                        channel=ch, max_batch=4, backend="rans")
    op, blob, stats, tx = gw.encode_request(imgs[:1], 0.0)
    assert tx.bits == 8 * blob.nbytes == stats.wire_bits
    assert stats.wire_bits > stats.total_bits      # header is on the wire too
    _, tel = gw.serve(imgs[:4])
    for rec in tel.records:
        assert rec.bits_on_wire > 0
        assert rec.bits_on_wire % 8 == 0
