"""Serving gateway: channel model, rate control, micro-batcher, end to end."""
import jax
import numpy as np
import pytest

from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import shapes_batch_iterator
from repro.models.cnn import init_cnn
from repro.serve import (ChannelConfig, DecodedRequest, MicroBatcher,
                         OperatingPoint, RateController, RDPoint,
                         ServingGateway, SimulatedChannel, bucket_sizes)


# ---------------------------------------------------------------------------
# Channel model
# ---------------------------------------------------------------------------

def test_channel_latency_is_serialization_plus_propagation():
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=1000, base_latency_s=0.5))
    tx = ch.transmit(1000, t_submit=0.0)
    assert tx.t_start == 0.0
    assert tx.t_arrive == pytest.approx(1.0 + 0.5)


def test_channel_serializes_back_to_back_transmissions():
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=1000, base_latency_s=0.0))
    a = ch.transmit(1000, t_submit=0.0)     # occupies the wire until t=1
    b = ch.transmit(1000, t_submit=0.0)     # must wait for a
    assert b.t_start == pytest.approx(a.t_submit + 1.0)
    assert b.queue_wait_s == pytest.approx(1.0)


def test_channel_is_deterministic_under_seed():
    cfg = ChannelConfig(bandwidth_bps=5000, base_latency_s=0.01, jitter_s=0.02)
    runs = []
    for _ in range(2):
        ch = SimulatedChannel(cfg, seed=42)
        runs.append([ch.transmit(512).t_arrive for _ in range(5)])
    assert runs[0] == runs[1]
    ch = SimulatedChannel(cfg, seed=7)
    assert [ch.transmit(512).t_arrive for _ in range(5)] != runs[0]


def test_channel_tick_budget_defers_transmission():
    cfg = ChannelConfig(bandwidth_bps=1e9, base_latency_s=0.0, tick_s=1.0,
                        budget_bits_per_tick=1000)
    ch = SimulatedChannel(cfg)
    assert ch.budget_remaining() == 1000
    ch.transmit(900, t_submit=0.0)
    assert ch.budget_remaining(at=0.0) == 100
    late = ch.transmit(500, t_submit=0.0)   # does not fit tick 0's remainder
    assert late.t_start >= 1.0              # deferred to the next tick


def test_channel_spanning_packet_waits_for_budget_grants():
    """A packet bigger than a whole tick budget drains several ticks and can
    only finish once the tick granting its last bits opens — fast wires do
    not let it tunnel through the cap."""
    cfg = ChannelConfig(bandwidth_bps=1e9, base_latency_s=0.0, tick_s=1.0,
                        budget_bits_per_tick=1000)
    ch = SimulatedChannel(cfg)
    big = ch.transmit(2500, t_submit=0.0)   # spans ticks 0, 1, 2
    assert big.t_arrive >= 2.0
    # ticks 0-2 are spent: the next packet waits for tick 3
    nxt = ch.transmit(1000, t_submit=0.0)
    assert nxt.t_start >= 3.0


# ---------------------------------------------------------------------------
# Rate controller on a fixed, documented RD table
# ---------------------------------------------------------------------------

FIXED_TABLE = [
    RDPoint(OperatingPoint(c=4, bits=2), bits_per_example=1_000, psnr_db=12.0),
    RDPoint(OperatingPoint(c=8, bits=4), bits_per_example=4_000, psnr_db=20.0),
    RDPoint(OperatingPoint(c=8, bits=8), bits_per_example=8_000, psnr_db=26.0),
    RDPoint(OperatingPoint(c=16, bits=8), bits_per_example=16_000, psnr_db=30.0),
]


def test_controller_cheapest_meeting_floor():
    rc = RateController(FIXED_TABLE, quality_floor_db=19.0)
    assert rc.cheapest_meeting_floor().op == OperatingPoint(c=8, bits=4)
    # floor above every point -> best available quality
    rc = RateController(FIXED_TABLE, quality_floor_db=99.0)
    assert rc.cheapest_meeting_floor().op == OperatingPoint(c=16, bits=8)


def test_controller_spends_the_budget_for_quality():
    rc = RateController(FIXED_TABLE, quality_floor_db=19.0)
    # unmetered: best quality point overall
    assert rc.select(None).op == OperatingPoint(c=16, bits=8)
    # generous budget: same
    assert rc.select(20_000).op == OperatingPoint(c=16, bits=8)
    # halved budget: best floor-meeting point that still fits
    assert rc.select(10_000).op == OperatingPoint(c=8, bits=8)
    assert rc.select(5_000).op == OperatingPoint(c=8, bits=4)


def test_controller_degrades_below_floor_rather_than_dropping():
    rc = RateController(FIXED_TABLE, quality_floor_db=19.0)
    # only the sub-floor point fits -> serve it (flagged by its psnr)
    pick = rc.select(2_000)
    assert pick.op == OperatingPoint(c=4, bits=2)
    assert pick.psnr_db < rc.quality_floor_db
    # nothing fits at all -> cheapest overall, never a drop
    assert rc.select(10).op == OperatingPoint(c=4, bits=2)


# ---------------------------------------------------------------------------
# Micro-batcher
# ---------------------------------------------------------------------------

def _req(req_id, c=8, bits=8, h=4, w=4, fill=None):
    fill = req_id if fill is None else fill
    return DecodedRequest(
        req_id=req_id,
        codes=np.full((1, h, w, c), fill % 251, np.uint8),
        mins=np.zeros((1, 1, 1, c), np.float16),
        maxs=np.ones((1, 1, 1, c), np.float16),
        c=c, bits=bits)


def test_bucket_sizes_are_powers_of_two_up_to_cap():
    assert bucket_sizes(8) == (1, 2, 4, 8)
    assert bucket_sizes(6) == (1, 2, 4, 6)
    assert bucket_sizes(1) == (1,)


def test_batcher_flushes_full_groups_and_pads_remainders():
    mb = MicroBatcher(max_batch=4)
    flushed = []
    for i in range(6):
        flushed += mb.add(_req(i))
    assert len(flushed) == 1 and flushed[0].padded_size == 4
    assert flushed[0].pad == 0
    rest = mb.flush()
    assert len(rest) == 1
    assert [r.req_id for r in rest[0].requests] == [4, 5]
    assert rest[0].padded_size == 2 and rest[0].pad == 0
    assert len(mb) == 0


def test_batcher_pads_to_next_bucket():
    mb = MicroBatcher(max_batch=8)
    for i in range(3):
        mb.add(_req(i))
    (b,) = mb.flush()
    assert b.padded_size == 4 and b.pad == 1
    # padding repeats the last row, so restore shapes stay bucketed
    assert np.array_equal(b.codes[3], b.codes[2])


def test_batcher_groups_by_operating_point():
    mb = MicroBatcher(max_batch=8)
    mb.add(_req(0, c=8, bits=8))
    mb.add(_req(1, c=8, bits=4))
    mb.add(_req(2, c=4, bits=8))
    batches = mb.flush()
    assert len(batches) == 3
    assert {b.key.c for b in batches} == {4, 8}


def test_batcher_preserves_request_identity_under_shuffled_arrival(rng):
    mb = MicroBatcher(max_batch=4)
    order = rng.permutation(12)
    batches = []
    for i in order:
        batches += mb.add(_req(int(i)))
    batches += mb.flush()
    seen = {}
    for b in batches:
        for row, req in enumerate(b.requests):
            # each row of the batch is that request's own payload
            assert int(b.codes[row, 0, 0, 0]) == req.req_id % 251
            seen[req.req_id] = True
    assert sorted(seen) == list(range(12))


# ---------------------------------------------------------------------------
# Gateway end to end (tiny system)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_bank():
    cnn_cfg = smoke_config()._replace(input_size=32)
    data_cfg = smoke_data_config()._replace(image_size=32, batch_size=8)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    bank = {}
    for c in (4, 8):
        baf = init_baf_conv(jax.random.PRNGKey(c),
                            BaFConvConfig(c=c, q=cnn_cfg.split_q, hidden=8))
        bank[c] = (baf, np.arange(c))
    imgs, _ = next(shapes_batch_iterator(data_cfg, seed=5))
    return params, bank, np.asarray(imgs)


def test_gateway_round_trips_and_orders_responses(tiny_bank):
    params, bank, imgs = tiny_bank
    gw = ServingGateway(params, bank, default_op=OperatingPoint(c=8, bits=8),
                        max_batch=4)
    responses, tel = gw.serve(imgs)
    assert [r.req_id for r in responses] == list(range(len(imgs)))
    assert all(np.isfinite(r.logits).all() for r in responses)
    assert len(tel) == len(imgs)
    assert tel.summary()["mean_batch_size"] == 4.0


def test_gateway_batched_matches_one_at_a_time(tiny_bank):
    """Micro-batching is an execution detail: logits must match naive serving."""
    params, bank, imgs = tiny_bank
    op = OperatingPoint(c=8, bits=8)
    batched = ServingGateway(params, bank, default_op=op, max_batch=4)
    naive = ServingGateway(params, bank, default_op=op, max_batch=1)
    r_b, _ = batched.serve(imgs)
    r_n, _ = naive.serve(imgs)
    for a, b in zip(r_b, r_n):
        np.testing.assert_allclose(a.logits, b.logits, atol=1e-5, rtol=1e-5)


def test_gateway_fused_restore_matches_reference(tiny_bank):
    params, bank, imgs = tiny_bank
    op = OperatingPoint(c=8, bits=4)
    fused = ServingGateway(params, bank, default_op=op, max_batch=4, fused=True)
    ref = ServingGateway(params, bank, default_op=op, max_batch=4, fused=False)
    r_f, _ = fused.serve(imgs)
    r_r, _ = ref.serve(imgs)
    for a, b in zip(r_f, r_r):
        np.testing.assert_allclose(a.logits, b.logits, atol=1e-5, rtol=1e-5)


def test_gateway_adapts_operating_point_to_channel_budget(tiny_bank):
    """Tight per-tick budget must push the controller to a cheaper (C, bits)."""
    params, bank, imgs = tiny_bank
    table = [
        RDPoint(OperatingPoint(c=4, bits=2), bits_per_example=600, psnr_db=12.0),
        RDPoint(OperatingPoint(c=8, bits=8), bits_per_example=3_000, psnr_db=25.0),
    ]
    rc = RateController(table, quality_floor_db=10.0)
    wide = ServingGateway(
        params, bank, controller=rc,
        channel=SimulatedChannel(ChannelConfig(budget_bits_per_tick=100_000)))
    tight = ServingGateway(
        params, bank, controller=rc,
        channel=SimulatedChannel(ChannelConfig(budget_bits_per_tick=2_000)))
    r_wide, _ = wide.serve(imgs[:2])
    r_tight, _ = tight.serve(imgs[:2])
    assert r_wide[0].op == OperatingPoint(c=8, bits=8)
    assert r_tight[0].op == OperatingPoint(c=4, bits=2)


def test_gateway_telemetry_accounts_wire_and_queue(tiny_bank):
    params, bank, imgs = tiny_bank
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=1e5, base_latency_s=0.01))
    gw = ServingGateway(params, bank, default_op=OperatingPoint(c=8, bits=8),
                        channel=ch, max_batch=4)
    _, tel = gw.serve(imgs[:4])
    for rec in tel.records:
        assert rec.wire_latency_s > 0.01          # serialization happened
        assert rec.queue_wait_s >= 0.0
        assert rec.total_latency_s >= rec.wire_latency_s + rec.compute_s
    # the shared uplink serializes: later requests waited longer on the wire
    lat = [r.wire_latency_s for r in sorted(tel.records, key=lambda r: r.req_id)]
    assert lat[-1] > lat[0]


# ---------------------------------------------------------------------------
# Entropy-coded serving (rANS backends) + true-byte accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["rans", "rans-ctx"])
def test_gateway_rans_backend_matches_zlib_logits(tiny_bank, backend):
    """The entropy coder is lossless: logits must be identical across
    backends at the same operating point."""
    params, bank, imgs = tiny_bank
    op = OperatingPoint(c=8, bits=8)
    ref = ServingGateway(params, bank, default_op=op, max_batch=4,
                         backend="zlib")
    gw = ServingGateway(params, bank, default_op=op, max_batch=4,
                        backend=backend)
    r_ref, _ = ref.serve(imgs[:4])
    r_gw, _ = gw.serve(imgs[:4])
    for a, b in zip(r_gw, r_ref):
        np.testing.assert_allclose(a.logits, b.logits, atol=1e-5, rtol=1e-5)


def test_gateway_meters_actual_container_bytes(tiny_bank):
    """Channel occupancy and telemetry must reflect the serialized container
    length exactly — not the payload+side-info estimate."""
    params, bank, imgs = tiny_bank
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=1e6))
    gw = ServingGateway(params, bank, default_op=OperatingPoint(c=8, bits=8),
                        channel=ch, max_batch=4, backend="rans")
    op, blob, stats, tx = gw.encode_request(imgs[:1], 0.0)
    assert tx.bits == 8 * len(blob) == stats.wire_bits
    assert stats.wire_bits > stats.total_bits      # header is on the wire too
    _, tel = gw.serve(imgs[:4])
    for rec in tel.records:
        assert rec.bits_on_wire > 0
        assert rec.bits_on_wire % 8 == 0
