"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import repeat_kv
from repro.models.linear_attention import LOG_DECAY_MIN


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2, 4, 6, 8])
@pytest.mark.parametrize("shape", [(1, 64, 8), (2, 256, 128), (3, 100, 16)])
def test_quantize_kernel_matches_ref(rng, bits, shape):
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32)) * 7
    bc = min(128, shape[-1])
    codes, qp = ops.quantize_fused(x, bits, block_c=bc)
    rc, rm, rM = ref.quantize_fused_ref(x, bits)
    assert codes.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(qp.mins).reshape(shape[0], -1),
                                  np.asarray(rm))
    np.testing.assert_array_equal(np.asarray(qp.maxs).reshape(shape[0], -1),
                                  np.asarray(rM))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_kernel_dtypes(rng, dtype):
    x = jnp.asarray(rng.normal(size=(2, 32, 16)).astype(np.float32)).astype(dtype)
    codes, qp = ops.quantize_fused(x, 8, block_c=16)
    rc, _, _ = ref.quantize_fused_ref(x.astype(jnp.float32), 8)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(rc))


def test_quantize_kernel_4d_layout(rng):
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 16)).astype(np.float32))
    codes, qp = ops.quantize_fused(x, 8, block_c=16)
    assert codes.shape == x.shape
    assert qp.mins.shape == (2, 1, 1, 16)   # per-example broadcastable


# ---------------------------------------------------------------------------
# consolidate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [3, 6, 8])
@pytest.mark.parametrize("shape", [(1, 64, 8), (2, 512, 32), (2, 100, 64)])
def test_consolidate_kernel_matches_ref(rng, bits, shape):
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    codes, qp = ops.quantize_fused(x, min(bits, 8))
    est = x + jnp.asarray(rng.normal(size=shape).astype(np.float32)) * 0.3
    b, c = shape[0], shape[-1]
    out = ops.consolidate_fused(est, codes, qp.mins, qp.maxs, bits)
    rout = ref.consolidate_ref(est, codes, qp.mins.reshape(b, c),
                               qp.maxs.reshape(b, c), bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rout), atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,h,kh,hd", [(128, 4, 4, 32), (256, 4, 2, 64),
                                       (64, 2, 1, 128)])
def test_flash_attention_matches_ref(rng, causal, s, h, kh, hd):
    q = jnp.asarray(rng.normal(size=(2, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, s, kh, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, s, kh, hd)).astype(np.float32))
    o = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64)
    ro = ref.flash_attention_ref(q, repeat_kv(k, h), repeat_kv(v, h),
                                 causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ro),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_windowed(rng):
    s, h, hd, w = 256, 2, 32, 64
    q = jnp.asarray(rng.normal(size=(1, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, s, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, s, h, hd)).astype(np.float32))
    o = ops.flash_attention(q, k, v, causal=True, window=w,
                            block_q=64, block_kv=64)
    ro = ref.flash_attention_ref(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ro),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 64))).astype(jnp.bfloat16)
    o = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    ro = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ro, np.float32), atol=3e-2)


def test_flash_attention_uneven_blocks(rng):
    # Sq != Sk (q_offset causal alignment, chunked prefill case)
    sq, sk = 64, 192
    q = jnp.asarray(rng.normal(size=(1, sq, 2, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, sk, 2, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, sk, 2, 32)).astype(np.float32))
    o = ops.flash_attention(q, k, v, causal=True, block_q=32, block_kv=32)
    ro = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ro),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# linear scan
# ---------------------------------------------------------------------------

def _ld(rng, shape):
    return -jnp.abs(jnp.asarray(rng.normal(size=shape).astype(np.float32)))


@pytest.mark.parametrize("mode", ["rwkv", "ssm"])
@pytest.mark.parametrize("s,chunk,dk,dv", [(64, 16, 16, 16), (128, 32, 32, 64),
                                           (96, 8, 64, 32)])
def test_linear_scan_matches_recurrent_ref(rng, mode, s, chunk, dk, dv):
    b, h = 2, 2
    q = jnp.asarray(rng.normal(size=(b, s, h, dk)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, dk)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, dv)).astype(np.float32))
    ld = _ld(rng, (b, s, h, dk)) if mode == "rwkv" else _ld(rng, (b, s, h, 1))
    bonus = (jnp.asarray(rng.normal(size=(h, dk)).astype(np.float32))
             if mode == "rwkv" else None)
    y, st = ops.linear_scan(q, k, v, ld, bonus=bonus, chunk=chunk, mode=mode)
    ld_full = jnp.clip(jnp.broadcast_to(ld, (b, s, h, dk)), LOG_DECAY_MIN, -1e-9)
    ry, rst = ref.linear_scan_ref(q, k, v, ld_full, bonus=bonus, mode=mode)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ry), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(rst), atol=1e-3, rtol=1e-3)


def test_linear_scan_initial_state_chaining(rng):
    """Scanning two halves with carried state == one full scan."""
    b, s, h, dk, dv, chunk = 1, 64, 2, 16, 16, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, dk)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, dk)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, dv)).astype(np.float32))
    ld = _ld(rng, (b, s, h, dk))
    y_full, st_full = ops.linear_scan(q, k, v, ld, chunk=chunk, mode="ssm")
    m = s // 2
    y1, st1 = ops.linear_scan(q[:, :m], k[:, :m], v[:, :m], ld[:, :m],
                              chunk=chunk, mode="ssm")
    y2, st2 = ops.linear_scan(q[:, m:], k[:, m:], v[:, m:], ld[:, m:],
                              chunk=chunk, mode="ssm", initial_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               atol=1e-4, rtol=1e-4)


def test_linear_scan_matches_library_chunked_engine(rng):
    """Kernel == models.linear_attention.chunked_linear_attention (the jnp
    path the models actually run) — same clamping, same chunk math."""
    from repro.models.linear_attention import chunked_linear_attention
    b, s, h, dk, dv, chunk = 2, 64, 2, 16, 16, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, dk)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, dk)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, dv)).astype(np.float32))
    ld = _ld(rng, (b, s, h, dk))
    u = jnp.asarray(rng.normal(size=(h, dk)).astype(np.float32))
    y_k, st_k = ops.linear_scan(q, k, v, ld, bonus=u, chunk=chunk, mode="rwkv")
    y_j, st_j = chunked_linear_attention(q, k, v, ld, bonus=u, chunk=chunk,
                                         mode="rwkv")
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_j),
                               atol=1e-4, rtol=1e-4)
