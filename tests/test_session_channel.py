"""Channel-corruption coverage: packet-granularity damage through frame
reassembly must always be *detected* — every truncation and bit-flip either
raises a distinct CorruptStream or triggers a session resync; a wrong tensor
is never served.
"""
import numpy as np
import pytest

from repro.codec.rans import CorruptStream
from repro.pipeline import ModelSpec, OperatingPoint
from repro.pipeline import compile as pcompile
from repro.serve import ChannelConfig, SimulatedChannel
from repro.session import (SessionConfig, SessionDecoder, SessionEncoder,
                           SessionError)

OP = OperatingPoint(c=8, bits=6, backend="rans")


@pytest.fixture(scope="module")
def plan_for():
    spec = ModelSpec(sel_idx=np.arange(8))
    cache = {}

    def get(op):
        op = op.resolve()
        if op not in cache:
            cache[op] = pcompile(op, spec)
        return cache[op]
    return get


@pytest.fixture(scope="module")
def frame_and_codes(plan_for):
    rng = np.random.default_rng(1)
    z = rng.normal(size=(1, 8, 8, 8)).astype(np.float32)
    cfg = SessionConfig(session_id=5, levels=(OP,))
    enc = SessionEncoder(cfg, plan_for)
    blob, _ = enc.encode(z)
    codes, _ = plan_for(OP)._quantize(z)
    return bytes(blob), np.asarray(codes), cfg


def _fresh_decoder(plan_for, cfg):
    return SessionDecoder(cfg, plan_for)


# ---------------------------------------------------------------------------
# Exhaustive single-frame fuzz (no channel): detection is total
# ---------------------------------------------------------------------------

def test_every_truncation_is_detected(plan_for, frame_and_codes):
    blob, _, cfg = frame_and_codes
    for cut in range(len(blob)):            # every proper prefix
        with pytest.raises(CorruptStream):
            _fresh_decoder(plan_for, cfg).decode(blob[:cut])


def test_every_seeded_bit_flip_is_detected(plan_for, frame_and_codes):
    """256 seeded single-bit flips across the whole frame (header, CRCs,
    payload): none may decode — header bytes fail framing/header-CRC,
    payload bytes fail the payload CRC. Zero wrong tensors, ever."""
    blob, codes, cfg = frame_and_codes
    rng = np.random.default_rng(7)
    messages = set()
    for _ in range(256):
        pos = int(rng.integers(0, 8 * len(blob)))
        bad = bytearray(blob)
        bad[pos >> 3] ^= 1 << (pos & 7)
        with pytest.raises((CorruptStream, SessionError)) as ei:
            _fresh_decoder(plan_for, cfg).decode(bytes(bad))
        messages.add(str(ei.value).split(":")[0])
    # damage in different regions surfaces as *distinct* diagnoses
    assert len(messages) >= 3


def test_multi_bit_burst_damage_is_detected(plan_for, frame_and_codes):
    blob, _, cfg = frame_and_codes
    rng = np.random.default_rng(13)
    for _ in range(32):
        bad = bytearray(blob)
        start = int(rng.integers(0, len(bad) - 4))
        for off in range(4):                # 4-byte burst
            bad[start + off] ^= int(rng.integers(1, 256))
        with pytest.raises((CorruptStream, SessionError)):
            _fresh_decoder(plan_for, cfg).decode(bytes(bad))


# ---------------------------------------------------------------------------
# Through the packetized channel
# ---------------------------------------------------------------------------

def test_corrupting_channel_never_yields_a_wrong_tensor(plan_for):
    """Stream 40 frames through a channel that flips a bit in ~every packet:
    every delivery either decodes to the exact quantized codes or raises —
    the decoded-equals-quantized check runs on every success."""
    cfg = SessionConfig(session_id=6, levels=(OP,))
    enc = SessionEncoder(cfg, plan_for)
    dec = SessionDecoder(cfg, plan_for)
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=50e6,
                                        corrupt_p=0.5, mtu_bytes=128),
                          seed=21)
    plan = plan_for(OP)
    rng = np.random.default_rng(3)
    z = rng.normal(size=(1, 8, 8, 8)).astype(np.float32)
    failures = successes = 0
    for _ in range(40):
        z = z + 0.01 * rng.normal(size=z.shape).astype(np.float32)
        blob, _ = enc.encode(z)
        delivery = ch.transmit_frame(blob)
        assert not delivery.lost
        try:
            decoded, _ = dec.decode(delivery.data)
        except (CorruptStream, SessionError):
            failures += 1
            enc.nack()                       # intra refresh restores sync
            continue
        successes += 1
        want, _ = plan._quantize(z)
        assert np.array_equal(decoded.codes, np.asarray(want))
    assert failures > 0 and successes > 0
    assert dec.synced


def test_lossy_channel_drops_whole_frames_and_meters_the_wire(plan_for,
                                                              frame_and_codes):
    blob, _, _ = frame_and_codes
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=50e6, loss_p=1.0,
                                        mtu_bytes=64), seed=0)
    d = ch.transmit_frame(blob)
    assert d.lost and d.data is None
    assert d.lost_packets == d.n_packets == -(-len(blob) // 64)
    # lost bits still occupied the wire
    assert d.tx.bits == 8 * len(blob)
    assert d.tx.t_arrive > 0


def test_impairment_free_frame_matches_plain_transmit(frame_and_codes):
    """With no impairments configured, transmit_frame is transmit_bytes plus
    packetization — same RNG stream, same timings on a jittered channel."""
    blob, _, _ = frame_and_codes
    cfg = ChannelConfig(bandwidth_bps=5e6, base_latency_s=0.01,
                        jitter_s=0.002)
    a, b = SimulatedChannel(cfg, seed=9), SimulatedChannel(cfg, seed=9)
    ta = a.transmit_bytes(blob)
    tb = b.transmit_frame(blob)
    assert not tb.lost and not tb.corrupted
    assert tb.tx == ta                      # bitwise-equal Transmission


def test_reorder_delays_the_whole_frame(frame_and_codes):
    blob, _, _ = frame_and_codes
    base = SimulatedChannel(ChannelConfig(bandwidth_bps=50e6,
                                          mtu_bytes=64), seed=4)
    t_clean = base.transmit_frame(blob).tx.t_arrive
    ch = SimulatedChannel(ChannelConfig(bandwidth_bps=50e6, mtu_bytes=64,
                                        reorder_p=1.0, reorder_delay_s=0.05),
                          seed=4)
    d = ch.transmit_frame(blob)
    assert not d.lost
    assert d.tx.t_arrive == pytest.approx(t_clean + 0.05)


def test_channel_config_validates_impairments():
    with pytest.raises(ValueError):
        ChannelConfig(loss_p=1.5)
    with pytest.raises(ValueError):
        ChannelConfig(corrupt_p=-0.1)
    with pytest.raises(ValueError):
        ChannelConfig(reorder_delay_s=-1.0)
    with pytest.raises(ValueError):
        ChannelConfig(mtu_bytes=0)
    with pytest.raises(ValueError):
        SimulatedChannel(ChannelConfig()).transmit_frame(b"")
