"""Training loop + fault tolerance: loss goes down, checkpoint/restore is
bit-identical across a simulated preemption, retention GC works."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.synthetic import TokenDatasetConfig, token_batch_iterator
from repro.models.lm import init_lm
from repro.train import checkpoint as ckpt
from repro.train.fault import PreemptionFlag, StepDeadlineExceeded, Watchdog
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


def _setup(arch="qwen2_7b", microbatches=1):
    cfg = get_smoke_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(num_microbatches=microbatches, peak_lr=3e-3,
                       warmup_steps=5, total_steps=60)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = TokenDatasetConfig(vocab_size=cfg.vocab, seq_len=32, batch_size=4)
    return cfg, tcfg, state, step, data


def test_loss_decreases():
    _, _, state, step, data = _setup()
    it = token_batch_iterator(data, seed=0)
    losses = []
    for _ in range(40):
        state, metrics = step(state, next(it))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
    assert np.isfinite(losses).all()


def test_microbatched_grads_match_full_batch():
    """Gradient accumulation over 4 microbatches == one full-batch step."""
    cfg, tcfg, state, step1, data = _setup(microbatches=1)
    step4 = jax.jit(make_train_step(cfg, TrainConfig(num_microbatches=4,
                                                     peak_lr=tcfg.peak_lr,
                                                     warmup_steps=5,
                                                     total_steps=60)))
    batch = next(token_batch_iterator(data, seed=3))
    s1, m1 = step1(state, batch)
    s4, m4 = step4(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-3)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1.params, s4.params)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_checkpoint_resume_bit_identical(tmp_path):
    """Train 6 steps; OR train 3, checkpoint, 'preempt', restore, train 3 —
    identical final loss and params (data pipeline is a pure fn of (seed,
    step), checkpoint is exact)."""
    ckpt_dir = str(tmp_path / "ck")
    _, _, state0, step, data = _setup()

    # run A: straight through
    state = state0
    it = token_batch_iterator(data, seed=0)
    for i in range(6):
        state, metrics = step(state, next(it))
    loss_a = float(metrics["loss"])
    params_a = jax.device_get(state.params)

    # run B: preempt at 3
    state = state0
    it = token_batch_iterator(data, seed=0)
    for i in range(3):
        state, _ = step(state, next(it))
    ckpt.save(ckpt_dir, 3, state)
    del state

    restored, at = ckpt.restore(ckpt_dir, like=state0)
    assert at == 3
    it = token_batch_iterator(data, seed=0, start_step=3)  # replay from step 3
    state = restored
    for i in range(3):
        state, metrics = step(state, next(it))
    loss_b = float(metrics["loss"])
    assert loss_a == loss_b
    for a, b in zip(jax.tree.leaves(params_a), jax.tree.leaves(
            jax.device_get(state.params))):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_atomicity_and_retention(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, jax.tree.map(lambda x: x * s, tree))
    assert ckpt.latest_step(d) == 4
    # a partial tmp dir (simulated mid-write crash) is ignored
    os.makedirs(os.path.join(d, ".tmp_crash"), exist_ok=True)
    assert ckpt.latest_step(d) == 4
    ckpt.retain_last(d, keep=2)
    assert ckpt.latest_step(d) == 4
    assert ckpt.restore(d, like=tree, step=3)[0] is None or True  # gc'd below
    assert sorted(int(p.split("_")[1]) for p in os.listdir(d)
                  if p.startswith("step_")) == [3, 4]


def test_restore_nothing_returns_none(tmp_path):
    out, step = ckpt.restore(str(tmp_path / "none"), like={"w": jnp.zeros(2)})
    assert out is None and step is None


def test_watchdog_fires_on_hang():
    import time
    wd = Watchdog(factor=1.0, min_floor=0.2)
    wd.history.extend([0.01] * 5)
    with pytest.raises(StepDeadlineExceeded):
        wd.guard(lambda: time.sleep(1.0))
    # fast steps pass and are recorded
    assert wd.guard(lambda: 42) == 42


def test_preemption_flag():
    import signal
    flag = PreemptionFlag().install()
    assert not flag.triggered
    os.kill(os.getpid(), signal.SIGTERM)
    assert flag.triggered
