"""Paper §3.3 + eq. (6): BaF predictor and consolidation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro import nn
from repro.core.baf import (BaFConvConfig, BaFStreamConfig, baf_conv_backward,
                            baf_conv_predict, baf_stream_backward,
                            baf_stream_predict, consolidate, gather_bn,
                            init_baf_conv, init_baf_stream,
                            scatter_consolidated)
from repro.core.quant import compute_quant_params, dequantize, quantize


# ---------------------------------------------------------------------------
# Consolidation — eq. (6)
# ---------------------------------------------------------------------------

def test_consolidate_keeps_in_bin_estimates(rng):
    z = jnp.asarray(rng.normal(size=(2, 4, 4, 8)).astype(np.float32))
    qp = compute_quant_params(z, 8)
    codes = quantize(z, qp)
    # estimate == truth -> same bin -> kept verbatim
    out = consolidate(z, codes, qp)
    assert np.allclose(np.asarray(out), np.asarray(z), atol=1e-6)


def test_consolidate_clamps_out_of_bin_to_boundary(rng):
    z = jnp.zeros((1, 1, 1, 1), jnp.float32)
    qp = compute_quant_params(jnp.linspace(-1, 1, 16).reshape(1, 4, 4, 1), 4)
    codes = quantize(jnp.full((1, 1, 1, 1), 0.9), qp)     # a high bin
    est = jnp.full((1, 1, 1, 1), -0.9)                    # estimate far below
    out = consolidate(est, codes, qp)
    from repro.core.quant import bin_bounds
    lo, hi = bin_bounds(codes, qp)
    assert np.allclose(np.asarray(out), np.asarray(lo))   # nearest boundary


@given(bits=st.integers(2, 8), seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_property_consolidation_never_hurts(bits, seed):
    """|consolidate(est) - truth| <= |est - truth| + step (consolidated value
    stays inside the truth's bin, so error is bounded by the bin width)."""
    r = np.random.default_rng(seed)
    z = jnp.asarray(r.normal(size=(1, 8, 8, 4)).astype(np.float32))
    est = z + jnp.asarray(r.normal(size=z.shape).astype(np.float32)) * 0.5
    qp = compute_quant_params(z, bits)
    codes = quantize(z, qp)
    out = consolidate(est, codes, qp)
    step = np.asarray(qp.step())
    err = np.abs(np.asarray(out) - np.asarray(z))
    assert (err <= step + 1e-4).all()                     # within one bin
    # and never worse than the dequantized fallback by more than eps
    base = np.abs(np.asarray(dequantize(codes, qp)) - np.asarray(z))
    assert err.mean() <= base.mean() + float(step.mean())


def test_scatter_consolidated(rng):
    z = jnp.zeros((1, 2, 2, 6))
    sel = jnp.asarray([4, 1])
    cons = jnp.ones((1, 2, 2, 2))
    out = scatter_consolidated(z, cons, sel)
    assert bool(jnp.all(out[..., 4] == 1)) and bool(jnp.all(out[..., 1] == 1))
    assert bool(jnp.all(out[..., 0] == 0))


# ---------------------------------------------------------------------------
# BN inverse (backward predictor entry, paper §3.3)
# ---------------------------------------------------------------------------

def test_batchnorm_inverse(rng):
    p = {
        "scale": jnp.asarray(rng.uniform(0.5, 2, 8).astype(np.float32)),
        "bias": jnp.asarray(rng.normal(size=8).astype(np.float32)),
        "mean": jnp.asarray(rng.normal(size=8).astype(np.float32)),
        "var": jnp.asarray(rng.uniform(0.5, 2, 8).astype(np.float32)),
    }
    x = jnp.asarray(rng.normal(size=(2, 4, 4, 8)).astype(np.float32))
    z = nn.batchnorm_apply(p, x)
    x_back = nn.batchnorm_inverse(p, z)
    assert np.allclose(np.asarray(x_back), np.asarray(x), atol=1e-4)


# ---------------------------------------------------------------------------
# Conv BaF predictor (Fig. 2)
# ---------------------------------------------------------------------------

def test_baf_conv_shapes(rng):
    cfg = BaFConvConfig(c=8, q=16, hidden=12)
    params = init_baf_conv(jax.random.PRNGKey(0), cfg)
    bn = nn.init_batchnorm(32)
    z_sel = jnp.asarray(rng.normal(size=(2, 4, 4, 8)).astype(np.float32))
    bn_sel = gather_bn(bn, jnp.arange(8))
    x_tilde = baf_conv_backward(params, z_sel, bn_sel)
    assert x_tilde.shape == (2, 8, 8, 16)     # x2 upsample (stride-2 split)


def test_baf_conv_predict_full_pipeline(rng):
    c, q, p_ch = 4, 8, 16
    cfg = BaFConvConfig(c=c, q=q, hidden=8)
    baf = init_baf_conv(jax.random.PRNGKey(0), cfg)
    split_conv = nn.init_conv(jax.random.PRNGKey(1), q, p_ch, 3, bias=False)
    split_bn = nn.init_batchnorm(p_ch)
    sel = jnp.arange(c)
    z_sel = jnp.asarray(rng.normal(size=(2, 4, 4, c)).astype(np.float32))
    z_tilde = baf_conv_predict(baf, split_conv, split_bn, sel, z_sel)
    assert z_tilde.shape == (2, 4, 4, p_ch)   # all P channels restored
    assert not bool(jnp.any(jnp.isnan(z_tilde)))
    # with consolidation: transmitted channels end inside their bins
    qp = compute_quant_params(z_sel, 8, per_example=True)
    codes = quantize(z_sel, qp)
    z_cons = baf_conv_predict(baf, split_conv, split_bn, sel, z_sel,
                              codes=codes, qp=qp)
    from repro.core.quant import bin_bounds
    lo, hi = bin_bounds(codes, qp)
    got = np.asarray(z_cons[..., :c])
    assert (got >= np.asarray(lo) - 1e-4).all()
    assert (got <= np.asarray(hi) + 1e-4).all()


def test_baf_training_reduces_charbonnier(rng):
    """Short end-to-end Tier-A check: a few steps of BaF training reduce the
    restoration loss on the frozen-CNN feature distribution."""
    from repro.configs.yolo_baf import smoke_config, smoke_data_config
    from repro.models.cnn import init_cnn
    from repro.train.baf_trainer import make_baf_loss, train_baf

    cnn_cfg = smoke_config()._replace(input_size=32)
    data_cfg = smoke_data_config()._replace(image_size=32, batch_size=4)
    cnn = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    sel = np.arange(8)
    res = train_baf(cnn, cnn_cfg, data_cfg, sel, bits=8, hidden=8, steps=30,
                    verbose=False)
    first = res.losses[0][1]
    from repro.models.cnn import cnn_edge
    from repro.data.synthetic import shapes_batch_iterator
    img, _ = next(shapes_batch_iterator(data_cfg, seed=123))
    z = cnn_edge(cnn, img)[1]
    final = float(make_baf_loss(cnn, sel, 8)(res.baf_params, z))
    assert final < first


# ---------------------------------------------------------------------------
# Stream BaF predictor (transformer variant)
# ---------------------------------------------------------------------------

def test_baf_stream_predict(rng):
    cfg = BaFStreamConfig(c=8, d_in=16, hidden=32)
    params = init_baf_stream(jax.random.PRNGKey(0), cfg)
    z_sel = jnp.asarray(rng.normal(size=(2, 6, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16, 24)).astype(np.float32)) * 0.1
    fwd = lambda x: x @ w                    # frozen "block"
    sel = jnp.arange(8)
    z_tilde = baf_stream_predict(params, fwd, sel, z_sel)
    assert z_tilde.shape == (2, 6, 24)
    assert not bool(jnp.any(jnp.isnan(z_tilde)))
