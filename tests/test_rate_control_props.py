"""Property tests for (content-keyed) rate control.

Invariants pinned here (hypothesis when installed, deterministic
spot-checks always):

  * floor satisfaction — with an unmetered budget, the selected point meets
    the PSNR floor whenever ANY table entry does (per-request estimates
    included);
  * budget monotonicity — as the bit budget shrinks, the wire cost of the
    selected point is monotone non-increasing (never spend more under a
    tighter budget).
"""
import math

import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core.split import ActivationStats
from repro.serve import ContentKeyedController, OperatingPoint, RDPoint

# -- strategies -------------------------------------------------------------

if HAVE_HYPOTHESIS:
    def _tables():
        point = st.tuples(
            st.sampled_from([2, 4, 8, 16]),           # C
            st.sampled_from([2, 4, 6, 8]),            # bits
            st.floats(100.0, 1e6),                    # bits_per_example
            st.floats(5.0, 45.0),                     # psnr_db
            st.floats(0.5, 8.0),                      # calib_peak
            st.floats(0.1, 6.0),                      # calib_range
        ).map(lambda t: RDPoint(
            op=OperatingPoint(c=t[0], bits=t[1]), bits_per_example=t[2],
            psnr_db=t[3], calib_peak=t[4], calib_range=t[5]))
        return st.lists(point, min_size=1, max_size=12)

    def _stats():
        one = st.tuples(st.floats(0.2, 10.0), st.floats(0.05, 8.0)).map(
            lambda t: ActivationStats(peak=t[0], dyn_range=t[1]))
        return st.one_of(st.none(), one,
                         st.dictionaries(st.sampled_from([2, 4, 8, 16]),
                                         one, max_size=4))
else:  # pragma: no cover - the @given decorator skips these tests anyway
    def _tables():
        return None

    def _stats():
        return None


# -- properties -------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(table=_tables(), floor=st.floats(0.0, 50.0) if HAVE_HYPOTHESIS else None,
       stats=_stats())
def test_selection_meets_floor_whenever_any_entry_does(table, floor, stats):
    rc = ContentKeyedController(table, quality_floor_db=floor)
    pick = rc.select_for(None, stats)
    est = {id(p): rc.estimate_psnr_db(p, stats) for p in rc.table}
    if any(v >= floor for v in est.values()):
        assert est[id(pick)] >= floor


@settings(max_examples=200, deadline=None)
@given(table=_tables(),
       floor=st.floats(0.0, 50.0) if HAVE_HYPOTHESIS else None,
       stats=_stats(),
       budgets=(st.lists(st.floats(10.0, 2e6), min_size=2, max_size=8)
                if HAVE_HYPOTHESIS else None))
def test_selected_cost_monotone_in_budget(table, floor, stats, budgets):
    rc = ContentKeyedController(table, quality_floor_db=floor)
    costs = [rc.select_for(b, stats).bits_per_example
             for b in sorted(budgets, reverse=True)]
    # non-increasing throughout: the nothing-fits fallback is the globally
    # cheapest point, which can never exceed an earlier (feasible) pick
    assert all(a >= b for a, b in zip(costs, costs[1:]))


# -- deterministic spot checks (run even without hypothesis) ----------------

TABLE = [
    RDPoint(OperatingPoint(c=4, bits=2), 1_000, 12.0,
            calib_peak=2.0, calib_range=1.0),
    RDPoint(OperatingPoint(c=8, bits=4), 4_000, 20.0,
            calib_peak=2.0, calib_range=1.0),
    RDPoint(OperatingPoint(c=8, bits=8), 8_000, 26.0,
            calib_peak=2.0, calib_range=1.0),
]


def test_content_shift_direction():
    """Wilder content (bigger dynamic range) lowers the PSNR estimate;
    tamer content raises it — peak held at the calibration anchor."""
    rc = ContentKeyedController(TABLE, quality_floor_db=19.0)
    p = TABLE[1]
    wild = ActivationStats(peak=2.0, dyn_range=4.0)
    tame = ActivationStats(peak=2.0, dyn_range=0.25)
    assert rc.estimate_psnr_db(p, wild) < p.psnr_db < \
        rc.estimate_psnr_db(p, tame)
    # 4x the range = 12 dB down, exactly
    assert rc.estimate_psnr_db(p, wild) == pytest.approx(20.0 - 12.04, 0.01)


def test_content_keying_changes_the_operating_point():
    """Tame content lets a cheaper point clear the floor -> fewer bits."""
    rc = ContentKeyedController(TABLE, quality_floor_db=19.0)
    tame = ActivationStats(peak=2.0, dyn_range=0.4)   # +14 dB shift
    assert rc.select_for(None, None).op == OperatingPoint(c=8, bits=8)
    # floor now met by the 4-bit point too; best-quality policy still takes
    # the highest estimate, but under a 5k budget tame content passes the
    # floor where calibration stats would have degraded below it
    budget_pick_tame = rc.select_for(5_000, tame)
    est = rc.estimate_psnr_db(budget_pick_tame, tame)
    assert est >= 19.0
    assert budget_pick_tame.op == OperatingPoint(c=8, bits=4)


def test_missing_anchors_fall_back_to_table_psnr():
    rc = ContentKeyedController(
        [RDPoint(OperatingPoint(c=4, bits=2), 1_000, 12.0)],
        quality_floor_db=5.0)
    stats = ActivationStats(peak=9.0, dyn_range=9.0)
    assert rc.estimate_psnr_db(rc.table[0], stats) == 12.0


def test_invariants_hold_on_seeded_random_tables(rng):
    """The two properties above, exercised without hypothesis: 200 seeded
    random tables/budgets/stats through the same assertions."""
    for _ in range(200):
        n = int(rng.integers(1, 12))
        table = [RDPoint(
            op=OperatingPoint(c=int(rng.choice([2, 4, 8, 16])),
                              bits=int(rng.choice([2, 4, 6, 8]))),
            bits_per_example=float(rng.uniform(100, 1e6)),
            psnr_db=float(rng.uniform(5, 45)),
            calib_peak=float(rng.uniform(0.5, 8)),
            calib_range=float(rng.uniform(0.1, 6)))
            for _ in range(n)]
        floor = float(rng.uniform(0, 50))
        stats = (None if rng.random() < 0.3 else ActivationStats(
            peak=float(rng.uniform(0.2, 10)),
            dyn_range=float(rng.uniform(0.05, 8))))
        rc = ContentKeyedController(table, quality_floor_db=floor)
        est = {id(p): rc.estimate_psnr_db(p, stats) for p in rc.table}
        pick = rc.select_for(None, stats)
        if any(v >= floor for v in est.values()):
            assert est[id(pick)] >= floor
        budgets = sorted(rng.uniform(10, 2e6, size=6), reverse=True)
        costs = [rc.select_for(float(b), stats).bits_per_example
                 for b in budgets]
        assert all(a >= b for a, b in zip(costs, costs[1:]))


def test_select_for_respects_per_tenant_floor_override():
    rc = ContentKeyedController(TABLE, quality_floor_db=99.0)
    # controller floor is unreachable, per-tenant override is not
    pick = rc.select_for(5_000, None, 19.0)
    assert pick.op == OperatingPoint(c=8, bits=4)


# -- P-frame-aware session pricing ------------------------------------------

def _pt(p_over_i=math.nan, bits=8_000.0):
    return RDPoint(OperatingPoint(c=8, bits=6), bits_per_example=bits,
                   psnr_db=25.0, p_over_i=p_over_i)


def test_session_bits_without_measured_ratio_is_i_only():
    from repro.serve import session_bits_per_frame
    assert session_bits_per_frame(_pt(), keyframe_interval=8) == 8_000.0
    assert session_bits_per_frame(_pt(), keyframe_interval=0) == 8_000.0


def test_session_bits_interpolates_keyframe_interval():
    from repro.serve import session_bits_per_frame
    p = _pt(p_over_i=0.5)
    # k=1 = every frame an I-frame; k=4 = I,P,P,P; k=0 = all-P steady state
    assert session_bits_per_frame(p, keyframe_interval=1) == 8_000.0
    assert session_bits_per_frame(p, keyframe_interval=4) == \
        pytest.approx(8_000.0 * (1 + 3 * 0.5) / 4)
    assert session_bits_per_frame(p, keyframe_interval=0) == \
        pytest.approx(4_000.0)


def test_session_bits_stride_divides_and_args_validate():
    from repro.serve import session_bits_per_frame
    p = _pt(p_over_i=0.25)
    full = session_bits_per_frame(p, keyframe_interval=8)
    assert session_bits_per_frame(p, keyframe_interval=8,
                                  frame_stride=2) == pytest.approx(full / 2)
    with pytest.raises(ValueError):
        session_bits_per_frame(p, keyframe_interval=-1)
    with pytest.raises(ValueError):
        session_bits_per_frame(p, keyframe_interval=8, frame_stride=0)


@given(ratio=st.floats(0.0, 1.0) if HAVE_HYPOTHESIS else None,
       k=st.integers(1, 32) if HAVE_HYPOTHESIS else None)
@settings(max_examples=100, deadline=None)
def test_session_bits_bounded_by_i_only_price(ratio, k):
    """P-frames only ever save bits: the session price never exceeds the
    I-only price and never drops below the all-P steady state."""
    from repro.serve import session_bits_per_frame
    p = _pt(p_over_i=ratio)
    per = session_bits_per_frame(p, keyframe_interval=k)
    assert per <= p.bits_per_example + 1e-9
    assert per >= ratio * p.bits_per_example - 1e-9
