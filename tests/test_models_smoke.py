"""Per-arch smoke tests: reduced same-family config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import SHAPES, active_param_count, total_param_count
from repro.models.encdec import encdec_loss, init_encdec
from repro.models.lm import (init_decode_cache, init_lm, lm_decode_step,
                             lm_forward, lm_loss)
from repro.optim import adamw_init, adamw_update

B, S = 2, 32


def _batch(cfg, key):
    if cfg.family == "audio":
        return {
            "audio_embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                              jnp.bfloat16),
            "tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32),
        }
    if not cfg.embed_inputs:
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.bfloat16),
                "labels": jnp.ones((B, S), jnp.int32)}
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    init = init_encdec if cfg.family == "audio" else init_lm
    params = init(key, cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    # forward: logits shape + finite
    if cfg.family == "audio":
        from repro.models.encdec import decode_train, encode
        enc = encode(params, cfg, batch["audio_embeds"])
        logits = decode_train(params, cfg, batch["tokens"], enc)
    else:
        logits, _ = lm_forward(params, cfg, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"), remat=False)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one train step: loss finite, params move, still finite
    loss_fn = encdec_loss if cfg.family == "audio" else lm_loss
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    opt = adamw_init(params)
    new_params, _, _ = adamw_update(grads, opt, params, 1e-3)
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in
               jax.tree.leaves(new_params))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    if cfg.family == "audio":
        from repro.models.encdec import (encdec_decode_step, encode,
                                         init_encdec_cache)
        params = init_encdec(key, cfg)
        enc = encode(params, cfg,
                     jax.random.normal(jax.random.PRNGKey(1),
                                       (B, 16, cfg.d_model), jnp.bfloat16))
        cache = init_encdec_cache(params, cfg, enc, max_len=8)
        tok = jnp.zeros((B,), jnp.int32)
        for _ in range(3):
            logits, cache = encdec_decode_step(params, cfg, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        return
    params = init_lm(key, cfg)
    cache = init_decode_cache(cfg, B, max_len=8)
    tok = jnp.zeros((B,), jnp.int32)
    for _ in range(3):
        logits, cache = lm_decode_step(params, cfg, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch,expect_b", [
    ("qwen2_72b", 72e9), ("qwen2_7b", 7e9), ("starcoder2_15b", 15e9),
    ("nemotron4_15b", 15e9), ("rwkv6_3b", 3e9), ("pixtral_12b", 12e9),
    ("zamba2_1p2b", 1.2e9),
])
def test_full_config_param_counts(arch, expect_b):
    """Analytic parameter count lands within ~35% of the marketing size
    (embeddings and per-arch details account for the slack)."""
    cfg = get_config(arch)
    n = total_param_count(cfg)
    assert 0.65 * expect_b < n < 1.45 * expect_b, f"{arch}: {n:.3e}"


def test_moe_param_counts():
    olmoe = get_config("olmoe_1b_7b")
    assert 0.6e9 < active_param_count(olmoe) < 1.8e9      # ~1B active
    assert 5e9 < total_param_count(olmoe) < 9e9           # ~7B total
    arctic = get_config("arctic_480b")
    assert 350e9 < total_param_count(arctic) < 560e9      # ~480B total


def test_supported_shapes_policy():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        sup = cfg.supported_shapes
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in sup                      # sub-quadratic
        else:
            assert "long_500k" not in sup                  # O(S^2) skip
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(sup)
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
