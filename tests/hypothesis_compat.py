"""Shared hypothesis import that degrades gracefully.

Property-based tests use hypothesis when it is installed (``pip install -r
requirements-dev.txt``); on bare environments the import used to take down
collection of six whole test modules. Import through this helper instead:

    from hypothesis_compat import given, settings, st

With hypothesis present these are the real objects. Without it, ``@given``
replaces the property test with a skip (reason: hypothesis not installed) so
the non-property tests in the same file still collect and run.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for any strategy object; never actually drawn from."""

        def __getattr__(self, name):
            return lambda *a, **k: self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(see requirements-dev.txt)")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco
