"""Invariant linter (repro.analysis): rule fixtures, pragma grammar, the
ratchet baseline, wire-format fingerprints, the autofixer, and the runtime
replay sanitizer.

Rule tests write toy snippets to a tmp tree at *scoped* relative paths
(e.g. ``src/repro/serve/x.py``) because most rules are path-scoped; each
true-positive fixture is paired with a clean twin proving the rule does not
overfire. The RA04 and negative-control tests copy the *real* modules into
a tmp tree and mutate them — the linter must catch exactly the edit the
acceptance criteria describe (a struct layout change without a
``codec_revision()`` bump; a seeded ``time.time()`` in the gateway).
"""
import json
import os
import random
import shutil
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import (ReplaySanitizerError, engine, fixes,
                            replay_sanitizer, rules, wire)

REPO = Path(__file__).resolve().parents[1]


def _tree(tmp_path, files):
    root = tmp_path / "repo"
    for rel, code in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(code))
    return str(root)


def _violations(tmp_path, rel, code, rule=None):
    root = _tree(tmp_path, {rel: code})
    _, vs = engine.analyze_file(root, rel)
    if rule is not None:
        vs = [v for v in vs if v.rule == rule]
    return vs


# ---------------------------------------------------------------------------
# RA01 — virtual-clock purity
# ---------------------------------------------------------------------------

def test_ra01_flags_wall_clock_in_scope(tmp_path):
    code = """\
        import time

        def now():
            return time.time()
    """
    vs = _violations(tmp_path, "src/repro/serve/x.py", code, "RA01")
    assert len(vs) == 1 and "time.time" in vs[0].message
    assert not vs[0].suppressed


def test_ra01_resolves_from_imports_and_datetime(tmp_path):
    code = """\
        from time import perf_counter
        from datetime import datetime

        def stamp():
            return perf_counter(), datetime.now()
    """
    vs = _violations(tmp_path, "src/repro/session/x.py", code, "RA01")
    assert {m for v in vs for m in [v.message.split("(")[0]]} \
        == {"wall-clock call time.perf_counter",
            "wall-clock call datetime.datetime.now"}


def test_ra01_out_of_scope_and_allowlisted_files_are_clean(tmp_path):
    code = "import time\nT = time.time()\n"
    assert not _violations(tmp_path, "src/repro/kernels/x.py", code, "RA01")
    assert not _violations(tmp_path, "src/repro/obs/hooks.py", code, "RA01")


# ---------------------------------------------------------------------------
# RA02 — determinism: legacy RNG + set iteration
# ---------------------------------------------------------------------------

def test_ra02_flags_legacy_rng_everywhere(tmp_path):
    code = """\
        import random
        import numpy as np

        x = np.random.rand(3)
        random.shuffle([1, 2])
    """
    vs = _violations(tmp_path, "src/repro/models/x.py", code, "RA02")
    assert len(vs) == 2
    assert any("numpy.random.rand" in v.message for v in vs)
    assert any("random.shuffle" in v.message for v in vs)


def test_ra02_explicit_generators_are_clean(tmp_path):
    code = """\
        import random
        import numpy as np

        rng = np.random.default_rng(0)
        x = rng.random(3)
        r = random.Random(0)
        r.shuffle([1, 2])
    """
    assert not _violations(tmp_path, "src/repro/models/x.py", code, "RA02")


def test_ra02_set_iteration_in_scope(tmp_path):
    bad = "for k in {1, 2}:\n    print(k)\n"
    vs = _violations(tmp_path, "src/repro/serve/x.py", bad, "RA02")
    assert len(vs) == 1 and "iteration over a set" in vs[0].message
    # sorted() is the fix, not a violation — and out-of-scope trees may
    # iterate sets freely
    good = "for k in sorted({1, 2}):\n    print(k)\n"
    assert not _violations(tmp_path, "src/repro/serve/y.py", good, "RA02")
    assert not _violations(tmp_path, "tools/x.py", bad, "RA02")


def test_ra02_sorted_genexp_over_set_union_is_clean(tmp_path):
    # the obs/bench.py config-drift idiom: a generator over a set union fed
    # straight into sorted() is order-insensitive by construction
    code = """\
        def drift(a, b):
            return sorted(k for k in set(a) | set(b)
                          if a.get(k) != b.get(k))
    """
    assert not _violations(tmp_path, "src/repro/obs/x.py", code, "RA02")


def test_ra02_list_of_set_flagged(tmp_path):
    code = "ORDER = list({'a', 'b'})\n"
    vs = _violations(tmp_path, "src/repro/codec/x.py", code, "RA02")
    assert len(vs) == 1


# ---------------------------------------------------------------------------
# RA03 — compat discipline
# ---------------------------------------------------------------------------

def test_ra03_raw_experimental_import_flagged_outside_shims(tmp_path):
    code = "from jax.experimental import pallas as pl\n"
    vs = _violations(tmp_path, "src/repro/kernels/foo.py", code, "RA03")
    assert len(vs) == 1 and "compat" in vs[0].message
    # the shim itself is the sanctioned home for exactly this import
    assert not _violations(tmp_path, "src/repro/kernels/compat.py",
                           code, "RA03")


def test_ra03_shard_map_and_attribute_chains(tmp_path):
    code = """\
        import jax
        from jax import shard_map

        call = jax.experimental.pallas.pallas_call
    """
    vs = _violations(tmp_path, "src/repro/serve/foo.py", code, "RA03")
    msgs = " | ".join(v.message for v in vs)
    assert "from jax import shard_map" in msgs
    assert "jax.experimental.pallas" in msgs


def test_ra03_compat_routed_imports_are_clean(tmp_path):
    code = """\
        from repro.kernels.compat import CompilerParams, pl, pltpu

        grid = pl.BlockSpec
    """
    assert not _violations(tmp_path, "src/repro/kernels/foo.py",
                           code, "RA03")


# ---------------------------------------------------------------------------
# RA05 — host-sync inside traced bodies
# ---------------------------------------------------------------------------

def test_ra05_item_in_jitted_body(tmp_path):
    code = """\
        import jax

        @jax.jit
        def f(x):
            return x.item()
    """
    vs = _violations(tmp_path, "src/repro/core/x.py", code, "RA05")
    assert len(vs) == 1 and ".item()" in vs[0].message
    # the same body untraced is host code and fine
    clean = "def f(x):\n    return x.item()\n"
    assert not _violations(tmp_path, "src/repro/core/y.py", clean, "RA05")


def test_ra05_pallas_kernel_body_and_np_asarray(tmp_path):
    code = """\
        import numpy as np
        from repro.kernels.compat import pl

        def kernel(x_ref, o_ref):
            o_ref[0] = float(x_ref[0])
            y = np.asarray(x_ref)

        def run(x):
            return pl.pallas_call(kernel, out_shape=x)(x)
    """
    vs = _violations(tmp_path, "src/repro/kernels/x.py", code, "RA05")
    msgs = " | ".join(v.message for v in vs)
    assert "float()" in msgs and "numpy.asarray" in msgs
    # float on a literal concretizes nothing
    lit = "import jax\n\n@jax.jit\ndef f(x):\n    return x + float(1)\n"
    assert not _violations(tmp_path, "src/repro/kernels/y.py", lit, "RA05")


# ---------------------------------------------------------------------------
# RA06 — silent failure
# ---------------------------------------------------------------------------

def test_ra06_bare_and_silent_catchalls(tmp_path):
    code = """\
        try:
            a()
        except:
            handle()
        try:
            b()
        except Exception:
            pass
    """
    vs = _violations(tmp_path, "src/repro/serve/x.py", code, "RA06")
    assert len(vs) == 2
    assert any("bare 'except:'" in v.message for v in vs)
    assert any("silently discards" in v.message for v in vs)


def test_ra06_typed_or_handled_excepts_are_clean(tmp_path):
    code = """\
        try:
            a()
        except ValueError:
            pass
        try:
            b()
        except Exception as e:
            log(e)
    """
    assert not _violations(tmp_path, "src/repro/serve/x.py", code, "RA06")


def test_ra06_allowlisted_best_effort_file(tmp_path):
    code = "try:\n    a()\nexcept Exception:\n    pass\n"
    assert not _violations(tmp_path, "src/repro/obs/bench.py", code, "RA06")


# ---------------------------------------------------------------------------
# Pragmas (RA00 hygiene)
# ---------------------------------------------------------------------------

def test_pragma_with_reason_suppresses(tmp_path):
    code = ("import time\n"
            "T = time.time()  # repro: allow[RA01] -- fixture wants wall\n")
    vs = _violations(tmp_path, "src/repro/serve/x.py", code)
    ra01 = [v for v in vs if v.rule == "RA01"]
    assert len(ra01) == 1 and ra01[0].suppressed
    assert ra01[0].reason == "fixture wants wall"
    assert not [v for v in vs if v.rule == "RA00"]


def test_pragma_without_reason_rejected_and_nothing_suppressed(tmp_path):
    code = ("import time\n"
            "T = time.time()  # repro: allow[RA01]\n")
    vs = _violations(tmp_path, "src/repro/serve/x.py", code)
    ra01 = [v for v in vs if v.rule == "RA01"]
    assert len(ra01) == 1 and not ra01[0].suppressed
    ra00 = [v for v in vs if v.rule == "RA00"]
    assert len(ra00) == 1 and "no reason" in ra00[0].message


def test_own_line_pragma_and_comment_block_continuation(tmp_path):
    code = ("import time\n"
            "# repro: allow[RA01] -- measures real compute wall; the\n"
            "# reading feeds telemetry, never the virtual clock\n"
            "T = time.time()\n")
    vs = _violations(tmp_path, "src/repro/serve/x.py", code)
    ra01 = [v for v in vs if v.rule == "RA01"]
    assert len(ra01) == 1 and ra01[0].suppressed
    assert not [v for v in vs if v.rule == "RA00"]


def test_unused_and_unknown_pragmas_flagged(tmp_path):
    code = ("X = 1  # repro: allow[RA01] -- nothing here violates it\n"
            "Y = 2  # repro: allow[RA99] -- no such rule\n")
    vs = _violations(tmp_path, "src/repro/serve/x.py", code, "RA00")
    msgs = " | ".join(v.message for v in vs)
    assert "unused suppression" in msgs and "unknown rule id" in msgs


def test_hard_rules_cannot_be_baselined(tmp_path):
    # an RA00 violation fails the run even with a fully matching baseline
    root = _tree(tmp_path, {
        "src/repro/serve/x.py": "X = 1  # repro: allow[RA01]\n"})
    ws = os.path.join(root, "ws.json")
    bl = os.path.join(root, "bl.json")
    wire.write_wire_schema(root, ws)
    engine.write_baseline(bl, {}, rules.config_fingerprint())
    res = engine.run_analysis(root, baseline_path=bl, wire_schema_path=ws,
                              max_violations=10_000)
    assert not res.ok
    assert any("[RA00]" in f for f in res.failures)


# ---------------------------------------------------------------------------
# Ratchet semantics
# ---------------------------------------------------------------------------

_CLOCK_SNIPPET = "import time\n\n\ndef now():\n    return time.time()\n"


def _toy_repo(tmp_path, code=_CLOCK_SNIPPET):
    root = _tree(tmp_path, {"src/repro/serve/clock.py": code})
    bl = os.path.join(root, "baseline.json")
    ws = os.path.join(root, "wire_schema.json")
    wire.write_wire_schema(root, ws)
    return root, bl, ws


def _run(root, bl, ws, **kw):
    kw.setdefault("max_violations", 0)
    return engine.run_analysis(root, baseline_path=bl, wire_schema_path=ws,
                               **kw)


def test_missing_baseline_fails(tmp_path):
    root, bl, ws = _toy_repo(tmp_path)
    res = _run(root, bl, ws)
    assert not res.ok and any("no baseline" in f for f in res.failures)


def test_ratchet_regression_fails_and_budget_admits(tmp_path):
    root, bl, ws = _toy_repo(tmp_path)
    res = _run(root, bl, ws)
    assert res.counts == {"RA01:src/repro/serve/clock.py": 1}
    engine.write_baseline(bl, res.counts, rules.config_fingerprint())
    assert _run(root, bl, ws).ok

    # a second wall-clock call regresses past the baseline
    p = Path(root, "src/repro/serve/clock.py")
    p.write_text(p.read_text() + "\n\nT0 = time.time()\n")
    res = _run(root, bl, ws)
    assert not res.ok
    assert any(f.startswith("ratchet regression:") for f in res.failures)
    # ... unless the explicit MAX_LINT_VIOLATIONS budget covers the excess
    assert _run(root, bl, ws, max_violations=1).ok


def test_fixed_violation_must_lower_the_baseline(tmp_path):
    root, bl, ws = _toy_repo(tmp_path)
    res = _run(root, bl, ws)
    engine.write_baseline(bl, res.counts, rules.config_fingerprint())

    Path(root, "src/repro/serve/clock.py").write_text(
        "def now(clock):\n    return clock.now_s\n")
    res = _run(root, bl, ws)
    assert not res.ok
    assert any(f.startswith("stale baseline:") for f in res.failures)
    # the budget never excuses a stale baseline — only regressions
    assert not _run(root, bl, ws, max_violations=50).ok
    engine.write_baseline(bl, res.counts, rules.config_fingerprint())
    assert _run(root, bl, ws).ok


def test_config_drift_fails(tmp_path):
    root, bl, ws = _toy_repo(tmp_path, code="X = 1\n")
    engine.write_baseline(bl, {}, "0" * 64)
    res = _run(root, bl, ws)
    assert not res.ok and any("config drift" in f for f in res.failures)


def test_max_violations_env_is_the_default_budget(tmp_path, monkeypatch):
    root, bl, ws = _toy_repo(tmp_path)
    engine.write_baseline(bl, {}, rules.config_fingerprint())
    monkeypatch.setenv("MAX_LINT_VIOLATIONS", "5")
    assert engine.run_analysis(root, baseline_path=bl,
                               wire_schema_path=ws).ok
    monkeypatch.setenv("MAX_LINT_VIOLATIONS", "0")
    assert not engine.run_analysis(root, baseline_path=bl,
                                   wire_schema_path=ws).ok


def test_json_report_schema(tmp_path):
    root, bl, ws = _toy_repo(tmp_path)
    engine.write_baseline(bl, {"RA01:src/repro/serve/clock.py": 1},
                          rules.config_fingerprint())
    js = _run(root, bl, ws).to_json()
    assert js["schema"] == "repro-analysis/1"
    assert js["ok"] is True and js["failures"] == []
    assert js["files_scanned"] == 1
    assert js["counts_by_rule"] == {"RA01": 1}
    assert js["counts_by_key"] == {"RA01:src/repro/serve/clock.py": 1}
    (v,) = js["violations"]
    assert set(v) == {"rule", "path", "line", "col", "message",
                      "suppressed", "reason"}
    json.loads(json.dumps(js))               # round-trips as plain JSON


# ---------------------------------------------------------------------------
# RA04 — wire fingerprints on the real modules
# ---------------------------------------------------------------------------

_WIRE_FILES = ("src/repro/core/codec.py", "src/repro/codec/container.py",
               "src/repro/session/codec.py", "src/repro/pipeline/op.py")


def _wire_tree(tmp_path):
    root = tmp_path / "wiretree"
    for rel in _WIRE_FILES:
        dst = root / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copyfile(REPO / rel, dst)
    schema = root / "wire_schema.json"
    shutil.copyfile(REPO / "src/repro/analysis/wire_schema.json", schema)
    return root, schema


def test_committed_wire_schema_matches_the_tree():
    committed = json.loads(
        (REPO / "src/repro/analysis/wire_schema.json").read_text())
    assert wire.build_wire_schema(str(REPO)) == committed


def test_wire_clean_tree_passes(tmp_path):
    root, schema = _wire_tree(tmp_path)
    vs, summary = wire.check_wire_schema(str(root), str(schema))
    assert vs == []
    assert {f: s["status"] for f, s in summary.items()} \
        == {"BaF2": "ok", "RTC1": "ok", "SSF1": "ok"}


def test_wire_layout_edit_without_bump_fails(tmp_path):
    root, schema = _wire_tree(tmp_path)
    codec = root / "src/repro/session/codec.py"
    src = codec.read_text()
    assert '"<4sBBBBIIII"' in src
    codec.write_text(src.replace('"<4sBBBBIIII"', '"<4sBBBBIIIIH"'))
    vs, summary = wire.check_wire_schema(str(root), str(schema))
    assert summary["SSF1"]["status"] == "layout-changed-no-bump"
    assert any("without a codec_revision() bump" in v.message for v in vs)
    assert all(v.rule == "RA04" for v in vs)


def test_wire_bump_needs_regenerated_fingerprints(tmp_path):
    root, schema = _wire_tree(tmp_path)
    codec = root / "src/repro/session/codec.py"
    codec.write_text(codec.read_text().replace(
        '"<4sBBBBIIII"', '"<4sBBBBIIIIH"'))
    op = root / "src/repro/pipeline/op.py"
    op.write_text(op.read_text().replace(
        "SESSION_WIRE_VERSION = 1", "SESSION_WIRE_VERSION = 2"))
    vs, summary = wire.check_wire_schema(str(root), str(schema))
    assert summary["SSF1"]["status"] == "stale-fingerprint"
    assert any("stale wire_schema.json" in v.message for v in vs)
    # regenerating the fingerprints next to the bump makes the pass green
    wire.write_wire_schema(str(root), str(schema))
    vs2, summary2 = wire.check_wire_schema(str(root), str(schema))
    assert vs2 == [] and summary2["SSF1"]["status"] == "ok"
    assert "SESSION_WIRE_VERSION=2" in summary2["SSF1"]["revision"]


def test_wire_registered_family_cannot_silently_vanish(tmp_path):
    root, schema = _wire_tree(tmp_path)
    (root / "src/repro/session/codec.py").unlink()
    vs, summary = wire.check_wire_schema(str(root), str(schema))
    assert summary["SSF1"]["status"] == "registered-but-absent"
    assert any("module(s) are gone" in v.message for v in vs)


def test_wire_absent_families_skip_on_toy_trees(tmp_path):
    root = _tree(tmp_path, {"src/repro/serve/x.py": "X = 1\n"})
    ws = os.path.join(root, "ws.json")
    schema = wire.write_wire_schema(root, ws)
    assert schema["families"] == {}
    vs, summary = wire.check_wire_schema(root, ws)
    assert vs == []
    assert all(s["status"] == "absent" for s in summary.values())


# ---------------------------------------------------------------------------
# Negative control: a seeded wall clock in the real gateway must fail
# ---------------------------------------------------------------------------

def _seeded_gateway_tree(tmp_path):
    root = tmp_path / "seeded"
    rel = "src/repro/serve/gateway.py"
    dst = root / rel
    dst.parent.mkdir(parents=True, exist_ok=True)
    lines = (REPO / rel).read_text().splitlines(keepends=True)
    i = next(n for n, line in enumerate(lines)
             if line.strip() == "while events:")
    indent = " " * (len(lines[i]) - len(lines[i].lstrip()))
    lines.insert(i, indent + "_wall = time.time()\n")
    dst.write_text("".join(lines))
    bl = root / "baseline.json"
    ws = root / "wire_schema.json"
    wire.write_wire_schema(str(root), str(ws))
    engine.write_baseline(str(bl), {}, rules.config_fingerprint())
    return root, bl, ws


def test_seeded_wall_clock_in_gateway_event_loop_fails(tmp_path):
    root, bl, ws = _seeded_gateway_tree(tmp_path)
    res = engine.run_analysis(str(root), baseline_path=str(bl),
                              wire_schema_path=str(ws), max_violations=0)
    assert not res.ok
    leaks = [v for v in res.unsuppressed()
             if v.rule == "RA01" and "time.time" in v.message]
    assert len(leaks) == 1
    # the gateway's own pragma'd perf_counter warm-timing sites stay quiet
    assert all("perf_counter" not in v.message for v in leaks)
    assert any("ratchet regression" in f and "RA01" in f
               for f in res.failures)


def test_cli_check_fails_on_seeded_tree(tmp_path):
    root, bl, ws = _seeded_gateway_tree(tmp_path)
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               MAX_LINT_VIOLATIONS="0")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--check",
         "--root", str(root), "--baseline", str(bl),
         "--wire-schema", str(ws)],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 1
    assert "CHECK FAILED" in proc.stderr
    assert "RA01" in proc.stdout + proc.stderr


def test_full_repo_check_passes():
    """The committed tree itself is clean: zero unsuppressed violations,
    every suppression reasoned, wire fingerprints current."""
    res = engine.run_analysis(str(REPO), max_violations=0)
    assert res.failures == []
    assert res.unsuppressed() == []
    assert all(v.reason for v in res.violations if v.suppressed)


# ---------------------------------------------------------------------------
# Autofixer
# ---------------------------------------------------------------------------

def test_fix_bare_except_with_real_body():
    src = "try:\n    a()\nexcept:\n    log()\n"
    fixed, applied = fixes.fix_source(src)
    assert "except Exception:" in fixed
    assert [f.rule for f in applied] == ["RA06"]
    # a *silent* bare except is a human decision, never autofixed
    silent = "try:\n    a()\nexcept:\n    pass\n"
    assert fixes.fix_source(silent) == (silent, [])


def test_fix_randomstate_to_default_rng():
    src = "import numpy as np\nr = np.random.RandomState(3)\n"
    fixed, applied = fixes.fix_source(src)
    assert "np.random.default_rng(3)" in fixed
    assert applied and applied[0].rule == "RA02"


def test_fix_seeded_global_api_rewrites_onto_generator():
    src = textwrap.dedent("""\
        import numpy as np

        np.random.seed(7)
        x = np.random.rand(3, 4)
        y = np.random.randn(2)
        i = np.random.randint(0, 9)
    """)
    fixed, applied = fixes.fix_source(src)
    assert "rng = np.random.default_rng(7)" in fixed
    assert "rng.random((3, 4))" in fixed
    assert "rng.standard_normal((2,))" in fixed
    assert "rng.integers(0, 9)" in fixed
    # the rewrite executes and keeps the legacy calling conventions
    ns = {}
    exec(fixed, ns)
    assert ns["x"].shape == (3, 4) and ns["y"].shape == (2,)
    assert 0 <= ns["i"] < 9
    # idempotent: a second --fix is a no-op
    assert fixes.fix_source(fixed) == (fixed, [])


def test_fix_output_is_ra02_clean(tmp_path):
    src = "import numpy as np\n\nnp.random.seed(1)\nx = np.random.rand(3)\n"
    fixed, _ = fixes.fix_source(src)
    assert not _violations(tmp_path, "src/repro/models/x.py", fixed, "RA02")


def test_fix_leaves_unseeded_legacy_for_a_human():
    src = "import numpy as np\nx = np.random.rand(3)\n"
    assert fixes.fix_source(src) == (src, [])


# ---------------------------------------------------------------------------
# Replay sanitizer (unit level; the SessionManager wiring lives in
# tests/test_session.py next to the gateway fixtures)
# ---------------------------------------------------------------------------

def test_sanitizer_blocks_wall_clock_and_global_rng():
    with replay_sanitizer():
        with pytest.raises(ReplaySanitizerError, match="virtual clock"):
            time.time()
        with pytest.raises(ReplaySanitizerError, match="Generator"):
            np.random.rand(2)  # repro: allow[RA02] -- asserts the sanitizer blocks exactly this call
        with pytest.raises(ReplaySanitizerError, match="Generator"):
            random.random()  # repro: allow[RA02] -- asserts the sanitizer blocks exactly this call
        # the sanctioned APIs keep working mid-replay
        assert time.perf_counter() > 0
        assert np.random.default_rng(0).random() == \
            np.random.default_rng(0).random()
        assert random.Random(0).random() == random.Random(0).random()
    # everything restored on exit
    assert time.time() > 0
    assert np.random.rand(2).shape == (2,)  # repro: allow[RA02] -- proves the patch was restored


def test_sanitizer_strict_forbids_perf_counter_too():
    with replay_sanitizer(strict=True):
        with pytest.raises(ReplaySanitizerError):
            time.perf_counter()
    assert time.perf_counter() > 0


def test_sanitizer_restores_after_an_exception():
    with pytest.raises(ValueError):
        with replay_sanitizer():
            raise ValueError("boom")
    assert time.time() > 0
