"""MeshExecutor (the sharded cloud tier), CalibratedCostModel, and gateway
federation.

The bit-identity tests compare batch shapes within one XLA CPU float
equivalence class (per-row results are bit-identical within {1, 2, 4} and
within {8, 16, 32, 64} on the host backend); the gateway tests use full
64-row buckets so serial and per-shard shapes land in the same class for
any device count up to 8. CI runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on a single device
the mesh degenerates to (data=1, model=1) and still must agree.
"""
import math
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.configs.yolo_baf import smoke_config, smoke_data_config
from repro.core.baf import BaFConvConfig, init_baf_conv
from repro.data.synthetic import shapes_batch_iterator
from repro.launch.mesh import make_dev_mesh
from repro.models.cnn import init_cnn
from repro.serve import (CalibratedCostModel, GatewayFederation,
                         LinearCostModel, MeshExecutor, MultiTenantGateway,
                         OperatingPoint, QueueDepthAdmission, RequestShed,
                         SerialExecutor, ServingGateway, TenantRequest,
                         TenantSpec, seed_cost_from_hlo, serve_federated)

N_DEV = len(jax.devices())
too_many_devices = pytest.mark.skipif(
    N_DEV > 8, reason="batch-shape float classes validated for <= 8 devices")


# ---------------------------------------------------------------------------
# make_dev_mesh axis preference
# ---------------------------------------------------------------------------

def test_make_dev_mesh_data_preference():
    m = make_dev_mesh(prefer="data")
    assert m.shape["data"] == N_DEV
    assert m.shape["model"] == 1


def test_make_dev_mesh_default_shape_unchanged():
    m = make_dev_mesh()
    model = next(f for f in (4, 2, 1) if N_DEV % f == 0)
    assert dict(m.shape) == {"data": N_DEV // model, "model": model}


def test_make_dev_mesh_rejects_unknown_preference():
    with pytest.raises(ValueError, match="prefer"):
        make_dev_mesh(prefer="pod")


# ---------------------------------------------------------------------------
# CalibratedCostModel: calibrate -> freeze -> replay
# ---------------------------------------------------------------------------

def _b(n):
    return SimpleNamespace(padded_size=n, key=None)


def test_calibrating_model_passes_through_and_records():
    m = CalibratedCostModel()
    assert m.duration_s(_b(4), 0.125) == 0.125
    assert m.samples == [(4, 0.125)]
    assert not m.frozen


def test_freeze_fits_exact_affine():
    m = CalibratedCostModel()
    for n in (1, 2, 4, 8, 16):
        m.observe(n, 0.007 + 0.003 * n)
    m.freeze()
    assert m.base_s == pytest.approx(0.007)
    assert m.per_item_s == pytest.approx(0.003)
    assert m.fit_rel_err() == pytest.approx(0.0, abs=1e-9)
    # frozen: pure function of padded_size, measured wall is ignored
    assert m.duration_s(_b(10), 123.0) == pytest.approx(0.037)
    assert m.duration_s(_b(10), 456.0) == m.duration_s(_b(10), 0.0)


def test_freeze_is_idempotent_and_locks_observation():
    m = CalibratedCostModel()
    m.observe(4, 0.01)
    assert m.freeze() is m
    m.freeze()
    with pytest.raises(RuntimeError):
        m.observe(4, 0.01)
    n_samples = len(m.samples)
    m.duration_s(_b(4), 0.5)           # predicts, must not record
    assert len(m.samples) == n_samples


def test_degenerate_single_size_keeps_seed_slope():
    m = CalibratedCostModel(seed_per_item_s=0.001)
    for wall in (0.018, 0.020, 0.022):
        m.observe(8, wall)
    m.freeze()
    assert m.per_item_s == 0.001
    assert m.base_s == pytest.approx(0.020 - 0.008)


def test_fit_clamps_negative_slope():
    m = CalibratedCostModel()
    m.observe(1, 0.02)
    m.observe(16, 0.01)                # decreasing: slope would be negative
    m.freeze()
    assert m.per_item_s == 0.0
    assert m.base_s >= 0.0


def test_freeze_without_samples_keeps_seeds():
    m = CalibratedCostModel(seed_base_s=0.005, seed_per_item_s=0.002)
    m.freeze()
    assert (m.base_s, m.per_item_s) == (0.005, 0.002)


def test_negative_seeds_rejected():
    with pytest.raises(ValueError):
        CalibratedCostModel(seed_base_s=-1.0)


# ---------------------------------------------------------------------------
# MeshExecutor: construction + per-shard virtual clock
# ---------------------------------------------------------------------------

def test_mesh_executor_refuses_unfrozen_calibration():
    with pytest.raises(ValueError, match="frozen"):
        MeshExecutor(cost=CalibratedCostModel())


def test_mesh_executor_requires_data_axis():
    mesh = jax.make_mesh((1, 1), ("pod", "model"))
    with pytest.raises(ValueError, match="data"):
        MeshExecutor(mesh=mesh)


def test_plan_duration_is_per_shard():
    cal = CalibratedCostModel(seed_base_s=0.005, seed_per_item_s=0.001)
    ex = MeshExecutor(cost=cal.freeze(), overhead_s=0.002)
    n = ex.n_data
    assert n == N_DEV
    assert ex.shard_rows(1) == 1
    assert ex.shard_rows(64) == math.ceil(64 / n)
    want = 0.002 + 0.005 + 0.001 * math.ceil(64 / n)
    assert ex._plan_duration(_b(64), 999.0) == pytest.approx(want)


def test_run_sharded_refuses_weightless_plan():
    ex = MeshExecutor(cost=LinearCostModel())
    plan = SimpleNamespace(spec=SimpleNamespace(params=None, baf_params=None))
    with pytest.raises(ValueError, match="weights"):
        ex.run_sharded(plan, None, 4)


# ---------------------------------------------------------------------------
# sharded compute: bit-identical to the serial path
# ---------------------------------------------------------------------------

C = 8
OP = OperatingPoint(c=C, bits=8)


@pytest.fixture(scope="module")
def system():
    cnn_cfg = smoke_config()._replace(input_size=32)
    params = init_cnn(jax.random.PRNGKey(0), cnn_cfg)
    baf = init_baf_conv(jax.random.PRNGKey(1),
                        BaFConvConfig(c=C, q=cnn_cfg.split_q, hidden=8))
    return params, {C: (baf, np.arange(C))}


@pytest.fixture(scope="module")
def imgs():
    data_cfg = smoke_data_config()._replace(image_size=32, batch_size=8)
    it = shapes_batch_iterator(data_cfg, seed=123)
    rows = []
    while len(rows) < 16:
        img, _ = next(it)
        rows.append(np.asarray(img))
    return np.concatenate(rows, axis=0)[:16]


@too_many_devices
@pytest.mark.parametrize("target", [4, 64])
def test_run_sharded_bit_identical_to_serial(system, imgs, target):
    """restore + cloud forward through the shard_map program returns the
    same logits, bit for bit, as the serial separate-jit path at the same
    bucket size (same float class on both sides)."""
    params, bank = system
    gw = ServingGateway(params, bank, default_op=OP, max_batch=64)
    plan = gw.plan_for(gw.default_op)
    blobs = [gw.encode_request(imgs[i % len(imgs)][None])[1]
             for i in range(min(target, 8))]
    decoded = plan.decode_batch(blobs)

    serial = np.asarray(jax.block_until_ready(
        gw._cloud_fn(params, plan.restore(decoded.pad_to(target)))))
    ex = MeshExecutor(cost=LinearCostModel())
    sharded = ex.run_sharded(plan, decoded, target)
    assert sharded.shape == (target,) + serial.shape[1:]
    assert np.array_equal(sharded, serial[:target])
    # program cache: one compile per (plan, padded shape)
    assert len(ex._fns) == 1
    ex.run_sharded(plan, decoded, target)
    assert len(ex._fns) == 1


def test_seed_cost_from_hlo_positive(system):
    params, bank = system
    gw = ServingGateway(params, bank, default_op=OP, max_batch=8)
    plan = gw.plan_for(gw.default_op)
    m = seed_cost_from_hlo(plan, (4, 4, 4, C))
    assert isinstance(m, CalibratedCostModel)
    assert not m.frozen
    assert m.seed_per_item_s > 0.0
    # the roofline seed carries an otherwise-degenerate single-size fit
    m.observe(8, 0.02)
    m.freeze()
    assert m.per_item_s == m.seed_per_item_s


# ---------------------------------------------------------------------------
# gateway federation on the shared mesh
# ---------------------------------------------------------------------------

def _mk_gateway(system, executor, *, seed, n_tenants=8, admission=None,
                max_batch=64):
    params, bank = system
    tenants = [TenantSpec(name=f"g{seed}t{i}") for i in range(n_tenants)]
    return MultiTenantGateway(params, bank, tenants=tenants, default_op=OP,
                              max_batch=max_batch, batch_window_s=None,
                              executor=executor, shared_executor=True,
                              seed=seed, admission=admission)


def _workload(gw, imgs, per_tenant, *, dt=1e-4):
    reqs = []
    names = sorted(gw.specs)
    for r in range(per_tenant):
        for i, name in enumerate(names):
            k = r * len(names) + i
            reqs.append(TenantRequest(tenant=name,
                                      img=imgs[k % len(imgs)][None],
                                      t_submit=k * dt))
    return reqs


def _frozen_cal():
    return CalibratedCostModel(seed_base_s=2e-3, seed_per_item_s=1e-4).freeze()


def _logit_rows(outcomes):
    return {t: [np.asarray(r.logits) for r in rs]
            for t, rs in outcomes.items()}


@too_many_devices
def test_federated_mesh_bit_identical_to_serial_and_replays(system, imgs):
    """Two federated gateways (8 tenants each, one full 64-bucket per
    gateway) served from the mesh return logits bit-identical to the same
    federation on a SerialExecutor; under the shared frozen cost model the
    mesh run replays bit for bit (logits and telemetry)."""
    cal = _frozen_cal()

    ser = SerialExecutor(cost=cal)
    gws_s = [_mk_gateway(system, ser, seed=g) for g in range(2)]
    wls = [_workload(gw, imgs, 8) for gw in gws_s]
    got_s = GatewayFederation(gws_s).serve(wls)

    mesh_ex = MeshExecutor(make_dev_mesh(prefer="data"), cost=cal)
    gws_m = [_mk_gateway(system, mesh_ex, seed=g) for g in range(2)]
    fed_m = GatewayFederation(gws_m)
    got_m = fed_m.serve(wls)

    for (out_s, tel_s), (out_m, tel_m) in zip(got_s, got_m):
        assert not tel_s.shed and not tel_m.shed
        rows_s, rows_m = _logit_rows(out_s), _logit_rows(out_m)
        assert rows_s.keys() == rows_m.keys()
        for t in rows_s:
            assert len(rows_s[t]) == 8
            for a, b in zip(rows_s[t], rows_m[t]):
                assert np.array_equal(a, b)
        # same virtual clock: the frozen model prices a 64-bucket the same
        # serial and sharded (per-shard rows at per-shard cost is the mesh's
        # *speedup*, visible in exec history, not in request outcomes)
        assert [r.tenant for r in tel_s.records] == \
               [r.tenant for r in tel_m.records]

    got_m2 = fed_m.serve(wls)
    for (out_1, tel_1), (out_2, tel_2) in zip(got_m, got_m2):
        assert tel_1.records == tel_2.records
        rows_1, rows_2 = _logit_rows(out_1), _logit_rows(out_2)
        for t in rows_1:
            for a, b in zip(rows_1[t], rows_2[t]):
                assert np.array_equal(a, b)

    # mesh virtual service time per 64-bucket is the per-shard prediction
    n = mesh_ex.n_data
    for tk in mesh_ex.history:
        assert (tk.t_done - tk.t_start) == pytest.approx(
            cal.predict(math.ceil(64 / n)))
    assert fed_m.depth() == 0


def test_serve_federated_rejects_disjoint_executors(system):
    gw1 = _mk_gateway(system, SerialExecutor(cost=LinearCostModel()), seed=0)
    gw2 = _mk_gateway(system, SerialExecutor(cost=LinearCostModel()), seed=1)
    with pytest.raises(ValueError, match="share one executor"):
        serve_federated([(gw1, []), (gw2, [])])


def test_serve_federated_rejects_duplicate_gateway(system):
    gw = _mk_gateway(system, SerialExecutor(cost=LinearCostModel()), seed=0)
    with pytest.raises(ValueError, match="once per federation"):
        serve_federated([(gw, []), (gw, [])])


def test_federation_requires_shared_flag(system):
    params, bank = system
    ex = SerialExecutor(cost=LinearCostModel())
    gw1 = _mk_gateway(system, ex, seed=0)
    gw2 = MultiTenantGateway(params, bank,
                             tenants=[TenantSpec(name="solo")],
                             default_op=OP, executor=ex)   # exclusive owner
    with pytest.raises(ValueError, match="shared_executor=True"):
        GatewayFederation([gw1, gw2])


def test_exclusive_executor_cannot_be_bound_twice(system):
    params, bank = system
    ex = SerialExecutor(cost=LinearCostModel())
    MultiTenantGateway(params, bank, tenants=[TenantSpec(name="a")],
                       default_op=OP, executor=ex)
    with pytest.raises(ValueError, match="already bound"):
        MultiTenantGateway(params, bank, tenants=[TenantSpec(name="b")],
                           default_op=OP, executor=ex)


def test_shared_depth_sheds_across_gateways(system, imgs):
    """One gateway's burst fills the shared executor; the *other* gateway's
    queue-depth admission reads that shared backlog and sheds, even though
    its own traffic is tiny."""
    ex = SerialExecutor(cost=LinearCostModel(base_s=0.5, per_item_s=0.01))
    gw_burst = _mk_gateway(system, ex, seed=0, n_tenants=1, max_batch=1)
    gw_meek = _mk_gateway(system, ex, seed=1, n_tenants=1, max_batch=1,
                          admission=QueueDepthAdmission(1))
    wl_burst = [TenantRequest(tenant="g0t0", img=imgs[i][None],
                              t_submit=0.001 * i) for i in range(4)]
    wl_meek = [TenantRequest(tenant="g1t0", img=imgs[i][None],
                             t_submit=0.25 + 0.001 * i) for i in range(2)]
    (out_b, tel_b), (out_m, tel_m) = GatewayFederation(
        [gw_burst, gw_meek]).serve([wl_burst, wl_meek])

    assert not tel_b.shed
    assert len(tel_m.shed) == 2
    assert all(isinstance(r, RequestShed) for r in out_m["g1t0"])
    assert all("queue-depth" in r.reason for r in out_m["g1t0"])
    # nothing silently dropped on either side
    assert len(out_b["g0t0"]) == 4
    assert all(not r.shed for r in out_b["g0t0"])
