"""Wire codec: n-bit packing, entropy coding, paper-style bit accounting."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import codec as wire
from repro.core.quant import QuantParams


def _qp(c, bits, rng):
    mins = rng.normal(size=(c,)).astype(np.float16)
    return QuantParams(mins=mins, maxs=(mins + 1).astype(np.float16), bits=bits)


@given(bits=st.integers(2, 8), n=st.integers(1, 300), seed=st.integers(0, 2**16))
@settings(max_examples=40, deadline=None)
def test_property_pack_unpack_roundtrip(bits, n, seed):
    r = np.random.default_rng(seed)
    codes = r.integers(0, 1 << bits, size=n).astype(np.uint8)
    assert np.array_equal(wire.unpack_bits(wire.pack_bits(codes, bits), bits, n),
                          codes)


def test_packed_size_is_exact():
    codes = np.zeros(100, np.uint8)
    for bits in range(2, 9):
        assert len(wire.pack_bits(codes, bits)) == (100 * bits + 7) // 8


@pytest.mark.parametrize("backend", ["zlib", "raw"])
@pytest.mark.parametrize("bits", [2, 5, 8])
def test_encode_decode_roundtrip(rng, backend, bits):
    codes = rng.integers(0, 1 << bits, size=(6, 6, 8)).astype(np.uint8)
    qp = _qp(8, bits, rng)
    enc = wire.encode(codes, qp, backend=backend)
    blob = enc.to_bytes()
    dec_codes, dec_qp = wire.decode(wire.EncodedTensor.from_bytes(blob))
    assert np.array_equal(dec_codes, codes)
    assert np.array_equal(dec_qp.mins, np.asarray(qp.mins))
    assert dec_qp.bits == bits


def test_side_info_accounting(rng):
    codes = rng.integers(0, 256, size=(4, 4, 16)).astype(np.uint8)
    qp = _qp(16, 8, rng)
    enc = wire.encode(codes, qp, backend="raw")
    # paper: C*32 bits of fp16 min/max side info + payload
    assert enc.total_bits() == 8 * len(enc.payload) + 16 * 32


def test_zlib_beats_raw_on_structured_data(rng):
    # low-entropy stream (mostly zeros) must compress
    codes = (rng.random(size=(64, 64)) < 0.05).astype(np.uint8) * 7
    qp = _qp(1, 8, rng)
    z = wire.encode(codes, qp, backend="zlib")
    raw = wire.encode(codes, qp, backend="raw")
    assert len(z.payload) < 0.5 * len(raw.payload)


def test_entropy_floor_below_payload(rng):
    codes = (rng.random(size=(64, 64)) < 0.1).astype(np.uint8)
    h = wire.empirical_entropy_bits(codes, 8)
    raw_bits = codes.size * 8
    assert 0 < h < raw_bits


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_property_entropy_is_compression_lower_bound_ish(seed):
    """DEFLATE payload should be within ~2x of the order-0 entropy floor for
    iid streams (sanity on the accounting, not a codec guarantee)."""
    r = np.random.default_rng(seed)
    codes = r.integers(0, 4, size=4096).astype(np.uint8)
    qp = QuantParams(mins=np.zeros(1, np.float16), maxs=np.ones(1, np.float16),
                     bits=2)
    enc = wire.encode(codes, qp, backend="zlib")
    h = wire.empirical_entropy_bits(codes, 2)
    assert 8 * len(enc.payload) >= 0.5 * h


# ---------------------------------------------------------------------------
# Hardening + header integrity (serving-gateway PR satellites)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["zlib", "raw"])
@pytest.mark.parametrize("bits", [3, 5, 6])
def test_roundtrip_odd_bit_widths(rng, backend, bits):
    codes = rng.integers(0, 1 << bits, size=(5, 7, 4)).astype(np.uint8)
    qp = _qp(4, bits, rng)
    dec, dec_qp = wire.decode(wire.EncodedTensor.from_bytes(
        wire.encode(codes, qp, backend=backend).to_bytes()))
    assert np.array_equal(dec, codes)
    assert dec_qp.bits == bits


@pytest.mark.parametrize("backend", ["zlib", "raw"])
def test_roundtrip_single_element(rng, backend):
    codes = np.asarray([[3]], np.uint8)
    qp = _qp(1, 4, rng)
    dec, _ = wire.decode(wire.encode(codes, qp, backend=backend))
    assert dec.shape == (1, 1) and dec[0, 0] == 3


def test_header_integrity_multidim(rng):
    shape = (2, 3, 4, 5)
    codes = rng.integers(0, 64, size=shape).astype(np.uint8)
    qp = _qp(5, 6, rng)
    enc = wire.encode(codes, qp, backend="raw")
    enc2 = wire.EncodedTensor.from_bytes(enc.to_bytes())
    assert enc2.shape == shape
    assert enc2.bits == 6 and enc2.backend == "raw"
    assert enc2.side_info == enc.side_info
    assert enc2.payload == enc.payload
    dec, _ = wire.decode(enc2)
    assert dec.shape == shape and np.array_equal(dec, codes)


@pytest.mark.parametrize("bits", [4, 8, 16])
def test_unpack_bits_rejects_short_stream(bits):
    codes = np.arange(16, dtype=np.uint16) % (1 << min(bits, 8))
    data = wire.pack_bits(codes, bits)
    with pytest.raises(ValueError, match="too short"):
        wire.unpack_bits(data[:-1], bits, 16)


def test_png_rejects_negative_codes(rng):
    qp = _qp(4, 8, rng)
    with pytest.raises(ValueError, match="negative"):
        wire.encode(np.full((4, 4), -1, np.int32), qp, backend="png")


def test_png_rejects_codes_over_8_bits(rng):
    qp = _qp(4, 8, rng)
    with pytest.raises(ValueError, match="fit in"):
        wire.encode(np.full((4, 4), 300, np.int32), qp, backend="png")


def test_png_roundtrip_still_works(rng):
    codes = rng.integers(0, 256, size=(8, 8)).astype(np.uint8)
    qp = _qp(8, 8, rng)
    enc = wire.encode(codes, qp, backend="png")
    dec, _ = wire.decode(wire.EncodedTensor.from_bytes(enc.to_bytes()))
    assert np.array_equal(dec.reshape(8, 8), codes)


# ---------------------------------------------------------------------------
# from_bytes structural hardening (entropy-coding PR satellite): every
# malformation fails loudly at the header with its own message, instead of
# surfacing later as a short stream inside unpack_bits
# ---------------------------------------------------------------------------

def _blob(rng, backend="raw"):
    codes = rng.integers(0, 64, size=(4, 6)).astype(np.uint8)
    return wire.encode(codes, _qp(6, 6, rng), backend=backend).to_bytes()


def test_from_bytes_rejects_bad_magic(rng):
    blob = _blob(rng)
    with pytest.raises(ValueError, match="bad magic"):
        wire.EncodedTensor.from_bytes(b"NOPE" + blob[4:])


def test_from_bytes_rejects_old_wire_version(rng):
    blob = _blob(rng)
    with pytest.raises(ValueError, match="unsupported wire-format version"):
        wire.EncodedTensor.from_bytes(b"BaF1" + blob[4:])


def test_from_bytes_rejects_truncated_header(rng):
    blob = _blob(rng)
    with pytest.raises(ValueError, match="truncated wire header"):
        wire.EncodedTensor.from_bytes(blob[:5])
    with pytest.raises(ValueError, match="truncated wire header"):
        wire.EncodedTensor.from_bytes(blob[:9])       # mid-shape


def test_from_bytes_rejects_truncated_side_info(rng):
    blob = _blob(rng)
    hdr = 7 + 4 * 2 + 8
    with pytest.raises(ValueError, match="truncated side info"):
        wire.EncodedTensor.from_bytes(blob[:hdr + 3])


def test_from_bytes_rejects_truncated_payload(rng):
    blob = _blob(rng)
    with pytest.raises(ValueError, match="truncated payload"):
        wire.EncodedTensor.from_bytes(blob[:-1])


def test_from_bytes_rejects_trailing_garbage(rng):
    blob = _blob(rng)
    with pytest.raises(ValueError, match="trailing garbage"):
        wire.EncodedTensor.from_bytes(blob + b"\x00")


def test_from_bytes_rejects_unknown_backend_id(rng):
    blob = bytearray(_blob(rng))
    blob[4] = 250
    with pytest.raises(ValueError, match="unknown backend id"):
        wire.EncodedTensor.from_bytes(bytes(blob))


def test_backend_registry_lists_rans(rng):
    names = wire.backend_names()
    for name in ("raw", "zlib", "png", "rans", "rans-ctx"):
        assert name in names
    with pytest.raises(ValueError, match="unknown backend"):
        wire.encode(np.zeros((2, 2), np.uint8), _qp(2, 8, rng),
                    backend="flif")
