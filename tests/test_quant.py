"""Paper eqs. (4)-(5): per-channel uniform scalar quantization."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.quant import (bin_bounds, compute_quant_params, dequantize,
                              quantization_mse, quantize)


@pytest.mark.parametrize("bits", [2, 3, 4, 5, 6, 7, 8])
def test_roundtrip_error_bounded_by_half_step(rng, bits):
    x = jnp.asarray(rng.normal(size=(4, 16, 16, 8)).astype(np.float32)) * 10
    qp = compute_quant_params(x, bits)
    x_hat = dequantize(quantize(x, qp), qp)
    step = np.asarray(qp.step())
    err = np.abs(np.asarray(x_hat - x))
    # fp16 side-info rounding slightly perturbs the grid; 0.51*step + eps margin
    assert (err <= 0.51 * step + 1e-4).all()


@pytest.mark.parametrize("bits", [2, 8])
@pytest.mark.parametrize("per_example", [False, True])
def test_codes_in_range(rng, bits, per_example):
    x = jnp.asarray(rng.normal(size=(3, 8, 8, 4)).astype(np.float32)) * 100
    qp = compute_quant_params(x, bits, per_example=per_example)
    codes = np.asarray(quantize(x, qp))
    assert codes.min() >= 0 and codes.max() <= (1 << bits) - 1


def test_fp16_side_info_never_overflows_top_code(rng):
    # adversarial: values exactly at a max that fp16 rounds *down*
    x = jnp.asarray(np.full((1, 4, 4, 2), 2049.3, np.float32))  # 2049.3 -> fp16 2050? varies
    x = x.at[0, 0, 0, 0].set(-1.0)
    qp = compute_quant_params(x, 8)
    codes = np.asarray(quantize(x, qp))
    assert codes.max() <= 255


def test_per_example_side_info_shapes(rng):
    x = jnp.asarray(rng.normal(size=(5, 8, 8, 16)).astype(np.float32))
    qp = compute_quant_params(x, 8, per_example=True)
    assert qp.mins.shape == (5, 1, 1, 16)
    assert qp.side_info_bits() == 5 * 16 * 32  # paper: C*32 bits per example


def test_mse_decreases_with_bits(rng):
    x = jnp.asarray(rng.normal(size=(2, 16, 16, 8)).astype(np.float32))
    mses = [float(quantization_mse(x, b)) for b in (2, 4, 6, 8)]
    assert mses == sorted(mses, reverse=True)
    assert mses[-1] < mses[0] / 100


@given(bits=st.integers(2, 8), seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_property_dequantized_value_in_own_bin(bits, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(2, 8, 4)).astype(np.float32) * r.uniform(0.1, 50))
    qp = compute_quant_params(x, bits)
    codes = quantize(x, qp)
    lo, hi = bin_bounds(codes, qp)
    xh = dequantize(codes, qp)
    # eq. (5) reconstruction sits inside the eq.-(6) bin bounds of its code
    assert bool(jnp.all(xh >= lo - 1e-4)) and bool(jnp.all(xh <= hi + 1e-4))


@given(seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_property_constant_channel_is_lossless(seed):
    r = np.random.default_rng(seed)
    const = np.float16(r.normal())  # fp16-representable so side info is exact
    x = jnp.full((1, 8, 8, 3), float(const), jnp.float32)
    qp = compute_quant_params(x, 8)
    xh = dequantize(quantize(x, qp), qp)
    assert np.allclose(np.asarray(xh), np.asarray(x), atol=2e-3)
