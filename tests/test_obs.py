"""Metrics registry, log-bucket histograms, instrumentation hooks, and the
Telemetry rebuild on top of them (repro.obs.metrics / repro.obs.hooks /
repro.serve.telemetry)."""
import math

import numpy as np
import pytest

from repro.obs import GROWTH, Counter, Gauge, LogHistogram, MetricsRegistry
from repro.obs import hooks
from repro.serve.telemetry import RequestRecord, ShedRecord, Telemetry


def _rec(i, *, tenant="", latency=None, compute=0.002, queue=0.001,
         wire=0.004, sched=0.0, bits=1000):
    if latency is not None:
        # place the whole latency in compute so total_latency_s == latency
        compute, queue, wire, sched = latency, 0.0, 0.0, 0.0
    return RequestRecord(req_id=i, c=8, bits=8, bits_on_wire=bits,
                         wire_latency_s=wire, queue_wait_s=queue,
                         compute_s=compute, batch_size=1, padded_size=1,
                         tenant=tenant, sched_wait_s=sched)


# ---------------------------------------------------------------------------
# counters / gauges / registry
# ---------------------------------------------------------------------------

def test_counter_monotone():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match=">= 0"):
        c.inc(-1)


def test_gauge_set_and_inc():
    g = Gauge()
    g.set(7)
    g.inc(-2)
    assert g.value == 5.0


def test_registry_series_identity_and_labels():
    m = MetricsRegistry()
    a = m.counter("reqs", tenant="a")
    assert m.counter("reqs", tenant="a") is a          # get-or-create
    assert m.counter("reqs", tenant="b") is not a      # labels split series
    # label order must not matter for series identity
    h1 = m.histogram("h", x="1", y="2")
    h2 = m.histogram("h", y="2", x="1")
    assert h1 is h2
    assert m.get("reqs", tenant="a") is a
    assert m.get("nope") is None                       # never creates
    assert len(m) == 3


def test_registry_kind_conflict():
    m = MetricsRegistry()
    m.counter("x")
    with pytest.raises(TypeError, match="already registered"):
        m.gauge("x")


def test_registry_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("c").inc(2)
    b.counter("c").inc(3)
    a.gauge("g").set(1)
    b.gauge("g").set(9)
    a.histogram("h").observe(1.0)
    b.histogram("h").observe(4.0)
    b.histogram("only_b").observe(2.0)
    a.merge(b)
    assert a.counter("c").value == 5.0          # counters add
    assert a.gauge("g").value == 9.0            # gauges take the other's
    assert a.histogram("h").count == 2          # histograms union
    assert a.histogram("only_b").count == 1     # missing series created


# ---------------------------------------------------------------------------
# log-bucket histogram
# ---------------------------------------------------------------------------

def test_histogram_percentile_within_bucket_tolerance(rng):
    h = LogHistogram()
    vals = np.exp(rng.normal(size=5000))        # lognormal spans many octaves
    for v in vals:
        h.observe(float(v))
    for p in (1, 25, 50, 75, 90, 99, 99.9):
        exact = float(np.percentile(vals, p, method="higher"))
        got = h.percentile(p)
        # one bucket of relative error at most (plus min/max clamping)
        assert exact / GROWTH <= got <= exact * GROWTH, (p, exact, got)


def test_histogram_single_observation_exact():
    h = LogHistogram()
    h.observe(0.1234)
    for p in (0, 50, 99, 100):
        assert h.percentile(p) == pytest.approx(0.1234)
    assert h.mean == pytest.approx(0.1234)


def test_histogram_zero_bucket_and_rejects():
    h = LogHistogram()
    for _ in range(9):
        h.observe(0.0)
    h.observe(5.0)
    assert h.percentile(50) == 0.0
    assert h.percentile(99) == pytest.approx(5.0)   # vmax clamp: exact
    with pytest.raises(ValueError, match=">= 0"):
        h.observe(-1e-9)
    with pytest.raises(ValueError, match=">= 0"):
        h.observe(float("nan"))
    with pytest.raises(ValueError, match="no observations"):
        LogHistogram().percentile(50)


def test_histogram_merge_equals_union(rng):
    a, b, u = LogHistogram(), LogHistogram(), LogHistogram()
    for i, v in enumerate(np.abs(rng.normal(size=400)) + 1e-6):
        (a if i % 2 else b).observe(float(v))
        u.observe(float(v))
    m = LogHistogram.merged([a, b])
    assert m.count == u.count
    assert m.total == pytest.approx(u.total)
    assert m.buckets == u.buckets
    assert m.vmin == u.vmin and m.vmax == u.vmax
    for p in (10, 50, 95):
        assert m.percentile(p) == u.percentile(p)


def test_histogram_merge_growth_mismatch():
    with pytest.raises(ValueError, match="growth"):
        LogHistogram(growth=2.0).merge(LogHistogram(growth=4.0))


def test_histogram_bucket_boundaries():
    h = LogHistogram(growth=2.0)
    # exact powers of growth land in their own bucket despite log rounding
    for v, want in ((1.0, 0), (2.0, 1), (4.0, 2), (0.5, -1)):
        assert h.bucket_index(v) == want, v


# ---------------------------------------------------------------------------
# Prometheus text dump
# ---------------------------------------------------------------------------

def test_prometheus_dump_cumulative_and_deterministic():
    m = MetricsRegistry()
    m.counter("reqs_total", tenant="a").inc(3)
    h = m.histogram("lat_seconds", tenant="a")
    for v in (0.0, 0.01, 0.02, 0.02):
        h.observe(v)
    text = m.to_prometheus_text()
    assert '# TYPE reqs_total counter' in text
    assert 'reqs_total{tenant="a"} 3' in text
    assert '# TYPE lat_seconds histogram' in text
    assert 'lat_seconds_bucket{le="0",tenant="a"} 1' in text   # zero bucket
    assert 'lat_seconds_bucket{le="+Inf",tenant="a"} 4' in text
    assert 'lat_seconds_count{tenant="a"} 4' in text
    # cumulative bucket counts must be non-decreasing
    cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
            if line.startswith("lat_seconds_bucket")]
    assert cums == sorted(cums)
    assert m.to_prometheus_text() == text              # deterministic
    # label values escape quotes/backslashes
    m2 = MetricsRegistry()
    m2.counter("c", path='a"b\\c').inc()
    assert r'{path="a\"b\\c"}' in m2.to_prometheus_text()


# ---------------------------------------------------------------------------
# hooks: zero-cost when disabled, scoped install
# ---------------------------------------------------------------------------

def test_hooks_disabled_are_noops():
    assert not hooks.enabled()
    # one shared null timer, regardless of stage/labels
    assert hooks.timed("a") is hooks.timed("b", backend="zlib")
    with hooks.timed("a"):
        pass
    hooks.observe("x", 1.0)       # no registry: swallowed
    hooks.count("y")
    assert hooks.installed() is None


def test_hooks_active_scoping():
    m = MetricsRegistry()
    with hooks.active(m) as got:
        assert got is m and hooks.enabled()
        with hooks.timed("stage_x", backend="rans"):
            pass
        hooks.observe("width", 16.0, mode="static")
        hooks.count("events", 2.0)
    assert not hooks.enabled()                        # uninstalled on exit
    hist = m.get("stage_seconds", stage="stage_x", backend="rans")
    assert hist is not None and hist.count == 1
    assert m.get("width", mode="static").count == 1
    assert m.get("events").value == 2.0


def test_hooks_active_uninstalls_on_exception():
    m = MetricsRegistry()
    with pytest.raises(RuntimeError):
        with hooks.active(m):
            raise RuntimeError("boom")
    assert not hooks.enabled()


# ---------------------------------------------------------------------------
# Telemetry on the registry
# ---------------------------------------------------------------------------

def test_telemetry_single_record_percentile_is_the_record():
    tel = Telemetry()
    tel.record(_rec(0, latency=0.6))
    for p in (0, 50, 99, 100):
        assert tel.percentile("total_latency_s", p) == pytest.approx(0.6)


def test_telemetry_empty_served_nonempty_shed():
    tel = Telemetry()
    tel.record_shed(ShedRecord(req_id=0, tenant="a", t_submit=0.0,
                               reason="queue full"))
    s = tel.summary()
    assert s["count"] == 0 and s["shed"] == 1 and s["shed_rate"] == 1.0
    assert "shed" in tel.format_summary()
    with pytest.raises(ValueError, match="1 shed"):
        tel.percentile("total_latency_s", 99)
    # the shed-only tenant still appears in per_tenant, latencies None
    row = tel.per_tenant()["a"]
    assert row["count"] == 0 and row["shed"] == 1
    assert row["p50_latency_s"] is None


def test_telemetry_percentiles_match_numpy():
    tel = Telemetry()
    lats = [0.01 * (i + 1) for i in range(40)]
    for i, lat in enumerate(lats):
        tel.record(_rec(i, latency=lat))
    assert tel.percentile("total_latency_s", 99) == pytest.approx(
        float(np.percentile(lats, 99)))


def test_telemetry_bounded_mode_keeps_aggregates(rng):
    tel = Telemetry(max_records=8)
    lats = np.abs(rng.normal(size=200)) + 1e-3
    for i, lat in enumerate(lats):
        tel.record(_rec(i, latency=float(lat), tenant=f"t{i % 3}"))
    assert len(tel) == 200                  # true count survives the cap
    assert len(tel.records) == 8
    assert tel.truncated
    exact = float(np.percentile(lats, 90))
    got = tel.percentile("total_latency_s", 90)
    assert exact / GROWTH ** 2 <= got <= exact * GROWTH ** 2
    # per-tenant percentile off the tenant's own histogram
    t0 = [float(l) for i, l in enumerate(lats) if i % 3 == 0]
    got0 = tel.percentile("total_latency_s", 50, tenant="t0")
    ex0 = float(np.percentile(t0, 50))
    assert ex0 / GROWTH ** 2 <= got0 <= ex0 * GROWTH ** 2
    # fields without a histogram series are an explicit error when truncated
    with pytest.raises(ValueError, match="truncated"):
        tel.percentile("sched_wait_s", 99)
    # fairness over bits stays exact through aggregates
    assert 0.9 <= tel.fairness("bits_on_wire") <= 1.0
    with pytest.raises(ValueError, match="truncated"):
        tel.fairness("compute_s")
    s = tel.summary()
    assert s["count"] == 200
    assert s["mean_bits_on_wire"] == pytest.approx(1000.0)


def test_telemetry_registry_counters():
    m = MetricsRegistry()
    tel = Telemetry(registry=m)
    for i in range(5):
        tel.record(_rec(i, tenant="a"))
    tel.record_shed(ShedRecord(req_id=5, tenant="a", t_submit=0.0,
                               reason="depth"))
    assert m.counter("gateway_requests_total", tenant="a").value == 5
    assert m.counter("gateway_wire_bits_total", tenant="a").value == 5000
    assert m.counter("gateway_shed_total", tenant="a").value == 1
    assert m.get("gateway_request_latency_seconds", tenant="a").count == 5
    assert tel.metrics is m


def test_telemetry_max_records_validation():
    with pytest.raises(ValueError, match="max_records"):
        Telemetry(max_records=0)
