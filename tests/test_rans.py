"""Entropy-coding subsystem: rANS core, context model, container, backends.

Round-trip properties run under hypothesis when installed (via the
hypothesis_compat shim) and as seeded spot checks otherwise.
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.codec import (CorruptStream, RansContainer, RansTable,
                         decode_channels, decode_ctx, decode_tensor,
                         encode_adaptive_tensor, encode_ctx,
                         encode_static_tensor, normalize_freqs, plan_lanes,
                         rans_decode)
from repro.codec.rans import RANS_L, encode_static
from repro.core import codec as wire
from repro.core.quant import QuantParams


def _qp(c, bits, rng):
    mins = rng.normal(size=(c,)).astype(np.float16)
    return QuantParams(mins=mins, maxs=(mins + 1).astype(np.float16),
                       bits=bits)


def _smooth_residuals(rng, shape, bits, rho=0.9):
    """2D spatially correlated quantized field — synthetic BaF residual.

    shape is (B, H, W, C); correlation runs along H (the up-neighbor the
    rans-ctx model keys on) and W.
    """
    z = rng.normal(size=shape)
    s = np.sqrt(1 - rho**2)
    for i in range(1, shape[1]):
        z[:, i] = rho * z[:, i - 1] + s * z[:, i]
    for j in range(1, shape[2]):
        z[:, :, j] = rho * z[:, :, j - 1] + s * z[:, :, j]
    lo = z.min(axis=tuple(range(z.ndim - 1)), keepdims=True)
    hi = z.max(axis=tuple(range(z.ndim - 1)), keepdims=True)
    q = np.round((z - lo) / np.maximum(hi - lo, 1e-9) * ((1 << bits) - 1))
    return np.clip(q, 0, (1 << bits) - 1).astype(np.uint32)


# ---------------------------------------------------------------------------
# normalize_freqs
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 300), prob_bits=st.integers(9, 14),
       seed=st.integers(0, 2**16))
@settings(max_examples=50, deadline=None)
def test_property_normalize_freqs_exact_sum_min_one(n, prob_bits, seed):
    r = np.random.default_rng(seed)
    # arbitrary code distribution, including many zero-count symbols
    counts = (r.integers(0, 50, size=n)
              * (r.random(n) < 0.4)).astype(np.int64)
    f = normalize_freqs(counts, prob_bits)
    assert int(f.sum()) == 1 << prob_bits
    assert int(f.min()) >= 1


def test_normalize_freqs_all_zero_counts():
    f = normalize_freqs(np.zeros(16, np.int64), 12)
    assert int(f.sum()) == 4096 and int(f.min()) >= 1


def test_normalize_freqs_rejects_oversized_alphabet():
    with pytest.raises(ValueError, match="does not fit"):
        normalize_freqs(np.ones(1 << 13), 12)


# ---------------------------------------------------------------------------
# core coder round-trips
# ---------------------------------------------------------------------------

@given(bits=st.integers(1, 12), n=st.integers(0, 600),
       lanes=st.integers(1, 32), alpha=st.floats(0.05, 5.0),
       seed=st.integers(0, 2**16))
@settings(max_examples=60, deadline=None)
def test_property_static_roundtrip_arbitrary_distributions(bits, n, lanes,
                                                           alpha, seed):
    r = np.random.default_rng(seed)
    nsym = 1 << bits
    p = r.dirichlet(np.full(nsym, alpha))        # arbitrary code distribution
    syms = r.choice(nsym, size=n, p=p).astype(np.uint32)
    table = RansTable.from_counts(np.bincount(syms, minlength=nsym),
                                  max(12, bits + 2))
    states, words = encode_static(syms, table, lanes)
    dec = rans_decode(states, words, n, table, lanes)
    assert np.array_equal(dec, syms)


@given(bits=st.integers(1, 12), h=st.integers(1, 24), w=st.integers(1, 24),
       seed=st.integers(0, 2**16))
@settings(max_examples=40, deadline=None)
def test_property_ctx_roundtrip(bits, h, w, seed):
    r = np.random.default_rng(seed)
    syms = r.integers(0, 1 << bits, size=h * w).astype(np.uint32)
    lanes = plan_lanes(syms.size, w)
    states, words = encode_ctx(syms, bits, lanes, w)
    dec = decode_ctx(states, words, syms.size, bits, lanes, w)
    assert np.array_equal(dec, syms)


@pytest.mark.parametrize("encode_fn", [encode_static_tensor,
                                       encode_adaptive_tensor])
@pytest.mark.parametrize("shape", [(1, 1), (1,), (3, 1, 1), (2, 5, 3, 4),
                                   (0, 4), (4, 0), (6, 6, 8)])
def test_tensor_roundtrip_edge_shapes(rng, encode_fn, shape):
    codes = rng.integers(0, 32, size=shape).astype(np.uint32)
    blob = encode_fn(codes, 5)
    assert np.array_equal(decode_tensor(blob, shape, 5), codes)


@pytest.mark.parametrize("bits", [1, 2, 3, 5, 7, 9, 11, 12])
def test_odd_bit_widths_both_modes(rng, bits):
    codes = rng.integers(0, 1 << bits, size=(2, 7, 5, 3)).astype(np.uint32)
    for fn in (encode_static_tensor, encode_adaptive_tensor):
        assert np.array_equal(
            decode_tensor(fn(codes, bits), codes.shape, bits), codes)


def test_rans_rejects_out_of_range_codes(rng):
    with pytest.raises(ValueError, match="does not fit"):
        encode_static_tensor(np.full((4, 4), 300), 8)
    with pytest.raises(ValueError, match="negative"):
        encode_adaptive_tensor(np.full((4, 4), -1), 8)
    with pytest.raises(ValueError, match="1..12"):
        encode_static_tensor(np.zeros((4, 4), np.uint32), 16)


# ---------------------------------------------------------------------------
# container: partial decode + corruption
# ---------------------------------------------------------------------------

def test_partial_decode_matches_full(rng):
    codes = rng.integers(0, 256, size=(2, 8, 8, 6)).astype(np.uint32)
    for fn in (encode_static_tensor, encode_adaptive_tensor):
        blob = fn(codes, 8)
        full = decode_tensor(blob, codes.shape, 8)
        part = decode_channels(blob, [5, 0, 2])
        for row, ch in zip(part, [5, 0, 2]):
            assert np.array_equal(row, full[..., ch].reshape(-1))


def test_partial_decode_skips_corrupt_other_chunks(rng):
    """Corruption in chunk j must not prevent decoding chunk i != j."""
    codes = rng.integers(0, 64, size=(1, 16, 16, 4)).astype(np.uint32)
    blob = bytearray(encode_adaptive_tensor(codes, 6))
    blob[-3] ^= 0x55                       # flip bits inside the LAST chunk
    got = decode_channels(bytes(blob), [0])
    assert np.array_equal(got[0], codes[..., 0].reshape(-1))
    with pytest.raises(CorruptStream):
        decode_channels(bytes(blob), [3])


@pytest.mark.parametrize("mutate,msg", [
    (lambda b: b"XXXX" + b[4:], "bad container magic"),
    (lambda b: b[:1], "truncated container header"),
    (lambda b: b[:4] + bytes([9]) + b[5:], "unsupported container version"),
    (lambda b: b[:5] + bytes([7]) + b[6:], "header CRC mismatch"),
    (lambda b: b + b"zz", "trailing garbage"),
    (lambda b: b[:-5], "truncated chunk"),
])
def test_container_corruption_distinct_errors(rng, mutate, msg):
    codes = rng.integers(0, 16, size=(4, 4, 2)).astype(np.uint32)
    blob = encode_static_tensor(codes, 4)
    with pytest.raises(CorruptStream, match=msg):
        RansContainer.parse(mutate(blob)).decode_all()


def test_container_rejects_unknown_mode_with_valid_crc():
    import struct
    import zlib as _z

    from repro.codec import container as box
    hdr = box._HEADER.pack(box.MAGIC, box.VERSION, 7, 4, 12, 1, 0, 0, 0)
    blob = hdr + struct.pack("<I", _z.crc32(hdr))
    with pytest.raises(CorruptStream, match="unknown container mode"):
        RansContainer.parse(blob)


def test_decode_tensor_shape_bits_crosschecks(rng):
    codes = rng.integers(0, 16, size=(4, 4, 2)).astype(np.uint32)
    blob = encode_static_tensor(codes, 4)
    with pytest.raises(CorruptStream, match="wire header says"):
        decode_tensor(blob, codes.shape, 6)
    with pytest.raises(CorruptStream, match="tile chunks"):
        decode_tensor(blob, (4, 4, 3), 4)
    with pytest.raises(CorruptStream, match="symbols"):
        decode_tensor(blob, (2, 4, 2), 4)


@given(seed=st.integers(0, 2**12))
@settings(max_examples=25, deadline=None)
def test_property_bit_flips_never_serve_wrong_data(seed):
    """Defense in depth (header CRC, table adler32, per-chunk CRC, lane-state
    check): any single-bit flip in a container either raises CorruptStream
    or decodes to exactly the original codes (flips in semantically-neutral
    zlib metadata bits of the table blob) — wrong tensors are never served."""
    r = np.random.default_rng(seed)
    codes = r.integers(0, 256, size=(1, 8, 8, 3)).astype(np.uint32)
    fn = encode_static_tensor if seed % 2 else encode_adaptive_tensor
    blob = bytearray(fn(codes, 8))
    pos = int(r.integers(0, len(blob)))
    blob[pos] ^= 1 << int(r.integers(0, 8))
    try:
        out = decode_tensor(bytes(blob), codes.shape, 8)
    except CorruptStream:
        return
    assert np.array_equal(out, codes)


# ---------------------------------------------------------------------------
# wire-codec integration (core/codec.py registry)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["rans", "rans-ctx"])
@pytest.mark.parametrize("bits", [2, 3, 5, 8])
def test_wire_roundtrip_all_c_bits(rng, backend, bits):
    for c in (1, 4, 8):
        codes = rng.integers(0, 1 << bits, size=(2, 6, 6, c)).astype(np.uint8)
        qp = _qp(c, bits, rng)
        enc = wire.encode(codes, qp, backend=backend)
        dec, dec_qp = wire.decode(
            wire.EncodedTensor.from_bytes(enc.to_bytes()))
        assert np.array_equal(dec, codes)
        assert dec_qp.bits == bits


def test_wire_bits_counts_whole_container(rng):
    codes = rng.integers(0, 256, size=(1, 4, 4, 4)).astype(np.uint8)
    qp = _qp(4, 8, rng)
    for backend in ("raw", "zlib", "rans", "rans-ctx"):
        enc = wire.encode(codes, qp, backend=backend)
        assert enc.wire_bits() == 8 * len(enc.to_bytes())
        assert enc.total_bits() == enc.wire_bits() - 8 * enc.header_bytes()


def test_ctx_beats_order0_floor_on_baf_residuals(rng):
    """Acceptance: rans-ctx within 5% of the empirical-entropy floor on
    synthetic BaF residuals (it lands well below by using 2D context)."""
    codes = _smooth_residuals(rng, (2, 48, 48, 8), bits=6)
    qp = _qp(8, 6, rng)
    enc = wire.encode(codes, qp, backend="rans-ctx")
    floor = wire.empirical_entropy_bits(codes, 6)
    assert 8 * len(enc.payload) <= 1.05 * floor


def test_static_close_to_floor_on_skewed_stream(rng):
    """Static tables on an iid skewed stream sit near the order-0 entropy."""
    p = np.asarray([0.6, 0.2, 0.1, 0.05, 0.02, 0.01, 0.01, 0.01])
    codes = rng.choice(8, size=(1, 64, 64, 4), p=p).astype(np.uint32)
    qp = _qp(4, 3, rng)
    enc = wire.encode(codes, qp, backend="rans")
    floor = wire.empirical_entropy_bits(codes, 3)
    assert 8 * len(enc.payload) <= 1.10 * floor


# ---------------------------------------------------------------------------
# Pallas histogram/CDF kernel vs bincount
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,bits", [((4, 16, 16, 8), 8), ((37, 5), 4),
                                        ((1, 1), 1), ((3, 7, 3), 6)])
def test_histogram_kernel_matches_bincount(rng, shape, bits):
    from repro.kernels.histogram import channel_histogram_cdf
    codes = rng.integers(0, 1 << bits, size=shape)
    counts, cdf = channel_histogram_cdf(codes, bits)
    c = shape[-1]
    ref = np.stack([np.bincount(codes.reshape(-1, c)[:, i],
                                minlength=1 << bits) for i in range(c)])
    assert np.array_equal(counts, ref)
    assert np.array_equal(cdf, np.cumsum(ref, axis=1) - ref)


def test_histogram_kernel_empty():
    from repro.kernels.histogram import channel_histogram_cdf
    counts, cdf = channel_histogram_cdf(np.empty((0, 4), np.int32), 8)
    assert counts.shape == (4, 256) and not counts.any()
    assert cdf.shape == (4, 256) and not cdf.any()
