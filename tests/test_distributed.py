"""Distribution layer: sharding rules, logical-axis shim, compressed all-reduce
and a multi-device dry-run smoke cell (subprocess — jax device count is locked
at first init, so fake-device tests cannot run in the main test process)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.api import AxisRules, axis_ctx, logical_axes
from repro.distributed.sharding import batch_pspec, param_pspec, params_pspecs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
       "JAX_PLATFORMS": "cpu"}


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_param_pspec_rules():
    mesh = _FakeMesh({"data": 16, "model": 16})
    leaf = jnp.zeros((8192, 4096))

    class K:  # tree path key stub
        def __init__(self, key):
            self.key = key

    spec = param_pspec((K("layers"), K("attn"), K("wq")), leaf, mesh)
    assert spec == P("data", "model")
    spec = param_pspec((K("attn"), K("wo")), leaf, mesh)
    assert spec == P("model", "data")
    # indivisible dim stays unsharded (whisper vocab 51865)
    # indivisible vocab dim stays unsharded (whisper 51865); d_model -> data
    spec = param_pspec((K("embed"),), jnp.zeros((51865, 384)), mesh)
    assert spec == P(None, "data")   # template (M, D): 51865 % 16 != 0
    # stacked MoE expert dim -> model axis
    spec = param_pspec((K("moe"), K("wup")), jnp.zeros((64, 2048, 1024)), mesh)
    assert spec == P("model", "data", None)
    # unknown leaves replicated
    assert param_pspec((K("ln1"), K("scale")), jnp.zeros((64,)), mesh) == P()


def test_batch_pspec_divisibility():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_pspec(256, mesh, multi_pod=False) == "data"
    assert batch_pspec(256, mesh, multi_pod=True) == ("pod", "data")
    assert batch_pspec(1, mesh, multi_pod=True) is None   # long_500k b=1
    assert batch_pspec(2, mesh, multi_pod=True) == "pod"


def test_logical_axes_noop_outside_context(rng):
    assert logical_axes("batch", None, "ffn") is None
    with axis_ctx(AxisRules(rules={"batch": "data", "ffn": "model"})):
        assert logical_axes("batch", None, "ffn") == P("data", None, "model")
    assert logical_axes("batch") is None


def test_params_pspecs_cover_every_arch():
    """Every large (>=1M elem) param leaf of every full config is sharded on
    at least one axis — catches rule-table gaps that would replicate a 72B
    matrix onto every chip."""
    from repro.configs import ARCH_IDS, get_config
    from repro.launch.specs import abstract_params
    from repro.models.encdec import init_encdec
    from repro.models.lm import init_lm
    mesh = _FakeMesh({"data": 16, "model": 16})
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        init = init_encdec if cfg.family == "audio" else init_lm
        a_params = abstract_params(cfg, init)
        specs = params_pspecs(a_params, mesh)
        flat = jax.tree_util.tree_flatten_with_path(a_params)[0]
        sflat = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        for (path, leaf), spec in zip(flat, sflat):
            n = int(np.prod(leaf.shape))
            if n >= 1_000_000:
                assert any(a is not None for a in spec), \
                    f"{arch}: {jax.tree_util.keystr(path)} {leaf.shape} replicated"


def test_cache_pspecs_cover_namedtuple_fields():
    """Regression for §Perf HC0: NamedTuple field names (GetAttrKey) must
    reach the rule matcher — a silent miss replicates every KV cache across
    the model axis. Every large cache leaf must get a non-trivial spec."""
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.distributed.sharding import cache_pspecs
    from repro.models.lm import init_decode_cache
    mesh = _FakeMesh({"data": 16, "model": 16})
    for arch in ("qwen2_7b", "rwkv6_3b", "zamba2_1p2b"):
        cfg = get_config(arch)
        cache = jax.eval_shape(lambda: init_decode_cache(cfg, 128, 4096))
        specs = cache_pspecs(cache, mesh, "data")
        flat = jax.tree_util.tree_flatten_with_path(cache)[0]
        sflat = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        for (path, leaf), spec in zip(flat, sflat):
            if int(np.prod(leaf.shape)) >= 1_000_000:
                assert any(a is not None for a in spec), \
                    f"{arch}: {jax.tree_util.keystr(path)} {leaf.shape} replicated"


def _run(code: str, devices: int = 8):
    env = {**ENV, "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}"}
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_grad_compress_all_reduce_multidevice():
    """On a (pod=2, data=2, model=2) fake mesh: quantized cross-pod mean is
    close to the exact mean, residual = g - dequant(local codes)."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import set_mesh
from repro.optim.grad_compress import quantized_pod_mean
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
g = {"w": jnp.linspace(-1, 1, 64).reshape(8, 8)}
with set_mesh(mesh):
    gp = jax.device_put(g, NamedSharding(mesh, P()))
    # pod-varying input: add pod index so the mean is non-trivial
    def f(x):
        return quantized_pod_mean(x, mesh, bits=8)
    mean, resid = jax.jit(f)(gp)
exact = g["w"]  # both pods hold the same tensor -> mean == tensor
err = float(jnp.max(jnp.abs(mean["w"] - exact)))
print("ERR", err)
assert err < 2e-2, err
rez = float(jnp.max(jnp.abs(resid["w"])))
assert rez < 2e-2, rez
print("OK")
""")
    assert "OK" in out


def test_dryrun_smoke_cell_multidevice():
    """A reduced-config cell lowers + compiles on a (2,2,2) fake-device mesh —
    the same code path as the production dry-run, at test-friendly scale."""
    out = _run("""
import os
os.environ["DRYRUN_XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.compat import set_mesh
from repro.launch.specs import build_cell
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
for arch, shape in [("qwen2_7b", "train_4k"), ("rwkv6_3b", "decode_32k")]:
    cell = build_cell(arch, shape, mesh, multi_pod=True, smoke=True)
    with set_mesh(mesh):
        c = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                    out_shardings=cell.out_shardings,
                    donate_argnums=cell.donate).lower(*cell.args).compile()
    assert c.memory_analysis() is not None
    print("OK", arch, shape)
""")
    assert out.count("OK") == 2
