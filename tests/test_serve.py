"""Serving invariants: decode-with-cache == teacher-forced forward; chunked
long-context ingestion == full pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.lm import (init_decode_cache, init_lm, lm_decode_step,
                             lm_forward)
from repro.serve.engine import init_long_state, make_long_ingest


@pytest.mark.parametrize("arch", ["qwen2_7b", "olmoe_1b_7b", "rwkv6_3b",
                                  "zamba2_1p2b"])
def test_decode_matches_prefill_logits(arch):
    """Replaying a sequence token-by-token through the decode path must give
    the same next-token logits as the full forward at every position."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # dropless at tiny scale so routing matches between paths
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    b, s = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    full_logits, _ = lm_forward(params, cfg, tokens=tokens, remat=False)

    cache = init_decode_cache(cfg, b, max_len=s)
    got = []
    for t in range(s):
        logits, cache = lm_decode_step(params, cfg, cache, tokens[:, t])
        got.append(logits)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("arch", ["rwkv6_3b", "zamba2_1p2b"])
def test_long_ingest_matches_full_forward(arch):
    """Chunked long-context ingestion's final logits == full-sequence forward
    (for zamba2 the full forward must use the same attention window)."""
    cfg = get_smoke_config(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    b, s = 1, 32
    block = 16 if cfg.family == "hybrid" else 16
    if cfg.family == "hybrid":
        cfg = cfg.with_(hybrid=dataclasses.replace(cfg.hybrid,
                                                   attn_window_long=block))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    ingest = make_long_ingest(cfg, block=block)
    last_logits, state = ingest(params, tokens)

    window = block if cfg.family == "hybrid" else None
    full_logits, _ = lm_forward(params, cfg, tokens=tokens, window=window,
                                remat=False)
    np.testing.assert_allclose(np.asarray(last_logits, np.float32),
                               np.asarray(full_logits[:, -1], np.float32),
                               atol=2e-2, rtol=2e-2)
    assert int(state.block_idx) == s // block


def test_long_state_shapes():
    cfg = get_smoke_config("zamba2_1p2b")
    st = init_long_state(cfg, batch=2, block=16)
    assert st.shared_k.shape[2] == 16          # one window of carry KV
    assert st.layer_states.ssm.shape[0] == cfg.n_layers
